"""AOT lowering: jax → HLO **text** artifacts for the rust PJRT runtime.

HLO text (not `.serialize()`) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids, which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts

Writes one `lloyd_step_{M}x{B}x{K}.hlo.txt` per shape bucket plus a
`manifest.txt` (one line per artifact: M B K filename) the rust runtime
reads to pick the smallest bucket that fits a clustering problem.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest_lines = []
    for m, b, k in model.BUCKETS:
        fname = f"lloyd_step_{m}x{b}x{k}.hlo.txt"
        lowered = jax.jit(lambda p, w, q: model.lloyd_step(p, w, q, interpret=True)).lower(
            *model.example_args(m, b, k)
        )
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{m} {b} {k} {fname}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {os.path.join(args.out, 'manifest.txt')}")


if __name__ == "__main__":
    main()
