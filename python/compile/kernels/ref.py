"""Pure-jnp correctness oracles for the Pallas kernel and the L2 Lloyd step.

These are the ground truth the pytest suite checks against; they use no
Pallas and no tiling, just dense jnp ops.
"""

import jax.numpy as jnp

LOG_CLAMP = 1e-30


def cross_entropy_matrix(w, lq):
    """CE[i, k] = sum_b w[i, b] * lq[k, b] — the kernel's contract."""
    return w @ lq.T


def _one_hot(idx, k):
    return (idx[:, None] == jnp.arange(k)[None, :]).astype(jnp.float32)


def lloyd_step(p, w, q):
    """One weighted-KL Lloyd iteration, dense reference.

    Args:
      p: (M, B) distributions (rows sum to 1; padded rows all-zero).
      w: (M,) sequence-length weights (0 = padded row).
      q: (K, B) centroids (zero rows = padded clusters).
    Returns:
      assign: (M,) int32 argmin_k of n_i*KL(P_i||Q_k)
      new_q:  (K, B) weighted member means (zero rows for empty clusters)
      obj:    () float32 — sum_i n_i * KL(P_i || Q_assign_i)
    """
    wp = p * w[:, None]
    lq = jnp.log2(jnp.maximum(q, LOG_CLAMP))
    ce = cross_entropy_matrix(wp, lq)  # (M, K)
    logp = jnp.where(p > 0, jnp.log2(jnp.where(p > 0, p, 1.0)), 0.0)
    selfh = jnp.sum(wp * logp, axis=1)  # (M,)
    d = selfh[:, None] - ce  # (M, K): n_i * KL(P_i || Q_k)
    assign = jnp.argmin(d, axis=1).astype(jnp.int32)
    obj = jnp.sum(jnp.min(d, axis=1))
    onehot = _one_hot(assign, q.shape[0])  # (M, K)
    mass = onehot.T @ w  # (K,)
    raw = onehot.T @ wp  # (K, B)
    new_q = jnp.where(mass[:, None] > 0, raw / jnp.maximum(mass[:, None], 1e-30), 0.0)
    return assign, new_q, obj
