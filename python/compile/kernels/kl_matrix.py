"""L1 Pallas kernel: the weighted cross-entropy matmul at the heart of the
Bregman clustering objective (paper eq. 6).

The M×K divergence matrix decomposes as

    n_i * D_KL(P_i || Q_k) = selfh_i  -  CE[i, k]
    selfh_i  = n_i * sum_b P_ib * log2(P_ib)          (assignment-invariant)
    CE[i, k] = sum_b W_ib * LQ_kb,   W = n[:, None] * P,  LQ = log2(clamp(Q))

so the hot spot is `CE = W @ LQ.T` — an (M×B)·(B×K) matmul that maps onto
the TPU MXU. This kernel computes exactly that contraction.

TPU mapping (DESIGN.md §Hardware-Adaptation):
  * grid = (M/TM, K/TK, B/TB); the B axis is innermost so each (i, k) output
    tile accumulates across B-tiles while staying resident in VMEM.
  * BlockSpecs stream (TM×TB) slabs of W and (TK×TB) slabs of LQ from HBM;
    Pallas double-buffers the HBM→VMEM copies across grid steps.
  * VMEM footprint per step = TM*TB + TK*TB + TM*TK floats
    (128*256 + 16*256 + 128*16 = 38,912 f32 ≈ 152 KiB — far under the
    ~16 MiB VMEM budget; the tile sizes trade pipelining depth against MXU
    occupancy: TM=128 feeds full 128-lane MXU rows).
  * `interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
    custom-calls; real-TPU numbers are estimated in DESIGN.md §Perf.

Correctness oracle: `ref.cross_entropy_matrix` (pure jnp); pytest sweeps
shapes/dtypes with hypothesis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes. B and K bucket sizes in aot.py are multiples of these; M
# buckets are multiples of TILE_M.
TILE_M = 128
TILE_K = 16
TILE_B = 256
# §Perf iterations 2–3: when the padded shape allows, use wider M/B tiles —
# fewer grid steps (fewer HBM↔VMEM round-trips per output tile on TPU;
# fewer interpret-mode dispatches on CPU). VMEM/step at (TM,TB)=(256,512):
# 256*512 + 16*512 + 256*16 = 143,360 f32 ≈ 560 KiB — still ≪ 16 MiB.
TILE_B_WIDE = 512
TILE_M_WIDE = 256

# Floor for log2 of centroid entries: zero-probability (padding) entries
# clamp here, making padded clusters maximally unattractive (the rust
# coordinator relies on this to mask padded K rows).
LOG_CLAMP = 1e-30


def _ce_kernel(w_ref, lq_ref, o_ref):
    """One grid step: accumulate a (TM, TK) output tile over one B-slab."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (TM, TB) @ (TB, TK) -> (TM, TK); jnp.dot on the MXU in f32
    o_ref[...] += jnp.dot(
        w_ref[...], lq_ref[...].T, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def cross_entropy_matrix(w, lq, *, interpret=True):
    """CE[i, k] = sum_b w[i, b] * lq[k, b] via the Pallas kernel.

    Args:
      w:  (M, B) float32 — weight-scaled distributions (n_i * P_i).
      lq: (K, B) float32 — log2 of (clamped) centroids.
    Returns:
      (M, K) float32.
    """
    m, b = w.shape
    k, b2 = lq.shape
    assert b == b2, f"alphabet mismatch {b} vs {b2}"
    tb = TILE_B_WIDE if b % TILE_B_WIDE == 0 else TILE_B
    tm = TILE_M_WIDE if m % TILE_M_WIDE == 0 else TILE_M
    assert m % tm == 0, f"M={m} must be a multiple of {tm}"
    assert k % TILE_K == 0, f"K={k} must be a multiple of {TILE_K}"
    assert b % tb == 0, f"B={b} must be a multiple of {tb}"
    grid = (m // tm, k // TILE_K, b // tb)
    return pl.pallas_call(
        _ce_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tb), lambda i, j, bb: (i, bb)),
            pl.BlockSpec((TILE_K, tb), lambda i, j, bb: (j, bb)),
        ],
        out_specs=pl.BlockSpec((tm, TILE_K), lambda i, j, bb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=interpret,
    )(w, lq)


def log2_clamped(q):
    """log2 with the padding clamp the kernel contract expects."""
    return jnp.log2(jnp.maximum(q, LOG_CLAMP))
