"""L2: the JAX compute graph AOT-compiled for the rust coordinator — one
weighted-KL Lloyd iteration (assignment + centroid update + objective),
built on the L1 Pallas cross-entropy kernel.

The rust side (`rust/src/runtime/xla_engine.rs`) drives the host-side loop
(convergence test, empty-cluster repair, K sweep); this graph is the
matmul-shaped inner step. Padding contract (shared with rust):

  * padded rows   : p = 0, w = 0   → contribute 0 to the objective, argmin
                    value irrelevant (rust ignores them);
  * padded columns: p = 0 and q = 0 beyond the real alphabet → zero weight
                    ⇒ no contribution;
  * padded clusters: q rows all-zero → log2(clamp) ≈ −99.7 makes them
                    maximally unattractive, so real rows never pick them.

Fusion notes (§Perf): the divergence matrix `d` feeds both the argmin and
the min; XLA fuses `selfh` broadcast + subtraction + both reductions into
the kernel's consumer, so the M×K matrix is produced once (verified on the
lowered HLO by `tests/test_model.py::test_single_ce_matmul_in_hlo`).
"""

import jax
import jax.numpy as jnp

from .kernels import kl_matrix
from .kernels.kl_matrix import LOG_CLAMP


def lloyd_step(p, w, q, *, interpret=True):
    """One Lloyd iteration. Shapes: p (M,B) f32, w (M,) f32, q (K,B) f32.

    Returns (assign (M,) i32, new_q (K,B) f32, obj () f32).
    """
    m, b = p.shape
    k, _ = q.shape
    wp = p * w[:, None]
    lq = kl_matrix.log2_clamped(q)
    ce = kl_matrix.cross_entropy_matrix(wp, lq, interpret=interpret)  # (M, K)
    logp = jnp.where(p > 0, jnp.log2(jnp.where(p > 0, p, 1.0)), 0.0)
    selfh = jnp.sum(wp * logp, axis=1)
    d = selfh[:, None] - ce
    assign = jnp.argmin(d, axis=1).astype(jnp.int32)
    obj = jnp.sum(jnp.min(d, axis=1))
    onehot = (assign[:, None] == jnp.arange(k)[None, :]).astype(jnp.float32)
    mass = onehot.T @ w
    raw = onehot.T @ wp
    new_q = jnp.where(mass[:, None] > 0, raw / jnp.maximum(mass[:, None], 1e-30), 0.0)
    return assign, new_q, obj


# Shape buckets lowered by aot.py. (M, B, K) — M, B, K must be multiples of
# the kernel tiles (128, 256, 16). Larger alphabets (huge regression fit
# tables) fall back to the rust NativeEngine; DESIGN.md §2 records this.
BUCKETS = [
    (128, 256, 16),
    (512, 256, 16),
    (512, 1024, 16),
    (2048, 2048, 16),
]


def example_args(m, b, k):
    spec = jax.ShapeDtypeStruct
    return (
        spec((m, b), jnp.float32),
        spec((m,), jnp.float32),
        spec((k, b), jnp.float32),
    )
