# makes `pytest python/tests/` work from the repo root: pytest inserts
# this directory (python/) into sys.path, so `compile.*` imports resolve.
