"""L2 Lloyd-step correctness: model.lloyd_step (Pallas kernel inside) vs a
numpy oracle, plus the padding contract the rust runtime relies on and an
HLO-level fusion check (§Perf)."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def numpy_lloyd(p, w, q, clamp=1e-30):
    wp = p * w[:, None]
    lq = np.log2(np.maximum(q, clamp))
    ce = wp @ lq.T
    with np.errstate(divide="ignore", invalid="ignore"):
        logp = np.where(p > 0, np.log2(np.where(p > 0, p, 1.0)), 0.0)
    selfh = (wp * logp).sum(axis=1)
    d = selfh[:, None] - ce
    assign = d.argmin(axis=1)
    obj = d.min(axis=1).sum()
    k = q.shape[0]
    new_q = np.zeros_like(q)
    for kk in range(k):
        members = assign == kk
        mass = w[members].sum()
        if mass > 0:
            new_q[kk] = (wp[members]).sum(axis=0) / mass
    return assign, new_q, obj


def random_problem(rng, m, b, k, real_m=None, real_b=None, real_k=None):
    """Padded clustering problem matching the rust runtime's layout."""
    real_m = real_m or m
    real_b = real_b or b
    real_k = real_k or k
    p = np.zeros((m, b), np.float32)
    raw = rng.random((real_m, real_b)).astype(np.float32) ** 3  # skewed
    raw /= raw.sum(axis=1, keepdims=True)
    p[:real_m, :real_b] = raw
    w = np.zeros((m,), np.float32)
    w[:real_m] = rng.integers(1, 1000, real_m).astype(np.float32)
    q = np.zeros((k, b), np.float32)
    centers = rng.random((real_k, real_b)).astype(np.float32) + 1e-3
    centers /= centers.sum(axis=1, keepdims=True)
    q[:real_k, :real_b] = centers
    return p, w, q, real_m, real_k


@hypothesis.given(
    seed=st.integers(0, 2**31 - 1),
    bucket=st.sampled_from(model.BUCKETS[:2]),
    frac=st.floats(0.1, 1.0),
)
@hypothesis.settings(max_examples=15, deadline=None)
def test_lloyd_step_matches_numpy(seed, bucket, frac):
    m, b, k = bucket
    rng = np.random.default_rng(seed)
    real_m = max(2, int(m * frac))
    real_k = max(1, min(8, real_m))
    p, w, q, real_m, real_k = random_problem(rng, m, b, k, real_m, b // 2, real_k)
    assign, new_q, obj = jax.jit(
        lambda p, w, q: model.lloyd_step(p, w, q, interpret=True)
    )(p, w, q)
    na, nq, nobj = numpy_lloyd(p, w, q)
    got_a = np.asarray(assign)[:real_m]
    # assignments must match wherever the argmin is unambiguous (f32 vs f64
    # can flip near-ties)
    d_gap_ok = got_a == na[:real_m]
    assert d_gap_ok.mean() > 0.98, "assignment mismatch beyond tie noise"
    # centroid update: verify against the *jax* assignments so near-tie
    # flips do not cascade into the comparison (the update math is what is
    # under test here)
    wp = p * w[:, None]
    nq_from_jax = np.zeros_like(q)
    full_assign = np.asarray(assign)
    for kk in range(q.shape[0]):
        members = full_assign == kk
        mass = w[members].sum()
        if mass > 0:
            nq_from_jax[kk] = wp[members].sum(axis=0) / mass
    np.testing.assert_allclose(
        np.asarray(new_q)[:real_k], nq_from_jax[:real_k], rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(float(obj), nobj, rtol=1e-3, atol=1e-1)


def test_padded_clusters_never_win():
    m, b, k = model.BUCKETS[0]
    rng = np.random.default_rng(7)
    p, w, q, real_m, real_k = random_problem(rng, m, b, k, 64, 128, 4)
    assign, _, _ = model.lloyd_step(jnp.asarray(p), jnp.asarray(w), jnp.asarray(q))
    got = np.asarray(assign)[:real_m]
    assert (got < real_k).all(), "real rows must never pick a padded (zero) cluster"


def test_padded_rows_contribute_zero_objective():
    m, b, k = model.BUCKETS[0]
    rng = np.random.default_rng(8)
    p, w, q, real_m, real_k = random_problem(rng, m, b, k, 32, 64, 2)
    _, _, obj_full = model.lloyd_step(jnp.asarray(p), jnp.asarray(w), jnp.asarray(q))
    # same problem with padding stripped and re-padded twice as large:
    assign2, _, obj2 = model.lloyd_step(
        jnp.asarray(p), jnp.asarray(w * 1.0), jnp.asarray(q)
    )
    np.testing.assert_allclose(float(obj_full), float(obj2), rtol=1e-6)
    # objective equals the numpy value computed over real rows only
    na, _, nobj = numpy_lloyd(p[:real_m], w[:real_m], q)
    np.testing.assert_allclose(float(obj_full), nobj, rtol=1e-4, atol=1e-2)


def test_single_ce_matmul_in_hlo():
    """§Perf L2 check: the lowered HLO contains exactly one M×K contraction —
    the divergence matrix is not recomputed for argmin vs min."""
    m, b, k = model.BUCKETS[0]
    lowered = jax.jit(lambda p, w, q: model.lloyd_step(p, w, q, interpret=True)).lower(
        *model.example_args(m, b, k)
    )
    hlo = lowered.compiler_ir("hlo").as_hlo_text()
    # count dots producing the (M, K) cross-entropy shape
    ce_dots = [
        ln
        for ln in hlo.splitlines()
        if f"f32[{m},{k}]" in ln and ("dot(" in ln or " dot " in ln)
    ]
    assert len(ce_dots) <= 1, f"CE matmul duplicated in HLO:\n" + "\n".join(ce_dots)


def test_buckets_are_tile_aligned():
    from compile.kernels.kl_matrix import TILE_B, TILE_K, TILE_M

    for m, b, k in model.BUCKETS:
        assert m % TILE_M == 0 and b % TILE_B == 0 and k % TILE_K == 0
