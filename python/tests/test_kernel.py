"""L1 kernel correctness: Pallas cross-entropy matmul vs the pure-jnp
oracle, swept over shapes and data distributions with hypothesis."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import kl_matrix, ref

jax.config.update("jax_platform_name", "cpu")


def rand_inputs(rng, m, b, k, sparsity=0.0):
    w = rng.random((m, b), dtype=np.float32)
    if sparsity > 0:
        w *= rng.random((m, b)) > sparsity
    q = rng.random((k, b), dtype=np.float32) + 1e-6
    q /= q.sum(axis=1, keepdims=True)
    lq = np.log2(np.maximum(q, kl_matrix.LOG_CLAMP)).astype(np.float32)
    return jnp.asarray(w), jnp.asarray(lq)


TILE_SHAPES = [
    (128, 256, 16),
    (256, 256, 16),
    (128, 512, 16),
    (128, 256, 32),
    (384, 768, 48),
]


@pytest.mark.parametrize("m,b,k", TILE_SHAPES)
def test_kernel_matches_ref(m, b, k):
    rng = np.random.default_rng(m * 31 + b * 7 + k)
    w, lq = rand_inputs(rng, m, b, k)
    got = kl_matrix.cross_entropy_matrix(w, lq)
    want = ref.cross_entropy_matrix(w, lq)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@hypothesis.given(
    mi=st.integers(1, 3),
    bi=st.integers(1, 3),
    ki=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
    sparsity=st.sampled_from([0.0, 0.5, 0.95]),
)
@hypothesis.settings(max_examples=25, deadline=None)
def test_kernel_matches_ref_hypothesis(mi, bi, ki, seed, sparsity):
    m, b, k = 128 * mi, 256 * bi, 16 * ki
    rng = np.random.default_rng(seed)
    w, lq = rand_inputs(rng, m, b, k, sparsity)
    got = kl_matrix.cross_entropy_matrix(w, lq)
    want = ref.cross_entropy_matrix(w, lq)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_kernel_zero_weight_rows_give_zero():
    m, b, k = 128, 256, 16
    rng = np.random.default_rng(0)
    _, lq = rand_inputs(rng, m, b, k)
    w = jnp.zeros((m, b), jnp.float32)
    got = kl_matrix.cross_entropy_matrix(w, lq)
    np.testing.assert_array_equal(np.asarray(got), np.zeros((m, k), np.float32))


def test_kernel_rejects_unaligned_shapes():
    w = jnp.zeros((100, 256), jnp.float32)  # M not multiple of 128
    lq = jnp.zeros((16, 256), jnp.float32)
    with pytest.raises(AssertionError):
        kl_matrix.cross_entropy_matrix(w, lq)


def test_log2_clamped_padding_contract():
    q = jnp.array([[0.0, 0.5, 0.5]], jnp.float32)
    lq = np.asarray(kl_matrix.log2_clamped(q))
    assert lq[0, 0] < -90.0, "zero centroid entries must clamp very negative"
    np.testing.assert_allclose(lq[0, 1], -1.0, rtol=1e-6)
