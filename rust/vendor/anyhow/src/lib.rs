//! Offline stand-in for the `anyhow` crate, implementing the subset of its
//! API that `rf_compress` uses: [`Error`], [`Result`], the [`Context`]
//! extension trait for `Result` and `Option`, and the [`anyhow!`] / [`bail!`]
//! macros.
//!
//! Semantics match upstream where it matters to callers:
//!
//! * `{}` (Display) prints the outermost message only;
//! * `{:#}` (alternate) prints the whole context chain joined by `": "`;
//! * `Debug` (what `unwrap()` shows) also prints the full chain;
//! * any `E: std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] via `?`, capturing its `source()` chain.
//!
//! The build environment has no network access, so this crate stands in for
//! the real dependency; swapping back to upstream `anyhow` is a one-line
//! change in `Cargo.toml`.

use std::fmt;

/// An error wrapping a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (what [`Context::context`] does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes the blanket `From` below coherent (same trick as upstream).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// As [`Context::context`], evaluating the message lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Early-return with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(format!("{e:?}"), "outer: inner");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<()> = Err(io_err()).context("reading file");
        assert_eq!(format!("{:#}", r.unwrap_err()), "reading file: missing");
        let o: Result<u32> = None.with_context(|| format!("key {}", 7));
        assert_eq!(format!("{}", o.unwrap_err()), "key 7");
        let ok: Result<u32> = Some(3).context("unused");
        assert_eq!(ok.unwrap(), 3);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<String> {
            let s = String::from_utf8(vec![0xff])?;
            Ok(s)
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero not allowed");
            }
            ensure!(x < 10, "too big: {x}");
            Err(anyhow!("fallthrough {}", x))
        }
        assert_eq!(f(0).unwrap_err().to_string(), "zero not allowed");
        assert_eq!(f(11).unwrap_err().to_string(), "too big: 11");
        assert_eq!(f(5).unwrap_err().to_string(), "fallthrough 5");
    }
}
