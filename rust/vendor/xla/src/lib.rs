//! Offline stub of the `xla`/PJRT bindings used by
//! `rf_compress::runtime::xla_engine`.
//!
//! The real dependency links the PJRT CPU plugin and compiles AOT-lowered
//! HLO artifacts; neither exists in this offline build environment. This
//! stub keeps the exact API shape so the engine code compiles unchanged,
//! while [`PjRtClient::cpu`] (the first call on every load path) returns an
//! error — `HybridEngine` then degrades to the native Lloyd engine and the
//! XLA integration tests skip with a loud message, exactly as they do when
//! `make artifacts` has not been run.

use std::fmt;
use std::path::Path;

/// Stub error: every runtime entry point produces one of these.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable() -> Self {
        Error(
            "XLA/PJRT runtime not available in this offline build (stub crate); \
             clustering runs on the native engine"
                .to_string(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

/// A compiled executable (unreachable through the stub client).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// A device buffer (unreachable through the stub client).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Host-side literal (tensor) value.
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable())
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal)> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().expect("stub must fail");
        assert!(e.to_string().contains("native engine"));
    }
}
