//! Helpers shared by the integration suites (`coordinator_e2e`,
//! `pipeline_e2e`, `router_e2e`): observation extraction, plus a re-export of the
//! library's wire encoder so a wire-format change cannot leave one suite
//! silently testing a stale encoding.

pub use rf_compress::coordinator::server::values_to_wire;
use rf_compress::coordinator::store::ObsValue;
use rf_compress::data::{Column, Dataset};

/// The observation values of one dataset row, in schema order.
pub fn row_values(ds: &Dataset, row: usize) -> Vec<ObsValue> {
    ds.features
        .iter()
        .map(|f| match &f.column {
            Column::Numeric(v) => ObsValue::Num(v[row]),
            Column::Categorical { values, .. } => ObsValue::Cat(values[row]),
        })
        .collect()
}
