//! Generation-chain integration suite.
//!
//! Three pillars, matching the chain's three promises
//! (`rust/src/pack/generations.rs`):
//!
//! 1. **Crash safety** — every mutation (first append, delta append,
//!    remove, compact) is driven through every declared [`CrashPoint`];
//!    reopening after the simulated crash must recover exactly the old or
//!    exactly the new generation set (never a mix), sweep every leftover,
//!    and accept a clean retry.
//! 2. **Differential correctness** — random append/replace/remove/compact
//!    schedules read bit-identically to a plain `BTreeMap` oracle at every
//!    step, and a merge-compacted chain is **byte-identical** on disk to a
//!    from-scratch [`PackBuilder`] archive over the same membership.
//! 3. **Typed failure** — corrupt chains (truncated or missing generation
//!    files, duplicate sequence numbers, tombstones for unknown keys)
//!    surface as typed errors from [`PackChain::open`], never panics.

use rf_compress::compress::{CompressOptions, CompressedForest};
use rf_compress::data::synthetic;
use rf_compress::forest::{Forest, ForestParams};
use rf_compress::pack::{compact_chain, CompactMode, PackBuilder, PackChain};
use rf_compress::testing::prop::{forall_cases, Gen};
use rf_compress::testing::CrashPoint;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Train `n` tiny per-user forests once and compress them as one cohort.
fn cohort(n: usize, seed: u64) -> Vec<CompressedForest> {
    let ds = synthetic::iris(41);
    let forests: Vec<Forest> = (0..n)
        .map(|i| Forest::train(&ds, &ForestParams::classification(2), seed + i as u64))
        .collect();
    rf_compress::pack::compress_cohort(&forests, &ds, &CompressOptions::default()).unwrap()
}

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("rfc-chain-suite-{tag}-{}", std::process::id()))
}

fn members(cfs: &[CompressedForest], keys: &[&str]) -> Vec<(String, Arc<[u8]>)> {
    keys.iter().zip(cfs).map(|(k, cf)| (k.to_string(), cf.bytes.clone())).collect()
}

/// The on-disk file name of generation `seq` (mirrors the chain's naming).
fn gen_file(seq: u64) -> String {
    format!("gen-{seq:08}.rfpk")
}

/// Every live key with its extracted (bit-exact) container bytes.
fn snapshot(chain: &PackChain) -> BTreeMap<String, Vec<u8>> {
    let keys: Vec<String> = chain.live_keys().map(String::from).collect();
    keys.into_iter().map(|k| {
        let bytes = chain.extract(&k).unwrap();
        (k, bytes)
    }).collect()
}

/// After a reopen, the directory must hold exactly the manifest plus the
/// referenced generation files — no `.tmp`, no unreferenced `gen-*.rfpk`.
fn assert_no_crash_leftovers(dir: &Path, chain: &PackChain) {
    let referenced: Vec<String> = chain
        .generations()
        .iter()
        .filter(|g| g.archive().is_some())
        .map(|g| gen_file(g.seq))
        .collect();
    for entry in std::fs::read_dir(dir).unwrap().flatten() {
        let name = entry.file_name().into_string().unwrap();
        assert!(
            name == "MANIFEST" || referenced.contains(&name),
            "crash leftover {name:?} survived the reopen sweep"
        );
    }
}

// ---------------------------------------------------------------------------
// 1. crash-injection matrix
// ---------------------------------------------------------------------------

/// Drive one mutation through one crash point and verify all-or-nothing
/// recovery plus a clean retry.
fn crash_case(op: &str, point: CrashPoint, cfs: &[CompressedForest]) {
    let dir = temp_dir(&format!("crash-{op}-{}", point.name()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut chain = PackChain::create(&dir).unwrap();

    // precondition state the op mutates
    match op {
        "first-append" => {}
        "delta-append" | "remove" => {
            chain.append_members(&members(&cfs[..2], &["a", "b"])).unwrap();
        }
        "compact" => {
            chain.append_members(&members(&cfs[..2], &["a", "b"])).unwrap();
            chain.append_members(&members(&cfs[2..4], &["c", "b"])).unwrap();
            chain.remove_members(&["a".to_string()]).unwrap();
        }
        other => unreachable!("{other}"),
    }
    let old_state = snapshot(&chain);
    let old_gens = chain.generation_count();

    // what a *successful* op would leave live
    let pair = |cf: &CompressedForest| -> Vec<u8> { cf.bytes.to_vec() };
    let new_state: BTreeMap<String, Vec<u8>> = match op {
        "first-append" => {
            BTreeMap::from([("a".into(), pair(&cfs[0])), ("b".into(), pair(&cfs[1]))])
        }
        "delta-append" => BTreeMap::from([
            ("a".into(), pair(&cfs[0])),
            ("b".into(), pair(&cfs[3])), // the delta shadows the base's b
            ("c".into(), pair(&cfs[2])),
        ]),
        "remove" => BTreeMap::from([("b".into(), pair(&cfs[1]))]),
        "compact" => old_state.clone(), // compaction changes layout, never content
        other => unreachable!("{other}"),
    };
    let new_gens = match op {
        "first-append" | "compact" => 1,
        "delta-append" | "remove" => 2,
        other => unreachable!("{other}"),
    };

    // arm, mutate, and require the failure to be OUR injected crash —
    // not a genuine bug on the same path
    chain.crash().arm(point);
    let err = match op {
        "first-append" => chain.append_members(&members(&cfs[..2], &["a", "b"])).unwrap_err(),
        "delta-append" => chain.append_members(&members(&cfs[2..4], &["c", "b"])).unwrap_err(),
        "remove" => chain.remove_members(&["a".to_string()]).unwrap_err(),
        "compact" => compact_chain(&mut chain, CompactMode::Merge).unwrap_err(),
        other => unreachable!("{other}"),
    };
    let rendered = format!("{err:#}");
    assert!(
        rendered.contains(&format!("injected crash at {}", point.name())),
        "{op} at {}: unexpected failure {rendered}",
        point.name()
    );

    // recovery: reopen must land on exactly one of the two sets
    let reopened = PackChain::open(&dir)
        .unwrap_or_else(|e| panic!("{op} crashed at {}: reopen failed: {e:#}", point.name()));
    let committed = matches!(point, CrashPoint::PostRename | CrashPoint::PostCleanup);
    let recovered = snapshot(&reopened);
    if committed {
        assert_eq!(
            recovered,
            new_state,
            "{op} at {}: the manifest rename landed, recovery must be the new set",
            point.name()
        );
        assert_eq!(reopened.generation_count(), new_gens, "{op} at {}", point.name());
        match op {
            "remove" => assert_eq!(reopened.tombstone_count(), 1),
            "compact" => assert_eq!(reopened.tombstone_count(), 0),
            _ => {}
        }
    } else {
        assert_eq!(
            recovered,
            old_state,
            "{op} at {}: the commit point was not reached, recovery must be the old set",
            point.name()
        );
        assert_eq!(reopened.generation_count(), old_gens, "{op} at {}", point.name());
    }
    assert_no_crash_leftovers(&dir, &reopened);

    // the one-shot injector is spent: retrying the interrupted op on the
    // recovered chain must succeed and land the new set
    if !committed {
        let mut retry = reopened;
        match op {
            "first-append" => {
                retry.append_members(&members(&cfs[..2], &["a", "b"])).unwrap();
            }
            "delta-append" => {
                retry.append_members(&members(&cfs[2..4], &["c", "b"])).unwrap();
            }
            "remove" => {
                retry.remove_members(&["a".to_string()]).unwrap();
            }
            "compact" => {
                compact_chain(&mut retry, CompactMode::Merge).unwrap();
            }
            other => unreachable!("{other}"),
        }
        assert_eq!(snapshot(&retry), new_state, "{op}: retry after {}", point.name());
        assert_eq!(retry.generation_count(), new_gens);
        assert_no_crash_leftovers(&dir, &retry);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn crash_matrix_every_point_recovers_all_or_nothing() {
    let cfs = cohort(4, 700);
    for op in ["first-append", "delta-append", "remove", "compact"] {
        for point in CrashPoint::ALL {
            crash_case(op, point, &cfs);
        }
    }
}

// ---------------------------------------------------------------------------
// 2. differential property
// ---------------------------------------------------------------------------

/// Candidate key space for the random schedules.
const KEY_SPACE: usize = 10;

/// Chain view == oracle view: same live keys, bit-identical extraction,
/// and every absent key (tombstoned or never appended) stays invisible.
fn check_against_oracle(
    chain: &PackChain,
    oracle: &BTreeMap<String, Arc<[u8]>>,
) -> Result<(), String> {
    let live: Vec<String> = chain.live_keys().map(String::from).collect();
    let want: Vec<String> = oracle.keys().cloned().collect();
    if live != want {
        return Err(format!("live set {live:?} != oracle {want:?}"));
    }
    for (k, bytes) in oracle {
        let got = chain.extract(k).map_err(|e| format!("extract {k:?}: {e:#}"))?;
        if got[..] != bytes[..] {
            return Err(format!("member {k:?} no longer bit-identical"));
        }
    }
    for i in 0..KEY_SPACE {
        let k = format!("user-{i}");
        if !oracle.contains_key(&k) {
            if chain.contains(&k) {
                return Err(format!("absent key {k:?} reported live"));
            }
            if chain.extract(&k).is_ok() {
                return Err(format!("absent key {k:?} extracted"));
            }
        }
    }
    Ok(())
}

#[test]
fn prop_generation_chain_reads_match_rebuilt_pack() {
    // one container pool, trained once; schedules only shuffle membership
    let pool: Vec<Arc<[u8]>> = cohort(6, 720).iter().map(|cf| cf.bytes.clone()).collect();
    static CASE: AtomicU64 = AtomicU64::new(0);

    forall_cases("chain reads == rebuilt pack", 24, &mut |g: &mut Gen| {
        let dir = temp_dir(&format!("prop-{}", CASE.fetch_add(1, Ordering::Relaxed)));
        let _ = std::fs::remove_dir_all(&dir);
        let mut chain = PackChain::create(&dir).map_err(|e| format!("{e:#}"))?;
        let mut oracle: BTreeMap<String, Arc<[u8]>> = BTreeMap::new();

        let ops = g.usize_in(3, 8);
        for _ in 0..ops {
            match g.usize_in(0, 3) {
                // append 1–3 members: fresh keys or replacements. Batches
                // are sorted so a lone uncompacted base generation has the
                // same member order a from-scratch rebuild would.
                0 | 1 => {
                    let n = g.usize_in(1, 3);
                    let mut batch: Vec<(String, Arc<[u8]>)> = Vec::new();
                    for _ in 0..n {
                        let key = format!("user-{}", g.usize_in(0, KEY_SPACE - 1));
                        if batch.iter().any(|(k, _)| *k == key) {
                            continue; // pack keys are unique within a build
                        }
                        let bytes = pool[g.usize_in(0, pool.len() - 1)].clone();
                        batch.push((key, bytes));
                    }
                    batch.sort_by(|a, b| a.0.cmp(&b.0));
                    chain.append_members(&batch).map_err(|e| format!("{e:#}"))?;
                    for (k, b) in batch {
                        oracle.insert(k, b);
                    }
                }
                // tombstone one live member, if any
                2 => {
                    if oracle.is_empty() {
                        continue;
                    }
                    let keys: Vec<String> = oracle.keys().cloned().collect();
                    let key = keys[g.usize_in(0, keys.len() - 1)].clone();
                    chain.remove_members(&[key.clone()]).map_err(|e| format!("{e:#}"))?;
                    oracle.remove(&key);
                }
                // merge-compact mid-schedule
                _ => {
                    compact_chain(&mut chain, CompactMode::Merge)
                        .map_err(|e| format!("{e:#}"))?;
                }
            }
            check_against_oracle(&chain, &oracle)?;
        }

        // final differential: a merge-compacted chain is BYTE-identical on
        // disk to a from-scratch pack of the sorted final membership
        compact_chain(&mut chain, CompactMode::Merge).map_err(|e| format!("{e:#}"))?;
        if oracle.is_empty() {
            if chain.generation_count() != 0 {
                return Err("empty live set must compact to zero generations".into());
            }
        } else {
            if chain.generation_count() != 1 {
                return Err(format!(
                    "compaction left {} generations",
                    chain.generation_count()
                ));
            }
            let mut builder = PackBuilder::new();
            for (k, b) in &oracle {
                builder.add(k, b.clone()).map_err(|e| format!("{e:#}"))?;
            }
            let (want, _) = builder.build().map_err(|e| format!("{e:#}"))?;
            let seq = chain.generations()[0].seq;
            let got = std::fs::read(dir.join(gen_file(seq))).map_err(|e| e.to_string())?;
            if got != want {
                return Err(
                    "compacted chain differs byte-for-byte from the immutable rebuild".into()
                );
            }
        }
        // a cold reopen reproduces the identical view
        let reopened = PackChain::open(&dir).map_err(|e| format!("{e:#}"))?;
        check_against_oracle(&reopened, &oracle)?;
        if chain.tombstone_count() != 0 {
            return Err("compaction must clear every tombstone".into());
        }
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 3. corrupt chains answer typed errors
// ---------------------------------------------------------------------------

/// A healthy two-generation chain to corrupt: base {a, b} + delta {c}.
fn build_template(dir: &Path, cfs: &[CompressedForest]) -> PackChain {
    let _ = std::fs::remove_dir_all(dir);
    let mut chain = PackChain::create(dir).unwrap();
    chain.append_members(&members(&cfs[..2], &["a", "b"])).unwrap();
    chain.append_members(&members(&cfs[2..3], &["c"])).unwrap();
    chain
}

#[test]
fn corrupt_chains_surface_typed_errors_not_panics() {
    let cfs = cohort(3, 760);

    // truncated delta pack: the archive parse fails with generation context
    let dir = temp_dir("corrupt-trunc");
    let chain = build_template(&dir, &cfs);
    let victim = dir.join(gen_file(chain.generations()[1].seq));
    drop(chain);
    let bytes = std::fs::read(&victim).unwrap();
    std::fs::write(&victim, &bytes[..bytes.len() / 2]).unwrap();
    let err = format!("{:#}", PackChain::open(&dir).unwrap_err());
    assert!(err.contains("generation"), "truncated archive: {err}");
    let _ = std::fs::remove_dir_all(&dir);

    // missing generation file: named, typed, no panic
    let dir = temp_dir("corrupt-missing");
    let chain = build_template(&dir, &cfs);
    let victim = dir.join(gen_file(chain.generations()[1].seq));
    drop(chain);
    std::fs::remove_file(&victim).unwrap();
    let err = format!("{:#}", PackChain::open(&dir).unwrap_err());
    assert!(err.contains("missing generation file"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);

    // duplicate sequence numbers in a hand-corrupted manifest
    let dir = temp_dir("corrupt-dupseq");
    build_template(&dir, &cfs);
    std::fs::write(
        dir.join("MANIFEST"),
        "RFPM 1\nnext 3\ngen 1 gen-00000001.rfpk\ngen 1 gen-00000001.rfpk\n",
    )
    .unwrap();
    let err = format!("{:#}", PackChain::open(&dir).unwrap_err());
    assert!(err.contains("duplicate generation sequence"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);

    // a tombstone for a key no generation ever held
    let dir = temp_dir("corrupt-ghost");
    build_template(&dir, &cfs);
    std::fs::write(
        dir.join("MANIFEST"),
        "RFPM 1\nnext 4\ngen 1 gen-00000001.rfpk\ngen 3 - ghost\n",
    )
    .unwrap();
    let err = format!("{:#}", PackChain::open(&dir).unwrap_err());
    assert!(err.contains("ghost") && err.contains("not live"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);

    // no manifest at all (not a chain directory)
    let dir = temp_dir("corrupt-nochain");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let err = format!("{:#}", PackChain::open(&dir).unwrap_err());
    assert!(err.contains("reading chain manifest"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}
