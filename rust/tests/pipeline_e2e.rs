//! End-to-end tests of per-connection request pipelining (`PIPE`):
//! out-of-order reply arrival, in-flight cap backpressure, typed timeouts,
//! drain-then-close on QUIT/shutdown, and the permutation property
//! (pipelined replies carry exactly the payloads serial replies would).
//!
//! The wire protocol under test is specified in `rust/PROTOCOL.md`.

mod common;

use common::{row_values, values_to_wire};
use rf_compress::compress::{CompressOptions, CompressedForest};
use rf_compress::coordinator::server::{Client, PipeReply, Server, ServerConfig};
use rf_compress::coordinator::store::ModelStore;
use rf_compress::coordinator::Coordinator;
use rf_compress::data::synthetic;
use std::sync::Arc;
use std::time::Duration;

#[test]
fn pipelined_replies_arrive_out_of_order_with_matching_ids() {
    // one connection, two models: "slow" is a big forest mounted through a
    // pack archive and never yet loaded (answering pays the pack load + a
    // large batch decode), "fast" is a tiny resident one. All slow
    // requests are issued BEFORE any fast request; pipelining must let the
    // fast replies overtake.
    let ds = synthetic::iris(41);
    let mut coord = Coordinator::native_only();
    let (slow_forest, slow_cf, _) =
        coord.train_and_compress(&ds, 192, 21, &CompressOptions::default()).unwrap();
    let (fast_forest, fast_cf, _) =
        coord.train_and_compress(&ds, 2, 22, &CompressOptions::default()).unwrap();
    let mut builder = rf_compress::pack::PackBuilder::new();
    builder.add("slow", slow_cf.bytes.clone()).unwrap();
    let (pack_bytes, _) = builder.build().unwrap();
    let pack = Arc::new(rf_compress::pack::PackArchive::from_bytes(pack_bytes).unwrap());
    let store = Arc::new(ModelStore::new());
    store.attach_pack(&pack).unwrap();
    store.insert("fast", &fast_cf).unwrap();
    assert!(store.is_packed("slow"), "slow model starts unloaded in its pack");
    let server = Server::start(store.clone(), 0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();

    const N: usize = 24; // per model, well under one batch
    for i in 0..N {
        let wire = values_to_wire(&row_values(&ds, i));
        client.pipe_predict(i as u64, "slow", &wire).unwrap();
    }
    for i in 0..N {
        let wire = values_to_wire(&row_values(&ds, i));
        client.pipe_predict((N + i) as u64, "fast", &wire).unwrap();
    }
    let replies = client.collect_pipelined(2 * N).unwrap();

    // every id answered exactly once, with the payload its forest predicts
    let mut seen = vec![false; 2 * N];
    for r in &replies {
        match r {
            PipeReply::Ok { id, value } => {
                let id = *id as usize;
                assert!(!seen[id], "id {id} answered twice");
                seen[id] = true;
                let (forest, row) =
                    if id < N { (&slow_forest, id) } else { (&fast_forest, id - N) };
                assert_eq!(
                    *value,
                    format!("{}", forest.predict_class(&ds, row)),
                    "id {id}: wrong payload"
                );
            }
            PipeReply::Err { id, message } => panic!("id {id:?} failed: {message}"),
        }
    }
    assert!(seen.iter().all(|&s| s));

    // out of order: some fast reply (issued later) must arrive before the
    // last slow reply — i.e. the reply stream is NOT the issue order
    let first_fast = replies.iter().position(|r| r.id().unwrap() >= N as u64).unwrap();
    let last_slow = replies
        .iter()
        .rposition(|r| r.id().unwrap() < N as u64)
        .expect("slow replies present");
    assert!(
        first_fast < last_slow,
        "pipelining must let fast replies overtake the slow batch \
         (first fast at {first_fast}, last slow at {last_slow})"
    );
    let issue_order: Vec<u64> = (0..2 * N as u64).collect();
    let arrival: Vec<u64> = replies.iter().map(|r| r.id().unwrap()).collect();
    assert_ne!(arrival, issue_order, "replies must not be head-of-line blocked");

    // the slow model's first request went through the pack-load path
    let stats = client.request("STATS").unwrap();
    assert!(stats.contains("pack_loads=1"), "{stats}");
    server.stop();
}

#[test]
fn inflight_cap_rejects_with_err_busy() {
    let ds = synthetic::iris(42);
    let mut coord = Coordinator::native_only();
    let (_, cf, _) = coord.train_and_compress(&ds, 3, 23, &CompressOptions::default()).unwrap();
    let store = Arc::new(ModelStore::new());
    store.insert("m", &cf).unwrap();
    let cfg = ServerConfig { inflight_cap: 1, ..ServerConfig::default() };
    let server = Server::start_with(store.clone(), 0, cfg).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();

    // eight requests in one TCP write against cap 1: the reader admits the
    // first and rejects the rest while the 2 ms batch window still holds
    // its reply. The asserts are deliberately order-loose: for `busy` to
    // come back EMPTY the reader would have to stall longer than a full
    // batch window between every consecutive pair of lines — seven times
    // in a row — so "at least one rejection" is robust on a loaded CI box.
    const BURST: usize = 8;
    let wire = values_to_wire(&row_values(&ds, 0));
    let burst: String = (0..BURST)
        .map(|id| format!("PIPE {id} PREDICT m {wire}"))
        .collect::<Vec<_>>()
        .join("\n");
    client.send(&burst).unwrap();
    let replies = client.collect_pipelined(BURST).unwrap();
    let busy: Vec<u64> = replies
        .iter()
        .filter_map(|r| match r {
            PipeReply::Err { id, message } if message == "busy" => Some(id.unwrap()),
            _ => None,
        })
        .collect();
    let ok: Vec<u64> = replies
        .iter()
        .filter_map(|r| match r {
            PipeReply::Ok { id, .. } => Some(*id),
            _ => None,
        })
        .collect();
    assert!(ok.contains(&0), "the first request fits the cap: {replies:?}");
    assert!(!busy.is_empty(), "the burst past the cap answers ERR busy: {replies:?}");
    assert_eq!(ok.len() + busy.len(), BURST, "{replies:?}");

    // the rejections are counted and the gauge drains back to zero
    let stats = client.request("STATS").unwrap();
    assert!(
        stats.contains(&format!("rejected_busy={}", busy.len())),
        "{stats} (busy: {busy:?})"
    );
    assert!(stats.contains("inflight=0"), "{stats}");
    // the connection survives rejection: the next pipelined request works
    client.pipe_predict(9, "m", &wire).unwrap();
    assert!(matches!(
        client.recv_pipelined().unwrap(),
        PipeReply::Ok { id: 9, .. }
    ));
    server.stop();
}

#[test]
fn zero_timeout_answers_typed_error_and_keeps_the_connection() {
    // a big forest makes the answer path slow (≥ the 2 ms batch window +
    // a 16-row full per-tree decode), so a zero request timeout reliably
    // expires every request long before its batch could answer it
    let ds = synthetic::iris(43);
    let mut coord = Coordinator::native_only();
    let (_, cf, _) =
        coord.train_and_compress(&ds, 192, 24, &CompressOptions::default()).unwrap();
    let store = Arc::new(ModelStore::new());
    store.insert("m", &cf).unwrap();
    let cfg = ServerConfig { request_timeout: Duration::ZERO, ..ServerConfig::default() };
    let server = Server::start_with(store.clone(), 0, cfg).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();

    let wire = values_to_wire(&row_values(&ds, 0));
    // serial: a typed `ERR timeout` line, not a dropped connection
    let reply = client.request(&format!("PREDICT m {wire}")).unwrap();
    assert_eq!(reply, "ERR timeout");
    // pipelined: every id of a burst comes back in its own typed line,
    // and the late real replies are dropped, never answered twice
    const N: u64 = 16;
    for id in 0..N {
        client.pipe_predict(id, "m", &wire).unwrap();
    }
    let replies = client.collect_pipelined(N as usize).unwrap();
    let mut ids: Vec<u64> = replies
        .iter()
        .map(|r| match r {
            PipeReply::Err { id, message } if message == "timeout" => id.unwrap(),
            other => panic!("expected ERR timeout id=<n>, got {other:?}"),
        })
        .collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..N).collect::<Vec<_>>());
    // the connection is still alive and the counters moved
    let list = client.request("LIST").unwrap();
    assert!(list.starts_with("OK"), "{list}");
    let stats = client.request("STATS").unwrap();
    let timeouts: u64 = stats
        .split_whitespace()
        .find_map(|tok| tok.strip_prefix("timeouts="))
        .expect("STATS carries timeouts=")
        .parse()
        .unwrap();
    assert!(timeouts >= N + 1, "{stats}");
    assert!(stats.contains("inflight=0"), "expired ids drain the gauge: {stats}");
    server.stop();
}

#[test]
fn quit_drains_outstanding_replies_before_closing() {
    let ds = synthetic::iris(44);
    let mut coord = Coordinator::native_only();
    let (forest, cf, _) =
        coord.train_and_compress(&ds, 3, 25, &CompressOptions::default()).unwrap();
    let store = Arc::new(ModelStore::new());
    store.insert("m", &cf).unwrap();
    let server = Server::start(store.clone(), 0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();

    // a burst of requests immediately followed by QUIT: the writer must
    // drain every reply still in the outbox (or in a batcher) first
    const N: usize = 8;
    for id in 0..N as u64 {
        let wire = values_to_wire(&row_values(&ds, id as usize));
        client.pipe_predict(id, "m", &wire).unwrap();
    }
    client.send("QUIT").unwrap();
    let replies = client.collect_pipelined(N).unwrap();
    let mut ids: Vec<u64> = replies.iter().map(|r| r.id().unwrap()).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..N as u64).collect::<Vec<_>>(), "all in-flight ids answered");
    for r in &replies {
        let PipeReply::Ok { id, value } = r else { panic!("{r:?}") };
        assert_eq!(*value, format!("{}", forest.predict_class(&ds, *id as usize)));
    }
    // ...and only then does the connection close
    assert_eq!(client.recv().unwrap(), "", "EOF after the drain");
    server.stop();
}

#[test]
fn shutdown_with_inflight_replies_neither_hangs_nor_panics() {
    let ds = synthetic::iris(45);
    let mut coord = Coordinator::native_only();
    let (_, cf, _) = coord.train_and_compress(&ds, 3, 26, &CompressOptions::default()).unwrap();
    let store = Arc::new(ModelStore::new());
    store.insert("m", &cf).unwrap();
    let server = Server::start(store.clone(), 0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    for id in 0..4u64 {
        let wire = values_to_wire(&row_values(&ds, id as usize));
        client.pipe_predict(id, "m", &wire).unwrap();
    }
    // stop the server with the burst still in flight; the connection must
    // wind down (replies, errors, or EOF) without hanging this test
    server.stop();
    for _ in 0..4 {
        match client.recv() {
            Ok(line) if line.is_empty() => break, // EOF: connection closed
            Ok(_) => {}                           // a drained reply or error
            Err(_) => break,                      // reset mid-shutdown
        }
    }
}

#[test]
fn pipelined_list_and_stats_interleave_with_predictions() {
    // LIST and STATS ride the PIPE path: interleaved with predictions on
    // one connection, every id comes back exactly once, the LIST payload
    // names the resident models, and the STATS payload carries the same
    // counter keys as the serial reply
    let ds = synthetic::iris(46);
    let mut coord = Coordinator::native_only();
    let (forest, cf, _) =
        coord.train_and_compress(&ds, 3, 27, &CompressOptions::default()).unwrap();
    let store = Arc::new(ModelStore::new());
    store.insert("m", &cf).unwrap();
    let server = Server::start(store.clone(), 0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();

    let wire = values_to_wire(&row_values(&ds, 0));
    client.pipe_predict(0, "m", &wire).unwrap();
    client.send("PIPE 1 LIST").unwrap();
    client.pipe_predict(2, "m", &wire).unwrap();
    client.send("PIPE 3 STATS").unwrap();
    let replies = client.collect_pipelined(4).unwrap();
    let mut by_id: Vec<Option<String>> = vec![None; 4];
    for r in replies {
        let PipeReply::Ok { id, value } = r else { panic!("{r:?}") };
        assert!(by_id[id as usize].replace(value).is_none(), "id {id} answered twice");
    }
    let expect = format!("{}", forest.predict_class(&ds, 0));
    assert_eq!(by_id[0].as_deref(), Some(expect.as_str()));
    assert_eq!(by_id[2].as_deref(), Some(expect.as_str()));
    assert_eq!(by_id[1].as_deref(), Some("m"), "pipelined LIST names the models");
    let stats = by_id[3].as_ref().unwrap();
    for key in ["requests=", "inflight=", "timeouts="] {
        assert!(stats.contains(key), "pipelined STATS carries {key}: {stats}");
    }
    // a pipelined id may be reused once answered, and unknown PIPE verbs
    // answer a typed error that names the supported set
    client.send("PIPE 1 LIST").unwrap();
    assert_eq!(client.recv_pipelined().unwrap().id(), Some(1));
    client.send("PIPE 7 BYTES").unwrap();
    let r = client.recv_pipelined().unwrap();
    let PipeReply::Err { id, message } = r else { panic!("{r:?}") };
    assert_eq!(id, Some(7));
    assert!(message.contains("LIST") && message.contains("STATS"), "{message}");
    server.stop();
}

#[test]
fn prop_pipelined_replies_are_a_permutation_of_serial() {
    use rf_compress::forest::{Forest, ForestParams};
    use rf_compress::testing::prop::{forall_cases, Gen};

    // over random schemas and interleavings: issuing N requests pipelined
    // yields exactly the payloads the serial protocol yields for the same
    // (model, row) pairs — pipelining may reorder replies, never change or
    // drop them
    forall_cases("pipelined == permutation of serial", 8, &mut |g: &mut Gen| {
        let n_rows = g.usize_in(12, 32);
        let numeric = g.usize_in(0, 3);
        let categorical = g.usize_in(if numeric == 0 { 1 } else { 0 }, 2);
        let ds = g.dataset(n_rows, numeric, categorical, true);
        let n_models = g.usize_in(1, 3);
        let store = Arc::new(ModelStore::new());
        for m in 0..n_models {
            let params = ForestParams::classification(g.usize_in(1, 5));
            let forest = Forest::train(&ds, &params, g.u64_in(1, 1 << 40));
            let cf = CompressedForest::compress(&forest, &ds, &CompressOptions::default())
                .map_err(|e| e.to_string())?;
            store.insert(&format!("m{m}"), &cf).map_err(|e| e.to_string())?;
        }
        let server = Server::start(store.clone(), 0).map_err(|e| e.to_string())?;
        let mut client = Client::connect(server.addr()).map_err(|e| e.to_string())?;

        let n_req = g.usize_in(2, 24);
        let plan: Vec<(String, usize)> = (0..n_req)
            .map(|_| {
                (format!("m{}", g.usize_in(0, n_models - 1)), g.usize_in(0, n_rows - 1))
            })
            .collect();
        // serial ground truth, in issue order
        let serial: Vec<String> = plan
            .iter()
            .map(|(model, row)| {
                let wire = values_to_wire(&row_values(&ds, *row));
                client.request(&format!("PREDICT {model} {wire}")).map_err(|e| e.to_string())
            })
            .collect::<Result<_, String>>()?;
        // the same plan, pipelined on the same connection
        for (id, (model, row)) in plan.iter().enumerate() {
            let wire = values_to_wire(&row_values(&ds, *row));
            client.pipe_predict(id as u64, model, &wire).map_err(|e| e.to_string())?;
        }
        let replies = client.collect_pipelined(n_req).map_err(|e| e.to_string())?;
        if replies.len() != n_req {
            return Err(format!("expected {n_req} replies, got {}", replies.len()));
        }
        let mut by_id: Vec<Option<String>> = vec![None; n_req];
        for r in replies {
            let PipeReply::Ok { id, value } = r else {
                return Err(format!("pipelined request failed: {r:?}"));
            };
            let slot = &mut by_id[id as usize];
            if slot.is_some() {
                return Err(format!("id {id} answered twice"));
            }
            *slot = Some(value);
        }
        for (id, (serial_reply, pipe_value)) in serial.iter().zip(&by_id).enumerate() {
            let pipe_value =
                pipe_value.as_ref().ok_or_else(|| format!("id {id} unanswered"))?;
            let expect = serial_reply
                .strip_prefix("OK ")
                .ok_or_else(|| format!("serial request {id} failed: {serial_reply}"))?;
            if pipe_value != expect {
                return Err(format!(
                    "id {id}: pipelined {pipe_value:?} != serial {expect:?}"
                ));
            }
        }
        server.stop();
        Ok(())
    });
}
