//! Property-based tests over the codec substrates and the compression
//! invariants (in-tree `testing::prop` framework; set `RF_PROP_CASES` to
//! raise the case count).

use rf_compress::coding::arith::{self, FreqModel};
use rf_compress::coding::bitio::{BitReader, BitWriter};
use rf_compress::coding::entropy;
use rf_compress::coding::f64pack;
use rf_compress::coding::huffman::HuffmanCode;
use rf_compress::coding::lz;
use rf_compress::compress::{CompressOptions, CompressedForest};
use rf_compress::data::{Column, Dataset, Feature, Target};
use rf_compress::forest::{Forest, ForestParams, TreeParams};
use rf_compress::testing::prop::{forall, Gen};

#[test]
fn prop_huffman_roundtrip_any_distribution() {
    forall("huffman roundtrip", |g: &mut Gen| {
        let alpha = g.usize_in(1, 200);
        let counts = g.counts(alpha, 1000, 0.4);
        let weights: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
        let code = HuffmanCode::from_weights(&weights).map_err(|e| e.to_string())?;
        // sequence drawn only from symbols with weight > 0
        let active: Vec<u32> = (0..alpha as u32).filter(|&s| counts[s as usize] > 0).collect();
        let n = g.usize_in(0, 500);
        let seq: Vec<u32> = (0..n).map(|_| active[g.usize_in(0, active.len() - 1)]).collect();
        let mut w = BitWriter::new();
        code.encode_all(&seq, &mut w).map_err(|e| e.to_string())?;
        let bytes = w.into_bytes();
        let out = code
            .decoder()
            .decode_all(&mut BitReader::new(&bytes), seq.len())
            .map_err(|e| e.to_string())?;
        if out != seq {
            return Err("decode mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_huffman_kraft_and_optimality() {
    forall("huffman kraft + H+1 bound", |g: &mut Gen| {
        let alpha = g.usize_in(2, 100);
        let counts = g.counts(alpha, 10_000, 0.3);
        let weights: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
        let code = HuffmanCode::from_weights(&weights).map_err(|e| e.to_string())?;
        let kraft: f64 = code
            .lengths()
            .iter()
            .filter(|&&l| l > 0)
            .map(|&l| 2f64.powi(-(l as i32)))
            .sum();
        if kraft > 1.0 + 1e-9 {
            return Err(format!("kraft {kraft} > 1"));
        }
        let p = entropy::normalize(&counts);
        let h = entropy::entropy_probs(&p);
        let r = code.expected_length(&p);
        if !(r >= h - 1e-9 && r < h + 1.0) {
            return Err(format!("R={r} outside [H, H+1) for H={h}"));
        }
        Ok(())
    });
}

#[test]
fn prop_arith_roundtrip_and_rate() {
    forall("arith roundtrip", |g: &mut Gen| {
        let alpha = g.usize_in(1, 64);
        let counts = g.counts(alpha, 500, 0.5);
        let model = FreqModel::from_freqs(&counts).map_err(|e| e.to_string())?;
        let active: Vec<u32> = (0..alpha as u32).filter(|&s| counts[s as usize] > 0).collect();
        let n = g.usize_in(0, 400);
        let seq: Vec<u32> = (0..n).map(|_| active[g.usize_in(0, active.len() - 1)]).collect();
        let mut w = BitWriter::new();
        arith::encode_sequence(&model, &seq, &mut w).map_err(|e| e.to_string())?;
        let bytes = w.into_bytes();
        let out = arith::decode_sequence(&model, &mut BitReader::new(&bytes), seq.len())
            .map_err(|e| e.to_string())?;
        if out != seq {
            return Err("decode mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_lz_roundtrip_any_bytes() {
    forall("lz roundtrip", |g: &mut Gen| {
        // mix random and repetitive segments
        let mut data = g.bytes(2000);
        let rep = g.bytes(16);
        for _ in 0..g.usize_in(0, 20) {
            data.extend_from_slice(&rep);
        }
        let c = lz::compress_to_bytes(&data);
        let out = lz::decompress_from_bytes(&c).map_err(|e| e.to_string())?;
        if out != data {
            return Err("roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_f64pack_bit_exact() {
    forall("f64pack", |g: &mut Gen| {
        let n = g.usize_in(0, 300);
        let values: Vec<f64> = (0..n)
            .map(|_| {
                let scale = 10f64.powi(g.usize_in(0, 12) as i32 - 6);
                (g.f64_in(-1.0, 1.0)) * scale
            })
            .collect();
        let mut w = BitWriter::new();
        f64pack::write_block(&values, &mut w).map_err(|e| e.to_string())?;
        let bytes = w.into_bytes();
        let out = f64pack::read_block(&mut BitReader::new(&bytes)).map_err(|e| e.to_string())?;
        if out.len() != values.len()
            || out.iter().zip(&values).any(|(a, b)| a.to_bits() != b.to_bits())
        {
            return Err("bit-exactness violated".into());
        }
        Ok(())
    });
}

/// Random dataset generator for the whole-pipeline property.
fn random_dataset(g: &mut Gen) -> Dataset {
    let n = g.usize_in(30, 200);
    let d = g.usize_in(1, 6);
    let mut features = Vec::new();
    for j in 0..d {
        if g.bool(0.6) {
            features.push(Feature {
                name: format!("n{j}"),
                column: Column::Numeric((0..n).map(|_| g.f64_in(-5.0, 5.0)).collect()),
            });
        } else {
            let levels = g.usize_in(2, 8) as u32;
            features.push(Feature {
                name: format!("c{j}"),
                column: Column::Categorical {
                    values: (0..n).map(|_| g.usize_in(0, levels as usize - 1) as u32).collect(),
                    levels,
                },
            });
        }
    }
    let target = if g.bool(0.5) {
        let classes = g.usize_in(2, 4) as u32;
        Target::Classification {
            labels: (0..n).map(|_| g.usize_in(0, classes as usize - 1) as u32).collect(),
            classes,
        }
    } else {
        Target::Regression((0..n).map(|_| g.f64_in(-10.0, 10.0)).collect())
    };
    Dataset { name: "prop".into(), features, target }
}

/// An f64 drawn heavily from the IEEE corner cases (NaN, ±∞, ±0,
/// subnormals) plus wide-dynamic-range ordinary values.
fn special_f64(g: &mut Gen) -> f64 {
    match g.usize_in(0, 9) {
        0 => f64::NAN,
        1 => f64::INFINITY,
        2 => f64::NEG_INFINITY,
        3 => -0.0,
        4 => 0.0,
        5 => 5e-324,  // smallest positive subnormal
        6 => -5e-324,
        7 => f64::MIN_POSITIVE,
        _ => g.f64_in(-1.0, 1.0) * 10f64.powi(g.usize_in(0, 12) as i32 - 6),
    }
}

#[test]
fn prop_lossless_stages_roundtrip_special_floats_bit_exactly() {
    use rf_compress::coding::stage::{BufferList, StageSpec};
    // every lossless stage must invert exactly on arbitrary byte inputs:
    // f64 arrays full of NaN/−0/subnormals, plus a ragged non-multiple-of-8
    // tail to prove the transform stages' tail tolerance
    forall("lossless stage roundtrip", |g: &mut Gen| {
        let pool = [
            StageSpec::Lzss,
            StageSpec::Huffman,
            StageSpec::Arith,
            StageSpec::DeltaU64,
            StageSpec::XorU64,
            StageSpec::ColumnSplit(g.usize_in(2, 16) as u8),
        ];
        let spec = pool[g.usize_in(0, pool.len() - 1)];
        let n = g.usize_in(0, 200);
        let mut bytes = Vec::with_capacity(n * 8 + 7);
        for _ in 0..n {
            bytes.extend_from_slice(&special_f64(g).to_le_bytes());
        }
        bytes.extend(g.bytes(g.usize_in(0, 7)));
        let st = spec.build();
        let enc = st
            .encode(&BufferList::from_single(bytes.clone()))
            .map_err(|e| format!("{}: encode: {e:#}", spec.name()))?;
        let dec = st
            .decode(&enc)
            .map_err(|e| format!("{}: decode: {e:#}", spec.name()))?
            .into_single()
            .map_err(|e| e.to_string())?;
        if dec != bytes {
            return Err(format!("{}: round-trip differs", spec.name()));
        }
        Ok(())
    });
}

#[test]
fn prop_convert_stages_are_idempotent_and_widen_exactly() {
    use rf_compress::coding::stage::{BufferList, StageSpec};
    // lossy converts: decode widens back to f64; f32 semantics are exactly
    // `v as f32`, and converting already-converted values is the identity
    // (round-to-nearest projects onto the target grid and stays there)
    forall("convert stage semantics", |g: &mut Gen| {
        let n = g.usize_in(0, 120);
        // keep magnitudes inside bf16's finite range so encode never
        // overflows (overflow is a separate typed-error test)
        let vals: Vec<f64> = (0..n)
            .map(|_| {
                let v = special_f64(g);
                if v.is_finite() && v.abs() > 1e38 {
                    v.signum()
                } else {
                    v
                }
            })
            .collect();
        let mut bytes = Vec::with_capacity(n * 8);
        for v in &vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for spec in [StageSpec::ConvertF64F32, StageSpec::ConvertF64Bf16] {
            let st = spec.build();
            let enc = st
                .encode(&BufferList::from_single(bytes.clone()))
                .map_err(|e| format!("{}: encode: {e:#}", spec.name()))?;
            let widened = st
                .decode(&enc)
                .map_err(|e| e.to_string())?
                .into_single()
                .map_err(|e| e.to_string())?;
            let dec: Vec<f64> = widened
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            if dec.len() != vals.len() {
                return Err(format!("{}: length changed", spec.name()));
            }
            for (v, d) in vals.iter().zip(&dec) {
                if v.is_nan() {
                    if !d.is_nan() {
                        return Err(format!("{}: NaN decoded as {d}", spec.name()));
                    }
                } else if spec == StageSpec::ConvertF64F32
                    && d.to_bits() != ((*v as f32) as f64).to_bits()
                {
                    return Err(format!("{}: {v} decoded as {d}", spec.name()));
                }
            }
            // idempotence: re-encoding the widened values is bit-identical
            let enc2 = st
                .encode(&BufferList::from_single(widened))
                .map_err(|e| format!("{}: re-encode: {e:#}", spec.name()))?;
            if !enc.iter().eq(enc2.iter()) {
                return Err(format!("{}: convert is not idempotent", spec.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_any_lossless_chain_keeps_containers_bit_exact() {
    use rf_compress::coding::stage::{SectionChains, StageSpec};
    use rf_compress::testing::prop::forall_cases;
    // the chain-composition property: ANY composition of lossless stages,
    // assigned independently per section, still round-trips the forest
    // bit-exactly, and the version byte is 2 iff any chain is non-empty
    forall_cases("lossless chain composition", 16, &mut |g: &mut Gen| {
        let rand_chain = |g: &mut Gen| -> Vec<StageSpec> {
            let pool = [
                StageSpec::Lzss,
                StageSpec::Huffman,
                StageSpec::Arith,
                StageSpec::DeltaU64,
                StageSpec::XorU64,
                StageSpec::ColumnSplit(2),
                StageSpec::ColumnSplit(8),
            ];
            (0..g.usize_in(0, 3)).map(|_| pool[g.usize_in(0, pool.len() - 1)]).collect()
        };
        let chains = SectionChains {
            structure: rand_chain(g),
            split_tables: rand_chain(g),
            fit_table: rand_chain(g),
        };
        let ds = random_dataset(g);
        ds.validate().map_err(|e| e.to_string())?;
        let params = if ds.target.is_classification() {
            ForestParams::classification(g.usize_in(1, 4))
        } else {
            ForestParams::regression(g.usize_in(1, 4))
        };
        let forest = Forest::train(&ds, &params, g.rng().next_u64());
        let opts = CompressOptions { chains: chains.clone(), ..Default::default() };
        let cf = CompressedForest::compress(&forest, &ds, &opts).map_err(|e| e.to_string())?;
        let want_version = if chains.is_default() { 1 } else { 2 };
        if cf.bytes[4] != want_version {
            return Err(format!("version byte {} != {want_version}", cf.bytes[4]));
        }
        let restored = cf.decompress().map_err(|e| format!("decompress: {e:#}"))?;
        if !restored.identical(&forest) {
            return Err("chained round-trip differs".into());
        }
        Ok(())
    });
}

#[test]
fn prop_pipeline_lossless_on_random_datasets() {
    // the central invariant: ANY forest on ANY (valid) dataset round-trips
    forall("pipeline lossless", |g: &mut Gen| {
        let ds = random_dataset(g);
        ds.validate().map_err(|e| e.to_string())?;
        let n_trees = g.usize_in(1, 5);
        let params = ForestParams {
            n_trees,
            tree: TreeParams {
                mtry: Some(g.usize_in(1, ds.num_features())),
                min_leaf: g.usize_in(1, 5),
                max_depth: if g.bool(0.3) { g.usize_in(1, 6) as u32 } else { u32::MAX },
            },
            bootstrap: g.bool(0.8),
            workers: 1,
        };
        let forest = Forest::train(&ds, &params, g.rng().next_u64());
        let opts = CompressOptions {
            k_max: g.usize_in(1, 6),
            seed: g.rng().next_u64(),
            ..Default::default()
        };
        let cf = CompressedForest::compress(&forest, &ds, &opts).map_err(|e| e.to_string())?;
        let restored = cf.decompress().map_err(|e| e.to_string())?;
        if !restored.identical(&forest) {
            return Err("round-trip differs".into());
        }
        Ok(())
    });
}

#[test]
fn prop_container_bitflip_never_panics() {
    // corruption robustness: a flipped bit or truncation must produce a
    // clean Err (or, rarely, a *valid* different forest) — never a panic
    forall("container corruption", |g: &mut Gen| {
        let ds = random_dataset(g);
        let params = if ds.target.is_classification() {
            ForestParams::classification(2)
        } else {
            ForestParams::regression(2)
        };
        let forest = Forest::train(&ds, &params, 3);
        let cf = CompressedForest::compress(&forest, &ds, &CompressOptions::default())
            .map_err(|e| e.to_string())?;
        let mut bytes = cf.bytes.to_vec();
        if g.bool(0.5) && !bytes.is_empty() {
            let i = g.usize_in(0, bytes.len() - 1);
            let bit = g.usize_in(0, 7);
            bytes[i] ^= 1 << bit;
        } else {
            let keep = g.usize_in(0, bytes.len());
            bytes.truncate(keep);
        }
        // must not panic; Err is expected, Ok(valid forest) is acceptable
        let _ = CompressedForest::from_bytes(bytes).and_then(|c| c.decompress());
        Ok(())
    });
}

#[test]
fn prop_leaf_only_forests_compress_predict_decompress() {
    use rf_compress::compress::predict::PredictOne;
    use rf_compress::compress::CompressedPredictor;
    // degenerate shape: every tree is a single root leaf (Zaks string "0");
    // the full compress → predict-from-bytes → decompress loop must hold
    forall("leaf-only forests", |g: &mut Gen| {
        let n_rows = g.usize_in(5, 40);
        let numeric = g.usize_in(0, 3);
        let categorical = g.usize_in(usize::from(numeric == 0), 3);
        let classification = g.bool(0.5);
        let ds = g.dataset(n_rows, numeric, categorical, classification);
        ds.validate().map_err(|e| e.to_string())?;
        let forest = g.leaf_only_forest(&ds, g.usize_in(1, 6));
        let cf = CompressedForest::compress(&forest, &ds, &CompressOptions::default())
            .map_err(|e| format!("compress: {e:#}"))?;
        let restored = cf.decompress().map_err(|e| format!("decompress: {e:#}"))?;
        if !restored.identical(&forest) {
            return Err("leaf-only round-trip differs".into());
        }
        let p = CompressedPredictor::new(cf.parse().map_err(|e| e.to_string())?)
            .map_err(|e| format!("predictor: {e:#}"))?;
        for row in 0..n_rows.min(5) {
            let got = p.predict_row(&ds, row).map_err(|e| format!("predict: {e:#}"))?;
            let want = if forest.classification {
                PredictOne::Class(forest.predict_class(&ds, row))
            } else {
                PredictOne::Value(forest.predict_regression(&ds, row))
            };
            if got != want {
                return Err(format!("row {row}: {got:?} != {want:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_single_tree_all_categorical_pipeline() {
    use rf_compress::compress::CompressedPredictor;
    // single-tree forests over all-categorical schemas: trained (not
    // synthetic) trees, batch prediction from the compressed bytes must
    // match the original forest exactly
    forall("single-tree all-categorical", |g: &mut Gen| {
        let classification = g.bool(0.5);
        let ds = g.dataset(g.usize_in(20, 80), 0, g.usize_in(1, 4), classification);
        ds.validate().map_err(|e| e.to_string())?;
        let params = if classification {
            ForestParams::classification(1)
        } else {
            ForestParams::regression(1)
        };
        let forest = Forest::train(&ds, &params, g.rng().next_u64());
        let cf = CompressedForest::compress(&forest, &ds, &CompressOptions::default())
            .map_err(|e| format!("compress: {e:#}"))?;
        let restored = cf.decompress().map_err(|e| format!("decompress: {e:#}"))?;
        if !restored.identical(&forest) {
            return Err("single-tree round-trip differs".into());
        }
        let p = CompressedPredictor::new(cf.parse().map_err(|e| e.to_string())?)
            .map_err(|e| format!("predictor: {e:#}"))?;
        let batch = p.predict_all(&ds).map_err(|e| format!("batch: {e:#}"))?;
        if batch != forest.predict_all(&ds) {
            return Err("batch predictions differ from the original forest".into());
        }
        Ok(())
    });
}

/// Compare a batch prediction against the per-row prefix decode, demanding
/// bit-identity (classes equal; regression values equal by bit pattern).
fn batch_matches_prefix_decode(
    p: &rf_compress::compress::CompressedPredictor,
    ds: &Dataset,
    batch: &rf_compress::forest::forest::Predictions,
    label: &str,
) -> Result<(), String> {
    use rf_compress::compress::predict::PredictOne;
    use rf_compress::forest::forest::Predictions;
    for row in 0..ds.num_rows() {
        let one = p.predict_row(ds, row).map_err(|e| format!("{label} row {row}: {e:#}"))?;
        match (batch, one) {
            (Predictions::Classes(cs), PredictOne::Class(c)) => {
                if cs[row] != c {
                    return Err(format!("{label} row {row}: batch {} != prefix {c}", cs[row]));
                }
            }
            (Predictions::Values(vs), PredictOne::Value(v)) => {
                if vs[row].to_bits() != v.to_bits() {
                    return Err(format!(
                        "{label} row {row}: batch {} not bit-identical to prefix {v}",
                        vs[row]
                    ));
                }
            }
            _ => return Err(format!("{label} row {row}: prediction kind mismatch")),
        }
    }
    Ok(())
}

#[test]
fn prop_flat_engine_bit_identical_to_prefix_decode() {
    use rf_compress::compress::CompressedPredictor;
    // the flat-tree batch engine must agree with the per-row prefix decode
    // bit-for-bit on every degenerate shape, at every worker count (both
    // parallelism axes get exercised: 8 workers over ≤6 trees forces the
    // row axis; 1–2 workers over several trees takes the tree axis)
    forall("flat engine == prefix decode", |g: &mut Gen| {
        let mode = g.usize_in(0, 3);
        let classification = g.bool(0.5);
        let (ds, forest, label) = match mode {
            0 => {
                // leaf-only forest (every tree a single root leaf)
                let numeric = g.usize_in(0, 2);
                let categorical = g.usize_in(usize::from(numeric == 0), 2);
                let ds = g.dataset(g.usize_in(5, 40), numeric, categorical, classification);
                let f = g.leaf_only_forest(&ds, g.usize_in(1, 6));
                (ds, f, "leaf-only")
            }
            1 => {
                // single-tree forest
                let numeric = g.usize_in(0, 2);
                let categorical = g.usize_in(usize::from(numeric == 0), 3);
                let ds = g.dataset(g.usize_in(20, 60), numeric, categorical, classification);
                let params = if classification {
                    ForestParams::classification(1)
                } else {
                    ForestParams::regression(1)
                };
                let f = Forest::train(&ds, &params, g.rng().next_u64());
                (ds, f, "single-tree")
            }
            2 => {
                // all-categorical schema
                let ds = g.dataset(g.usize_in(20, 60), 0, g.usize_in(1, 4), classification);
                let params = if classification {
                    ForestParams::classification(g.usize_in(2, 5))
                } else {
                    ForestParams::regression(g.usize_in(2, 5))
                };
                let f = Forest::train(&ds, &params, g.rng().next_u64());
                (ds, f, "all-categorical")
            }
            _ => {
                // general mixed-schema forest
                let ds = random_dataset(g);
                let params = if ds.target.is_classification() {
                    ForestParams::classification(g.usize_in(1, 6))
                } else {
                    ForestParams::regression(g.usize_in(1, 6))
                };
                let f = Forest::train(&ds, &params, g.rng().next_u64());
                (ds, f, "mixed")
            }
        };
        ds.validate().map_err(|e| e.to_string())?;
        let cf = CompressedForest::compress(&forest, &ds, &CompressOptions::default())
            .map_err(|e| format!("{label} compress: {e:#}"))?;
        let p = CompressedPredictor::new(cf.parse().map_err(|e| e.to_string())?)
            .map_err(|e| format!("{label} predictor: {e:#}"))?;
        let baseline = p
            .predict_all_baseline(&ds)
            .map_err(|e| format!("{label} baseline: {e:#}"))?;
        for workers in [1usize, 2, 8] {
            let batch = p
                .predict_all_workers(&ds, workers)
                .map_err(|e| format!("{label} {workers}w: {e:#}"))?;
            if batch != baseline {
                return Err(format!("{label} {workers}w: flat engine != re-decode baseline"));
            }
            batch_matches_prefix_decode(&p, &ds, &batch, &format!("{label} {workers}w"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_plan_cache_transparent_under_any_budget() {
    use rf_compress::compress::{CompressedPredictor, PlanCache};
    use std::sync::Arc;
    // a plan cache of ANY byte budget (including one that fits nothing, or
    // evicts mid-sequence) must never change predictions
    forall("plan cache transparent", |g: &mut Gen| {
        let ds = random_dataset(g);
        let params = if ds.target.is_classification() {
            ForestParams::classification(g.usize_in(1, 5))
        } else {
            ForestParams::regression(g.usize_in(1, 5))
        };
        let forest = Forest::train(&ds, &params, g.rng().next_u64());
        let cf = CompressedForest::compress(&forest, &ds, &CompressOptions::default())
            .map_err(|e| e.to_string())?;
        let plain = CompressedPredictor::new(cf.parse().map_err(|e| e.to_string())?)
            .map_err(|e| e.to_string())?;
        let expect = plain.predict_all(&ds).map_err(|e| e.to_string())?;
        let budget = match g.usize_in(0, 2) {
            0 => 1,                   // caches nothing
            1 => g.u64_in(64, 4096),  // evicts under churn
            _ => u64::MAX,            // caches everything
        };
        let cache = Arc::new(PlanCache::new(budget));
        let cached = CompressedPredictor::new(cf.parse().map_err(|e| e.to_string())?)
            .map_err(|e| e.to_string())?
            .with_plan_cache(cache.clone());
        for round in 0..3 {
            let got = cached.predict_all(&ds).map_err(|e| e.to_string())?;
            if got != expect {
                return Err(format!("round {round} diverged under budget {budget}"));
            }
        }
        if cache.resident_bytes() > budget {
            return Err("cache exceeded its byte budget".into());
        }
        Ok(())
    });
}

#[test]
fn prop_kl_clustering_objective_nonincreasing_in_k() {
    use rf_compress::cluster::kmeans::{cluster_k, NativeEngine};
    forall("kmeans objective monotone", |g: &mut Gen| {
        let m = g.usize_in(2, 20);
        let b = g.usize_in(2, 12);
        let mut p = Vec::with_capacity(m * b);
        for _ in 0..m {
            let row = g.probs(b, 0.3);
            p.extend(row);
        }
        let w: Vec<f64> = (0..m).map(|_| g.f64_in(1.0, 100.0)).collect();
        let mut eng = NativeEngine;
        let mut prev = f64::INFINITY;
        for k in 1..=m.min(5) {
            let c = cluster_k(&p, &w, m, b, k, 42, &mut eng).map_err(|e| e.to_string())?;
            if c.data_bits > prev + 1e-6 {
                return Err(format!("k={k}: {} > {prev}", c.data_bits));
            }
            prev = c.data_bits;
        }
        Ok(())
    });
}

#[test]
fn prop_spill_reload_round_trip_is_transparent() {
    // Tier transitions must be invisible to callers: for any model, the
    // answers from a Resident store, the same store after Spilled → reloaded,
    // and a fresh parse of the original bytes are identical (bit-identical
    // for regression fits). Exercised across random schemas and both target
    // kinds; ~12 cases keep the disk traffic reasonable for tier-1.
    use rf_compress::compress::predict::PredictOne;
    use rf_compress::coordinator::store::{ModelStore, ObsValue};
    use rf_compress::testing::prop::forall_cases;
    use std::sync::atomic::{AtomicU64, Ordering};

    static CASE: AtomicU64 = AtomicU64::new(0);
    forall_cases("spill round trip", 12, &mut |g: &mut Gen| {
        let n_rows = g.usize_in(12, 48);
        let numeric = g.usize_in(0, 3);
        let categorical = g.usize_in(if numeric == 0 { 1 } else { 0 }, 2);
        let classification = g.bool(0.5);
        let ds = g.dataset(n_rows, numeric, categorical, classification);
        let params = if classification {
            ForestParams::classification(g.usize_in(1, 4))
        } else {
            ForestParams::regression(g.usize_in(1, 4))
        };
        let forest = Forest::train(&ds, &params, g.u64_in(1, 1 << 40));
        let cf = CompressedForest::compress(&forest, &ds, &CompressOptions::default())
            .map_err(|e| e.to_string())?;

        let dir = std::env::temp_dir().join(format!(
            "rfc-prop-spill-{}-{}",
            std::process::id(),
            CASE.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = ModelStore::with_budget(2 * cf.total_bytes().max(1))
            .spill_dir(&dir)
            .predict_workers(g.usize_in(1, 8));
        store.insert("m", &cf).map_err(|e| e.to_string())?;

        let rows: Vec<Vec<ObsValue>> = (0..n_rows)
            .map(|r| {
                ds.features
                    .iter()
                    .map(|f| match &f.column {
                        Column::Numeric(v) => ObsValue::Num(v[r]),
                        Column::Categorical { values, .. } => ObsValue::Cat(values[r]),
                    })
                    .collect()
            })
            .collect();
        let resident = store.predict_batch("m", &rows).map_err(|e| e.to_string())?;
        if !store.spill("m").map_err(|e| e.to_string())? {
            return Err("spill refused on a resident model".into());
        }
        let reloaded = store.predict_batch("m", &rows).map_err(|e| e.to_string())?;
        for (i, (a, b)) in resident.iter().zip(&reloaded).enumerate() {
            let same = match (a, b) {
                (PredictOne::Class(x), PredictOne::Class(y)) => x == y,
                (PredictOne::Value(x), PredictOne::Value(y)) => x.to_bits() == y.to_bits(),
                _ => false,
            };
            if !same {
                return Err(format!("row {i}: resident {a:?} != reloaded {b:?}"));
            }
        }
        // the store's answers match the original forest on every row
        for (i, out) in reloaded.iter().enumerate() {
            let ok = match out {
                PredictOne::Class(c) => *c == forest.predict_class(&ds, i),
                PredictOne::Value(v) => v.to_bits() == forest.predict_regression(&ds, i).to_bits(),
            };
            if !ok {
                return Err(format!("row {i}: store diverges from the forest"));
            }
        }
        drop(store);
        if std::fs::read_dir(&dir).map(|d| d.count()).unwrap_or(0) != 0 {
            return Err("spill dir not empty after reload + shutdown".into());
        }
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}

#[test]
fn prop_pack_round_trip_is_bit_identical() {
    // ISSUE-4 acceptance property: build → mmap → extract reproduces every
    // member container bit for bit, over random schemas, member counts
    // 1/2/32, and with/without shared cohort codebooks; parsed members
    // decode to their original forests straight out of the mapping.
    use rf_compress::pack::{compress_cohort, PackArchive, PackBuilder};
    use rf_compress::testing::prop::forall_cases;
    use std::sync::atomic::{AtomicU64, Ordering};

    static CASE: AtomicU64 = AtomicU64::new(0);
    forall_cases("pack round trip", 12, &mut |g: &mut Gen| {
        let n_rows = g.usize_in(12, 40);
        let numeric = g.usize_in(0, 3);
        let categorical = g.usize_in(if numeric == 0 { 1 } else { 0 }, 2);
        let classification = g.bool(0.5);
        let ds = g.dataset(n_rows, numeric, categorical, classification);
        let members = [1usize, 2, 32][g.usize_in(0, 2)];
        let shared = g.bool(0.5);
        let params = if classification {
            ForestParams {
                tree: TreeParams { mtry: None, min_leaf: 2, max_depth: 3 },
                ..ForestParams::classification(g.usize_in(1, 3))
            }
        } else {
            ForestParams {
                tree: TreeParams { mtry: None, min_leaf: 2, max_depth: 3 },
                ..ForestParams::regression(g.usize_in(1, 3))
            }
        };
        let forests: Vec<Forest> = (0..members)
            .map(|i| Forest::train(&ds, &params, g.u64_in(1, 1 << 40) + i as u64))
            .collect();
        let opts = CompressOptions::default();
        // shared mode compresses the cohort against union codebooks (the
        // side sections then dedup); unshared compresses independently
        let containers: Vec<std::sync::Arc<[u8]>> = if shared {
            compress_cohort(&forests, &ds, &opts)
                .map_err(|e| e.to_string())?
                .into_iter()
                .map(|cf| cf.bytes)
                .collect()
        } else {
            forests
                .iter()
                .map(|f| CompressedForest::compress(f, &ds, &opts).map(|cf| cf.bytes))
                .collect::<Result<_, _>>()
                .map_err(|e| e.to_string())?
        };

        let mut builder = PackBuilder::new().shared(shared);
        for (i, bytes) in containers.iter().enumerate() {
            builder.add(&format!("m{i}"), bytes.clone()).map_err(|e| e.to_string())?;
        }
        let path = std::env::temp_dir().join(format!(
            "rfc-prop-pack-{}-{}.rfpk",
            std::process::id(),
            CASE.fetch_add(1, Ordering::Relaxed)
        ));
        builder.write(&path).map_err(|e| e.to_string())?;

        // mmap the archive back and check every member
        let pack = PackArchive::open(&path).map_err(|e| e.to_string())?;
        if pack.member_count() != members {
            return Err(format!("{} members stored, {members} expected", pack.member_count()));
        }
        if shared && members >= 2 && pack.blob_count() == 0 {
            return Err("cohort members must share a side-info blob".into());
        }
        for (i, bytes) in containers.iter().enumerate() {
            let extracted = pack.extract_member(i).map_err(|e| e.to_string())?;
            if extracted[..] != bytes[..] {
                return Err(format!(
                    "member {i}: extraction differs (got {} bytes, want {}, shared={shared})",
                    extracted.len(),
                    bytes.len()
                ));
            }
            let pc = pack.parse_member(i).map_err(|e| e.to_string())?;
            let decoded = rf_compress::compress::pipeline::decompress_container(&pc)
                .map_err(|e| e.to_string())?;
            if !decoded.identical(&forests[i]) {
                return Err(format!("member {i}: packed decode diverges from the forest"));
            }
        }
        std::fs::remove_file(&path).map_err(|e| e.to_string())?;
        Ok(())
    });
}

#[test]
fn prop_loadgen_replay_is_deterministic() {
    use rf_compress::testing::loadgen::{
        generate_trace, hot_tenants, render_trace, LoadgenConfig, Scenario,
    };
    forall("loadgen replay determinism", |g: &mut Gen| {
        let scenario = Scenario::ALL[g.usize_in(0, Scenario::ALL.len() - 1)];
        let tenants = g.usize_in(1, 64);
        let cfg = LoadgenConfig {
            seed: g.u64_in(0, u64::MAX / 2),
            tenants,
            requests: g.usize_in(0, 400),
            rate: g.f64_in(100.0, 50_000.0),
            zipf_s: g.f64_in(0.5, 2.0),
            hot_set: g.usize_in(1, tenants),
            cohort: g.usize_in(1, tenants),
            ..LoadgenConfig::quick(scenario)
        };
        // the replay contract: equal configs render byte-identical traces
        let a = generate_trace(&cfg);
        let b = generate_trace(&cfg);
        if render_trace(&cfg, &a) != render_trace(&cfg, &b) {
            return Err(format!("{scenario:?}: same config rendered two different traces"));
        }
        // well-formedness: monotone schedule, tenants in range
        let mut last = 0u64;
        for r in &a {
            if r.at_us < last {
                return Err(format!("{scenario:?}: schedule went backwards"));
            }
            if r.tenant as usize >= cfg.tenants {
                return Err(format!("{scenario:?}: tenant {} out of range", r.tenant));
            }
            last = r.at_us;
        }
        // the hot set is a stable function of the config too
        if hot_tenants(&cfg) != hot_tenants(&cfg) {
            return Err("hot set must be deterministic".into());
        }
        Ok(())
    });
}
