//! End-to-end coordinator tests: train → compress → store → serve over TCP
//! → predictions from compressed bytes match the original forest.

use rf_compress::compress::predict::PredictOne;
use rf_compress::compress::CompressOptions;
use rf_compress::coordinator::server::{Client, Server};
use rf_compress::coordinator::store::{ModelStore, ObsValue};
use rf_compress::coordinator::Coordinator;
use rf_compress::data::{synthetic, Column, Dataset};
use std::sync::Arc;

fn row_values(ds: &Dataset, row: usize) -> Vec<ObsValue> {
    ds.features
        .iter()
        .map(|f| match &f.column {
            Column::Numeric(v) => ObsValue::Num(v[row]),
            Column::Categorical { values, .. } => ObsValue::Cat(values[row]),
        })
        .collect()
}

fn values_to_wire(values: &[ObsValue]) -> String {
    values
        .iter()
        .map(|v| match v {
            ObsValue::Num(x) => format!("{x}"),
            ObsValue::Cat(c) => format!("c{c}"),
        })
        .collect::<Vec<_>>()
        .join(",")
}

#[test]
fn coordinator_to_server_round_trip() {
    // 1. coordinator trains + compresses two models
    let iris = synthetic::iris(91);
    let wages = synthetic::wages(91);
    let mut coord = Coordinator::native_only();
    let opts = CompressOptions::default();
    let (iris_forest, iris_cf, iris_report) =
        coord.train_and_compress(&iris, 30, 5, &opts).unwrap();
    let (wages_forest, wages_cf, _) = coord.train_and_compress(&wages, 4, 6, &opts).unwrap();
    assert!(
        iris_report.ours_bytes < iris_report.light_bytes,
        "at 30 trees the dictionaries amortize: ours {} vs light {}",
        iris_report.ours_bytes,
        iris_report.light_bytes
    );

    // 2. store them
    let store = Arc::new(ModelStore::new());
    store.insert("iris", &iris_cf).unwrap();
    store.insert("wages", &wages_cf).unwrap();
    assert_eq!(store.len(), 2);

    // 3. serve and query over TCP
    let server = Server::start(store.clone(), 0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();

    let list = client.request("LIST").unwrap();
    assert!(list.starts_with("OK"));
    assert!(list.contains("iris") && list.contains("wages"));

    for row in (0..iris.num_rows()).step_by(29) {
        let wire = values_to_wire(&row_values(&iris, row));
        let reply = client.request(&format!("PREDICT iris {wire}")).unwrap();
        let expect = iris_forest.predict_class(&iris, row);
        assert_eq!(reply, format!("OK {expect}"), "row {row}");
    }
    for row in (0..wages.num_rows()).step_by(101) {
        let wire = values_to_wire(&row_values(&wages, row));
        let reply = client.request(&format!("PREDICT wages {wire}")).unwrap();
        let expect = wages_forest.predict_class(&wages, row);
        assert_eq!(reply, format!("OK {expect}"));
    }

    let stats = client.request("STATS").unwrap();
    assert!(stats.starts_with("OK requests="), "{stats}");
    let bytes = client.request("BYTES").unwrap();
    assert!(bytes.starts_with("OK resident="), "{bytes}");

    // 4. error paths stay connected
    let err = client.request("PREDICT nope 1,2,3,4").unwrap();
    assert!(err.starts_with("ERR"), "{err}");
    let err = client.request("GARBAGE").unwrap();
    assert!(err.starts_with("ERR"), "{err}");

    server.stop();
}

#[test]
fn concurrent_clients_batch_correctly() {
    let ds = synthetic::airfoil_classification(92);
    let mut coord = Coordinator::native_only();
    let (forest, cf, _) =
        coord.train_and_compress(&ds, 5, 7, &CompressOptions::default()).unwrap();
    let store = Arc::new(ModelStore::new());
    store.insert("m", &cf).unwrap();
    let server = Server::start(store.clone(), 0).unwrap();
    let addr = server.addr();

    let rows: Vec<usize> = (0..ds.num_rows()).step_by(97).collect();
    let expected: Vec<u32> = rows.iter().map(|&r| forest.predict_class(&ds, r)).collect();

    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|c| {
                let ds = &ds;
                let rows = &rows;
                let expected = &expected;
                scope.spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    for (i, &row) in rows.iter().enumerate() {
                        if i % 4 != c {
                            continue;
                        }
                        let wire = values_to_wire(&row_values(ds, row));
                        let reply = client.request(&format!("PREDICT m {wire}")).unwrap();
                        assert_eq!(reply, format!("OK {}", expected[i]));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    let stats = store.stats();
    assert!(stats.requests >= rows.len() as u64);
    server.stop();
}

#[test]
fn store_direct_api_matches_forest() {
    let ds = synthetic::naval_classification(93);
    let mut coord = Coordinator::native_only();
    let (forest, cf, report) =
        coord.train_and_compress(&ds, 4, 8, &CompressOptions::default()).unwrap();
    // 4 trees cannot amortize dictionaries; the standard baseline must
    // still lose (light-baseline wins are covered by the Table-2 bench)
    assert!(report.standard_ratio() > 1.0);
    let store = ModelStore::new();
    store.insert("naval", &cf).unwrap();
    for row in (0..ds.num_rows()).step_by(397) {
        let got = store.predict("naval", &row_values(&ds, row)).unwrap();
        assert_eq!(got, PredictOne::Class(forest.predict_class(&ds, row)));
    }
}
