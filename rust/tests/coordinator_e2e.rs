//! End-to-end coordinator tests: train → compress → store → serve over TCP
//! → predictions from compressed bytes match the original forest.

mod common;

use common::{row_values, values_to_wire};
use rf_compress::compress::predict::PredictOne;
use rf_compress::compress::CompressOptions;
use rf_compress::coordinator::server::{Client, PipeReply, Server};
use rf_compress::coordinator::store::{ModelStore, ObsValue};
use rf_compress::coordinator::Coordinator;
use rf_compress::data::{synthetic, Column, Dataset};
use std::sync::Arc;

#[test]
fn coordinator_to_server_round_trip() {
    // 1. coordinator trains + compresses two models
    let iris = synthetic::iris(91);
    let wages = synthetic::wages(91);
    let mut coord = Coordinator::native_only();
    let opts = CompressOptions::default();
    let (iris_forest, iris_cf, iris_report) =
        coord.train_and_compress(&iris, 30, 5, &opts).unwrap();
    let (wages_forest, wages_cf, _) = coord.train_and_compress(&wages, 4, 6, &opts).unwrap();
    assert!(
        iris_report.ours_bytes < iris_report.light_bytes,
        "at 30 trees the dictionaries amortize: ours {} vs light {}",
        iris_report.ours_bytes,
        iris_report.light_bytes
    );

    // 2. store them
    let store = Arc::new(ModelStore::new());
    store.insert("iris", &iris_cf).unwrap();
    store.insert("wages", &wages_cf).unwrap();
    assert_eq!(store.len(), 2);

    // 3. serve and query over TCP
    let server = Server::start(store.clone(), 0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();

    let list = client.request("LIST").unwrap();
    assert!(list.starts_with("OK"));
    assert!(list.contains("iris") && list.contains("wages"));

    for row in (0..iris.num_rows()).step_by(29) {
        let wire = values_to_wire(&row_values(&iris, row));
        let reply = client.request(&format!("PREDICT iris {wire}")).unwrap();
        let expect = iris_forest.predict_class(&iris, row);
        assert_eq!(reply, format!("OK {expect}"), "row {row}");
    }
    for row in (0..wages.num_rows()).step_by(101) {
        let wire = values_to_wire(&row_values(&wages, row));
        let reply = client.request(&format!("PREDICT wages {wire}")).unwrap();
        let expect = wages_forest.predict_class(&wages, row);
        assert_eq!(reply, format!("OK {expect}"));
    }

    let stats = client.request("STATS").unwrap();
    assert!(stats.starts_with("OK requests="), "{stats}");
    let bytes = client.request("BYTES").unwrap();
    assert!(bytes.starts_with("OK resident="), "{bytes}");

    // 4. error paths stay connected
    let err = client.request("PREDICT nope 1,2,3,4").unwrap();
    assert!(err.starts_with("ERR"), "{err}");
    let err = client.request("GARBAGE").unwrap();
    assert!(err.starts_with("ERR"), "{err}");

    server.stop();
}

#[test]
fn concurrent_clients_batch_correctly() {
    let ds = synthetic::airfoil_classification(92);
    let mut coord = Coordinator::native_only();
    let (forest, cf, _) =
        coord.train_and_compress(&ds, 5, 7, &CompressOptions::default()).unwrap();
    let store = Arc::new(ModelStore::new());
    store.insert("m", &cf).unwrap();
    let server = Server::start(store.clone(), 0).unwrap();
    let addr = server.addr();

    let rows: Vec<usize> = (0..ds.num_rows()).step_by(97).collect();
    let expected: Vec<u32> = rows.iter().map(|&r| forest.predict_class(&ds, r)).collect();

    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|c| {
                let ds = &ds;
                let rows = &rows;
                let expected = &expected;
                scope.spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    for (i, &row) in rows.iter().enumerate() {
                        if i % 4 != c {
                            continue;
                        }
                        let wire = values_to_wire(&row_values(ds, row));
                        let reply = client.request(&format!("PREDICT m {wire}")).unwrap();
                        assert_eq!(reply, format!("OK {}", expected[i]));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    let stats = store.stats();
    assert!(stats.requests >= rows.len() as u64);
    server.stop();
}

#[test]
fn eviction_under_budget_over_tcp() {
    // budget for ~2.5 models: the third insert must evict the LRU one, and
    // LIST/BYTES over the wire must reflect the post-eviction store
    let ds = synthetic::iris(94);
    let mut coord = Coordinator::native_only();
    let (_, cf, _) = coord.train_and_compress(&ds, 5, 9, &CompressOptions::default()).unwrap();
    let one = cf.total_bytes();
    let store = Arc::new(ModelStore::with_budget(2 * one + one / 2));
    store.insert("m0", &cf).unwrap();
    store.insert("m1", &cf).unwrap();
    let server = Server::start(store.clone(), 0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();

    // touch m0 over the wire so m1 becomes the LRU victim
    let wire = values_to_wire(&row_values(&ds, 0));
    let reply = client.request(&format!("PREDICT m0 {wire}")).unwrap();
    assert!(reply.starts_with("OK"), "{reply}");

    // insert-past-budget → evicts m1 (never the fresh m2)
    store.insert("m2", &cf).unwrap();
    let list = client.request("LIST").unwrap();
    assert!(list.contains("m0") && list.contains("m2"), "{list}");
    assert!(!list.contains("m1"), "LRU model must be gone: {list}");
    let bytes = client.request("BYTES").unwrap();
    let resident: u64 = bytes
        .split_whitespace()
        .find_map(|tok| tok.strip_prefix("resident="))
        .expect("BYTES reply carries resident=")
        .parse()
        .unwrap();
    assert_eq!(resident, 2 * one, "two models resident after eviction");
    assert!(resident <= store.max_resident_bytes().unwrap());
    assert!(bytes.contains("plans="), "BYTES reports plan residency: {bytes}");

    // the evicted model now errors over the wire; the connection survives
    let reply = client.request(&format!("PREDICT m1 {wire}")).unwrap();
    assert!(reply.starts_with("ERR"), "{reply}");
    let stats = client.request("STATS").unwrap();
    assert!(stats.contains("evictions=1"), "{stats}");
    server.stop();
}

#[test]
fn batcher_queues_reaped_after_model_removal() {
    let ds = synthetic::iris(95);
    let mut coord = Coordinator::native_only();
    let (_, cf, _) = coord.train_and_compress(&ds, 4, 10, &CompressOptions::default()).unwrap();
    let store = Arc::new(ModelStore::new());
    store.insert("m", &cf).unwrap();
    let server = Server::start(store.clone(), 0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();

    let wire = values_to_wire(&row_values(&ds, 0));
    assert!(client.request(&format!("PREDICT m {wire}")).unwrap().starts_with("OK"));
    assert_eq!(server.active_batchers(), 1);

    // a bad model name must not spawn a queue
    assert!(client.request("PREDICT ghost 1,2,3,4").unwrap().starts_with("ERR"));
    assert_eq!(server.active_batchers(), 1, "unknown models spawn no batcher");

    // removing the model retires its batcher on the next idle tick
    assert!(store.remove("m"));
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while server.active_batchers() > 0 && std::time::Instant::now() < deadline {
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    assert_eq!(server.active_batchers(), 0, "dead per-model queue must be reaped");

    // QUIT closes the connection cleanly (empty read on our side)
    assert_eq!(client.request("QUIT").unwrap(), "");
    server.stop();
}

/// Unique spill directory per test (tests run in parallel in one process).
fn temp_spill_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("rfc-e2e-spill-{tag}-{}", std::process::id()))
}

fn spill_file_count(dir: &std::path::Path) -> usize {
    std::fs::read_dir(dir).map(|d| d.count()).unwrap_or(0)
}

#[test]
fn spill_reload_bit_identical_across_workers_and_tiers() {
    // regression dataset so "bit-identical" means f64 bit patterns, not
    // just class labels; checked at worker counts 1/2/8 against a
    // Resident, a Spilled-then-reloaded, and a freshly-parsed model
    let ds = synthetic::airfoil_regression(96);
    let mut coord = Coordinator::native_only();
    let (_, cf, _) = coord.train_and_compress(&ds, 6, 11, &CompressOptions::default()).unwrap();
    let one = cf.total_bytes();
    let rows: Vec<Vec<ObsValue>> = (0..32).map(|r| row_values(&ds, r * 7)).collect();

    let fresh_predictor =
        rf_compress::compress::CompressedPredictor::new(cf.parse().unwrap()).unwrap();
    for workers in [1usize, 2, 8] {
        let dir = temp_spill_dir(&format!("workers{workers}"));
        let _ = std::fs::remove_dir_all(&dir);
        let store = Arc::new(
            ModelStore::with_budget(2 * one).spill_dir(&dir).predict_workers(workers),
        );
        store.insert("m", &cf).unwrap();
        let resident = store.predict_batch("m", &rows).unwrap();
        assert!(store.spill("m").unwrap());
        assert!(store.is_spilled("m"));
        let reloaded = store.predict_batch("m", &rows).unwrap();
        assert!(!store.is_spilled("m"), "the request pulled the model back to RAM");
        for (i, (a, b)) in resident.iter().zip(&reloaded).enumerate() {
            match (a, b) {
                (PredictOne::Value(x), PredictOne::Value(y)) => assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "row {i}, {workers} workers: reload must be bit-identical"
                ),
                _ => panic!("regression values expected"),
            }
        }
        // and both agree bit-exactly with a fresh parse of the original bytes
        match fresh_predictor.predict_all_workers(&row_batch_dataset(&ds, &rows), workers) {
            Ok(rf_compress::forest::forest::Predictions::Values(vs)) => {
                for (i, out) in resident.iter().enumerate() {
                    match out {
                        PredictOne::Value(x) => assert_eq!(x.to_bits(), vs[i].to_bits(), "row {i}"),
                        _ => panic!(),
                    }
                }
            }
            other => panic!("fresh predictor failed: {other:?}"),
        }
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Rebuild a query dataset holding exactly the batch rows (so a fresh
/// predictor can answer the same observations the store answered).
fn row_batch_dataset(ds: &Dataset, rows: &[Vec<ObsValue>]) -> Dataset {
    use rf_compress::data::{Feature, Target};
    let d = ds.features.len();
    let features = (0..d)
        .map(|j| {
            let column = match &ds.features[j].column {
                Column::Numeric(_) => Column::Numeric(
                    rows.iter()
                        .map(|r| match r[j] {
                            ObsValue::Num(x) => x,
                            ObsValue::Cat(_) => panic!("numeric column"),
                        })
                        .collect(),
                ),
                Column::Categorical { levels, .. } => Column::Categorical {
                    values: rows
                        .iter()
                        .map(|r| match r[j] {
                            ObsValue::Cat(c) => c,
                            ObsValue::Num(_) => panic!("categorical column"),
                        })
                        .collect(),
                    levels: *levels,
                },
            };
            Feature { name: ds.features[j].name.clone(), column }
        })
        .collect();
    let target = if ds.target.is_classification() {
        Target::Classification { labels: vec![0; rows.len()], classes: ds.target.num_classes() }
    } else {
        Target::Regression(vec![0.0; rows.len()])
    };
    Dataset { name: "batch".into(), features, target }
}

#[test]
fn spill_tier_serves_over_tcp_with_stats() {
    // budget for ~2.5 models + a spill dir: the third insert spills the LRU
    // model instead of dropping it; the wire still serves it (via reload)
    let ds = synthetic::iris(97);
    let mut coord = Coordinator::native_only();
    let (forest, cf, _) =
        coord.train_and_compress(&ds, 5, 12, &CompressOptions::default()).unwrap();
    let one = cf.total_bytes();
    let dir = temp_spill_dir("tcp");
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(ModelStore::with_budget(2 * one + one / 2).spill_dir(&dir));
    store.insert("m0", &cf).unwrap();
    store.insert("m1", &cf).unwrap();
    let server = Server::start(store.clone(), 0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();

    // touch m0 so m1 is the LRU spill victim
    let wire = values_to_wire(&row_values(&ds, 0));
    assert!(client.request(&format!("PREDICT m0 {wire}")).unwrap().starts_with("OK"));
    store.insert("m2", &cf).unwrap();
    assert!(store.is_spilled("m1"), "LRU model spilled, not dropped");

    // LIST still owns all three; BYTES reports the disk tier
    let list = client.request("LIST").unwrap();
    assert!(list.contains("m0") && list.contains("m1") && list.contains("m2"), "{list}");
    let bytes = client.request("BYTES").unwrap();
    let spilled: u64 = bytes
        .split_whitespace()
        .find_map(|tok| tok.strip_prefix("spilled="))
        .expect("BYTES reply carries spilled=")
        .parse()
        .unwrap();
    assert_eq!(spilled, one, "{bytes}");

    // a PREDICT against the spilled model reloads and answers correctly
    for row in (0..ds.num_rows()).step_by(31) {
        let wire = values_to_wire(&row_values(&ds, row));
        let reply = client.request(&format!("PREDICT m1 {wire}")).unwrap();
        assert_eq!(reply, format!("OK {}", forest.predict_class(&ds, row)), "row {row}");
    }
    assert!(!store.is_spilled("m1"));
    let stats = client.request("STATS").unwrap();
    assert!(stats.contains("spills=") && stats.contains("reloads="), "{stats}");
    let reloads: u64 = stats
        .split_whitespace()
        .find_map(|tok| tok.strip_prefix("reloads="))
        .unwrap()
        .parse()
        .unwrap();
    assert!(reloads >= 1, "{stats}");
    server.stop();
    drop(server);
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn slow_ring_captures_spill_reload_phase_over_tcp() {
    // a spill-reload request is exactly what the SLOW ring exists to
    // explain: with the threshold at 0 every request retains its trace,
    // and the reloading request must carry a nonzero reload phase
    let ds = synthetic::iris(99);
    let mut coord = Coordinator::native_only();
    let (_, cf, _) = coord.train_and_compress(&ds, 5, 14, &CompressOptions::default()).unwrap();
    let one = cf.total_bytes();
    let dir = temp_spill_dir("slowring");
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(
        ModelStore::with_budget(2 * one + one / 2)
            .spill_dir(&dir)
            .slow_threshold_us(0)
            .trace_ring(32),
    );
    store.insert("m0", &cf).unwrap();
    store.insert("m1", &cf).unwrap();
    let server = Server::start(store.clone(), 0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();

    // touch m0 so m1 is the LRU victim, then push m1 out to disk
    let wire = values_to_wire(&row_values(&ds, 0));
    assert!(client.request(&format!("PREDICT m0 {wire}")).unwrap().starts_with("OK"));
    store.insert("m2", &cf).unwrap();
    assert!(store.is_spilled("m1"));

    // this PREDICT pays the reload; its trace must attribute it. The
    // batcher observes the span just after handing the reply back, so the
    // ring can trail the reply by an instant — poll briefly.
    assert!(client.request(&format!("PREDICT m1 {wire}")).unwrap().starts_with("OK"));
    let mut slow = client.request_block("SLOW").unwrap();
    for _ in 0..100 {
        if slow.iter().any(|l| l.contains("model=m1"))
            && slow.iter().any(|l| l.contains("model=m0"))
        {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
        slow = client.request_block("SLOW").unwrap();
    }
    let m1 = slow
        .iter()
        .find(|l| l.contains("model=m1"))
        .unwrap_or_else(|| panic!("no m1 trace in SLOW dump: {slow:?}"));
    let reload_us: u64 = m1
        .split_whitespace()
        .find_map(|t| t.strip_prefix("reload_us="))
        .expect("trace line carries reload_us=")
        .parse()
        .unwrap();
    assert!(reload_us > 0, "the reloading request must show a nonzero reload phase: {m1}");
    // the warm m0 request paid no reload
    let m0 = slow.iter().find(|l| l.contains("model=m0")).expect("m0 trace retained");
    assert!(m0.contains(" reload_us=0 "), "{m0}");
    // SLOW <n> caps the dump at the n most recent traces
    assert_eq!(client.request_block("SLOW 1").unwrap().len(), 1);

    // METRICS exposes typed counters, phase totals, and the histogram
    let metrics = client.request_block("METRICS").unwrap().join("\n");
    assert!(metrics.contains("# TYPE requests counter"), "{metrics}");
    assert!(metrics.contains("# TYPE request_latency_us histogram"), "{metrics}");
    assert!(metrics.contains("request_latency_us_bucket"), "{metrics}");
    assert!(metrics.contains("reloads 1"), "{metrics}");
    // the phase totals include the reload the trace attributed
    let phase_reload: u64 = metrics
        .lines()
        .find_map(|l| l.strip_prefix("phase_reload_us "))
        .expect("phase_reload_us sample present")
        .parse()
        .unwrap();
    assert!(phase_reload >= reload_us, "{metrics}");

    // pipelined METRICS frames the same block under the request id
    client.send("PIPE 11 METRICS").unwrap();
    let piped = client.recv_block().unwrap();
    assert!(piped.iter().any(|l| l.starts_with("# TYPE requests ")), "{piped:?}");

    // STATS now reports histogram quantiles next to the mean
    let stats = client.request("STATS").unwrap();
    let p50: u64 = stats
        .split_whitespace()
        .find_map(|t| t.strip_prefix("p50_us="))
        .expect("STATS carries p50_us=")
        .parse()
        .unwrap();
    let p99: u64 = stats
        .split_whitespace()
        .find_map(|t| t.strip_prefix("p99_us="))
        .expect("STATS carries p99_us=")
        .parse()
        .unwrap();
    // p99 covers the reload request, which certainly took > 0 µs
    assert!(p99 > 0 && p99 >= p50, "{stats}");

    server.stop();
    drop(server);
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn spill_corrupted_file_is_an_error_over_the_wire() {
    let ds = synthetic::iris(98);
    let mut coord = Coordinator::native_only();
    let (_, cf, _) = coord.train_and_compress(&ds, 4, 13, &CompressOptions::default()).unwrap();
    let dir = temp_spill_dir("corrupt");
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(ModelStore::new().spill_dir(&dir));
    store.insert("m", &cf).unwrap();
    assert!(store.spill("m").unwrap());
    // truncate the spill file behind the store's back
    let file = std::fs::read_dir(&dir).unwrap().next().unwrap().unwrap().path();
    let full = std::fs::read(&file).unwrap();
    std::fs::write(&file, &full[..full.len() / 3]).unwrap();

    let server = Server::start(store.clone(), 0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    let wire = values_to_wire(&row_values(&ds, 0));
    let reply = client.request(&format!("PREDICT m {wire}")).unwrap();
    assert!(reply.starts_with("ERR"), "typed error, no panic: {reply}");
    // the connection (and the server) survive the failed reload
    assert!(client.request("LIST").unwrap().starts_with("OK"));
    server.stop();
    drop(server);
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn spill_files_lifecycle_remove_replace_shutdown() {
    let ds = synthetic::wages(99);
    let mut coord = Coordinator::native_only();
    let (_, cf, _) = coord.train_and_compress(&ds, 4, 14, &CompressOptions::default()).unwrap();
    let dir = temp_spill_dir("lifecycle");
    let _ = std::fs::remove_dir_all(&dir);
    let store = ModelStore::new().spill_dir(&dir);
    for name in ["a", "b", "c"] {
        store.insert(name, &cf).unwrap();
        assert!(store.spill(name).unwrap());
    }
    assert_eq!(spill_file_count(&dir), 3);
    assert_eq!(store.spilled_len(), 3);
    // remove → file deleted
    assert!(store.remove("a"));
    assert_eq!(spill_file_count(&dir), 2);
    // replace → old file deleted, new model resident
    store.insert("b", &cf).unwrap();
    assert!(!store.is_spilled("b"));
    assert_eq!(spill_file_count(&dir), 1);
    // shutdown → everything left is purged
    drop(store);
    assert_eq!(spill_file_count(&dir), 0, "shutdown must purge spill files");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn store_direct_api_matches_forest() {
    let ds = synthetic::naval_classification(93);
    let mut coord = Coordinator::native_only();
    let (forest, cf, report) =
        coord.train_and_compress(&ds, 4, 8, &CompressOptions::default()).unwrap();
    // 4 trees cannot amortize dictionaries; the standard baseline must
    // still lose (light-baseline wins are covered by the Table-2 bench)
    assert!(report.standard_ratio() > 1.0);
    let store = ModelStore::new();
    store.insert("naval", &cf).unwrap();
    for row in (0..ds.num_rows()).step_by(397) {
        let got = store.predict("naval", &row_values(&ds, row)).unwrap();
        assert_eq!(got, PredictOne::Class(forest.predict_class(&ds, row)));
    }
}

/// Build an in-memory cohort pack over tiny per-user iris forests.
fn cohort_pack(
    members: usize,
    seed: u64,
) -> (
    Arc<rf_compress::pack::PackArchive>,
    Vec<rf_compress::forest::Forest>,
    Dataset,
) {
    use rf_compress::forest::{Forest, ForestParams};
    let ds = synthetic::iris(90);
    let forests: Vec<Forest> = (0..members)
        .map(|i| Forest::train(&ds, &ForestParams::classification(2), seed + i as u64))
        .collect();
    let cohort =
        rf_compress::pack::compress_cohort(&forests, &ds, &CompressOptions::default()).unwrap();
    let mut builder = rf_compress::pack::PackBuilder::new();
    for (i, cf) in cohort.iter().enumerate() {
        builder.add(&format!("user-{i}"), cf.bytes.clone()).unwrap();
    }
    let (bytes, _) = builder.build().unwrap();
    let pack = rf_compress::pack::PackArchive::from_bytes(bytes).unwrap();
    (Arc::new(pack), forests, ds)
}

#[test]
fn pack_members_serve_over_tcp_with_stats() {
    // a pack attaches as the third tier; members load on first PREDICT and
    // the wire protocol reports the pack counters
    let (pack, forests, ds) = cohort_pack(4, 31);
    let store = Arc::new(ModelStore::new());
    store.attach_pack(&pack).unwrap();
    assert_eq!(store.packed_len(), 4, "members start unloaded");

    let server = Server::start(store.clone(), 0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    let list = client.request("LIST").unwrap();
    for i in 0..4 {
        assert!(list.contains(&format!("user-{i}")), "{list}");
    }
    // BYTES reports the packed tier before any load
    let bytes = client.request("BYTES").unwrap();
    let packed: u64 = bytes
        .split_whitespace()
        .find_map(|tok| tok.strip_prefix("packed="))
        .expect("BYTES reply carries packed=")
        .parse()
        .unwrap();
    assert!(packed > 0, "{bytes}");

    // every member answers exactly like its original forest
    for (m, forest) in forests.iter().enumerate() {
        for row in (0..ds.num_rows()).step_by(37) {
            let wire = values_to_wire(&row_values(&ds, row));
            let reply = client.request(&format!("PREDICT user-{m} {wire}")).unwrap();
            assert_eq!(reply, format!("OK {}", forest.predict_class(&ds, row)), "member {m}");
        }
    }
    let stats = client.request("STATS").unwrap();
    let loads: u64 = stats
        .split_whitespace()
        .find_map(|tok| tok.strip_prefix("pack_loads="))
        .expect("STATS reply carries pack_loads=")
        .parse()
        .unwrap();
    assert_eq!(loads, 4, "{stats}");
    assert!(stats.contains("pack_releases=0"), "{stats}");
    // loaded members left the packed tier
    let bytes = client.request("BYTES").unwrap();
    assert!(bytes.contains("packed=0"), "{bytes}");
    server.stop();
}

#[test]
fn pack_release_under_budget_keeps_every_member_servable() {
    // budget for ~2 loaded members, 6 in the pack: sweeping all of them
    // twice must release under pressure (never spill, never evict) and
    // still answer correctly on both passes
    let (pack, forests, ds) = cohort_pack(6, 32);
    let one = pack.member_logical_bytes(0);
    let store = Arc::new(ModelStore::with_budget(2 * one + one / 2));
    store.attach_pack(&pack).unwrap();

    for pass in 0..2 {
        for (m, forest) in forests.iter().enumerate() {
            let vals = row_values(&ds, m);
            let got = store.predict(&format!("user-{m}"), &vals).unwrap();
            assert_eq!(
                got,
                PredictOne::Class(forest.predict_class(&ds, m)),
                "pass {pass}, member {m}"
            );
        }
    }
    assert!(store.resident_bytes() <= store.max_resident_bytes().unwrap());
    let s = store.stats();
    assert!(s.pack_loads >= 6, "every member loaded at least once");
    assert!(s.pack_releases >= 4, "budget pressure must release members");
    assert_eq!(s.spills, 0, "pack members never spill");
    assert_eq!(s.evictions, 0, "pack members never drop");
    assert_eq!(store.len(), 6, "all members still owned");
}

#[test]
fn pack_file_round_trip_through_cli_surfaces() {
    // the repro CLI path: write the archive to disk, reopen via mmap,
    // extract every member bit-identical (what `repro pack extract` does)
    use rf_compress::forest::{Forest, ForestParams};
    let ds = synthetic::iris(93);
    let forests: Vec<Forest> =
        (0..3).map(|i| Forest::train(&ds, &ForestParams::classification(2), 60 + i)).collect();
    let cohort =
        rf_compress::pack::compress_cohort(&forests, &ds, &CompressOptions::default()).unwrap();
    let mut builder = rf_compress::pack::PackBuilder::new();
    for (i, cf) in cohort.iter().enumerate() {
        builder.add(&format!("user-{i}"), cf.bytes.clone()).unwrap();
    }
    let path = std::env::temp_dir()
        .join(format!("rfc-e2e-pack-{}.rfpk", std::process::id()));
    let stats = builder.write(&path).unwrap();
    assert!(stats.shared_saved_bytes > 0, "cohort must dedup side info");

    let pack = rf_compress::pack::PackArchive::open(&path).unwrap();
    for (i, cf) in cohort.iter().enumerate() {
        assert_eq!(
            pack.extract_member(i).unwrap()[..],
            cf.bytes[..],
            "member {i} bit-identical through disk + mmap"
        );
    }
    // and a store mounted on the reopened pack serves from the mapping
    let store = ModelStore::new();
    store.attach_pack(&Arc::new(pack)).unwrap();
    let got = store.predict("user-0", &row_values(&ds, 5)).unwrap();
    assert_eq!(got, PredictOne::Class(forests[0].predict_class(&ds, 5)));
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn pack_compaction_under_load_over_tcp() {
    // the chain acceptance drill: a three-generation chain serves a
    // pipelined burst while a forced compaction swaps the manifest
    // mid-burst. Required: every request answers OK bit-identically (the
    // retry in the pack-load path absorbs the swap), STATS reports the
    // compaction, and the remount is replacement — not an eviction storm.
    use rf_compress::forest::{Forest, ForestParams};
    use rf_compress::pack::PackChain;

    let ds = synthetic::iris(90);
    let forests: Vec<Forest> = (0..6)
        .map(|i| Forest::train(&ds, &ForestParams::classification(2), 33 + i as u64))
        .collect();
    let opts = CompressOptions::default();
    let batch = |range: std::ops::Range<usize>| -> Vec<(String, Arc<[u8]>)> {
        let cohort =
            rf_compress::pack::compress_cohort(&forests[range.clone()], &ds, &opts).unwrap();
        range.zip(&cohort).map(|(i, cf)| (format!("user-{i}"), cf.bytes.clone())).collect()
    };
    let dir = std::env::temp_dir()
        .join(format!("rfc-e2e-chain-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    // three delta generations, each its own compressed cohort
    let mut chain = PackChain::create(&dir).unwrap();
    chain.append_members(&batch(0..3)).unwrap();
    chain.append_members(&batch(3..5)).unwrap();
    chain.append_members(&batch(5..6)).unwrap();
    assert_eq!(chain.generation_count(), 3);

    let store = Arc::new(ModelStore::new());
    let (_handle, mounted) = store.attach_chain(chain).unwrap();
    assert_eq!(mounted, 6, "every live chain member mounts");
    let server = Server::start(store.clone(), 0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    let stats = client.request("STATS").unwrap();
    assert!(stats.contains("pack_generations=3"), "{stats}");
    assert!(stats.contains("compactions=0"), "{stats}");

    // first half of the burst, then force the compaction from another
    // thread while the rest is issued — the swap lands mid-traffic
    const BURST: usize = 48;
    let plan: Vec<(usize, usize)> =
        (0..BURST).map(|id| (id % 6, id % ds.num_rows())).collect();
    for (id, (member, row)) in plan.iter().enumerate().take(BURST / 2) {
        let wire = values_to_wire(&row_values(&ds, *row));
        client.pipe_predict(id as u64, &format!("user-{member}"), &wire).unwrap();
    }
    let compactor = {
        let store = store.clone();
        std::thread::spawn(move || store.compact_chains(true))
    };
    for (id, (member, row)) in plan.iter().enumerate().skip(BURST / 2) {
        let wire = values_to_wire(&row_values(&ds, *row));
        client.pipe_predict(id as u64, &format!("user-{member}"), &wire).unwrap();
    }
    let replies = client.collect_pipelined(BURST).unwrap();
    assert_eq!(compactor.join().unwrap().unwrap(), 1, "one chain compacted");

    // every id answered exactly once with the forest's own prediction
    let mut seen = vec![false; BURST];
    for r in &replies {
        let PipeReply::Ok { id, value } = r else { panic!("mid-compaction failure: {r:?}") };
        let id = *id as usize;
        assert!(!seen[id], "id {id} answered twice");
        seen[id] = true;
        let (member, row) = plan[id];
        assert_eq!(
            *value,
            format!("{}", forests[member].predict_class(&ds, row)),
            "id {id}: wrong payload across the compaction swap"
        );
    }
    assert!(seen.iter().all(|&s| s), "some ids never resolved");

    // the chain is one generation now; replacement, not eviction
    let stats = client.request("STATS").unwrap();
    assert!(stats.contains("compactions=1"), "{stats}");
    assert!(stats.contains("pack_generations=1"), "{stats}");
    assert!(stats.contains("tombstones=0"), "{stats}");
    assert!(stats.contains("evictions=0"), "remount must not storm evictions: {stats}");
    // and the compacted chain still serves fresh loads correctly
    for (m, forest) in forests.iter().enumerate() {
        let wire = values_to_wire(&row_values(&ds, m));
        let reply = client.request(&format!("PREDICT user-{m} {wire}")).unwrap();
        assert_eq!(reply, format!("OK {}", forest.predict_class(&ds, m)));
    }
    server.stop();
    let _ = std::fs::remove_dir_all(&dir);
}
