//! End-to-end coordinator tests: train → compress → store → serve over TCP
//! → predictions from compressed bytes match the original forest.

use rf_compress::compress::predict::PredictOne;
use rf_compress::compress::CompressOptions;
use rf_compress::coordinator::server::{Client, Server};
use rf_compress::coordinator::store::{ModelStore, ObsValue};
use rf_compress::coordinator::Coordinator;
use rf_compress::data::{synthetic, Column, Dataset};
use std::sync::Arc;

fn row_values(ds: &Dataset, row: usize) -> Vec<ObsValue> {
    ds.features
        .iter()
        .map(|f| match &f.column {
            Column::Numeric(v) => ObsValue::Num(v[row]),
            Column::Categorical { values, .. } => ObsValue::Cat(values[row]),
        })
        .collect()
}

fn values_to_wire(values: &[ObsValue]) -> String {
    values
        .iter()
        .map(|v| match v {
            ObsValue::Num(x) => format!("{x}"),
            ObsValue::Cat(c) => format!("c{c}"),
        })
        .collect::<Vec<_>>()
        .join(",")
}

#[test]
fn coordinator_to_server_round_trip() {
    // 1. coordinator trains + compresses two models
    let iris = synthetic::iris(91);
    let wages = synthetic::wages(91);
    let mut coord = Coordinator::native_only();
    let opts = CompressOptions::default();
    let (iris_forest, iris_cf, iris_report) =
        coord.train_and_compress(&iris, 30, 5, &opts).unwrap();
    let (wages_forest, wages_cf, _) = coord.train_and_compress(&wages, 4, 6, &opts).unwrap();
    assert!(
        iris_report.ours_bytes < iris_report.light_bytes,
        "at 30 trees the dictionaries amortize: ours {} vs light {}",
        iris_report.ours_bytes,
        iris_report.light_bytes
    );

    // 2. store them
    let store = Arc::new(ModelStore::new());
    store.insert("iris", &iris_cf).unwrap();
    store.insert("wages", &wages_cf).unwrap();
    assert_eq!(store.len(), 2);

    // 3. serve and query over TCP
    let server = Server::start(store.clone(), 0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();

    let list = client.request("LIST").unwrap();
    assert!(list.starts_with("OK"));
    assert!(list.contains("iris") && list.contains("wages"));

    for row in (0..iris.num_rows()).step_by(29) {
        let wire = values_to_wire(&row_values(&iris, row));
        let reply = client.request(&format!("PREDICT iris {wire}")).unwrap();
        let expect = iris_forest.predict_class(&iris, row);
        assert_eq!(reply, format!("OK {expect}"), "row {row}");
    }
    for row in (0..wages.num_rows()).step_by(101) {
        let wire = values_to_wire(&row_values(&wages, row));
        let reply = client.request(&format!("PREDICT wages {wire}")).unwrap();
        let expect = wages_forest.predict_class(&wages, row);
        assert_eq!(reply, format!("OK {expect}"));
    }

    let stats = client.request("STATS").unwrap();
    assert!(stats.starts_with("OK requests="), "{stats}");
    let bytes = client.request("BYTES").unwrap();
    assert!(bytes.starts_with("OK resident="), "{bytes}");

    // 4. error paths stay connected
    let err = client.request("PREDICT nope 1,2,3,4").unwrap();
    assert!(err.starts_with("ERR"), "{err}");
    let err = client.request("GARBAGE").unwrap();
    assert!(err.starts_with("ERR"), "{err}");

    server.stop();
}

#[test]
fn concurrent_clients_batch_correctly() {
    let ds = synthetic::airfoil_classification(92);
    let mut coord = Coordinator::native_only();
    let (forest, cf, _) =
        coord.train_and_compress(&ds, 5, 7, &CompressOptions::default()).unwrap();
    let store = Arc::new(ModelStore::new());
    store.insert("m", &cf).unwrap();
    let server = Server::start(store.clone(), 0).unwrap();
    let addr = server.addr();

    let rows: Vec<usize> = (0..ds.num_rows()).step_by(97).collect();
    let expected: Vec<u32> = rows.iter().map(|&r| forest.predict_class(&ds, r)).collect();

    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|c| {
                let ds = &ds;
                let rows = &rows;
                let expected = &expected;
                scope.spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    for (i, &row) in rows.iter().enumerate() {
                        if i % 4 != c {
                            continue;
                        }
                        let wire = values_to_wire(&row_values(ds, row));
                        let reply = client.request(&format!("PREDICT m {wire}")).unwrap();
                        assert_eq!(reply, format!("OK {}", expected[i]));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    let stats = store.stats();
    assert!(stats.requests >= rows.len() as u64);
    server.stop();
}

#[test]
fn eviction_under_budget_over_tcp() {
    // budget for ~2.5 models: the third insert must evict the LRU one, and
    // LIST/BYTES over the wire must reflect the post-eviction store
    let ds = synthetic::iris(94);
    let mut coord = Coordinator::native_only();
    let (_, cf, _) = coord.train_and_compress(&ds, 5, 9, &CompressOptions::default()).unwrap();
    let one = cf.total_bytes();
    let store = Arc::new(ModelStore::with_budget(2 * one + one / 2));
    store.insert("m0", &cf).unwrap();
    store.insert("m1", &cf).unwrap();
    let server = Server::start(store.clone(), 0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();

    // touch m0 over the wire so m1 becomes the LRU victim
    let wire = values_to_wire(&row_values(&ds, 0));
    let reply = client.request(&format!("PREDICT m0 {wire}")).unwrap();
    assert!(reply.starts_with("OK"), "{reply}");

    // insert-past-budget → evicts m1 (never the fresh m2)
    store.insert("m2", &cf).unwrap();
    let list = client.request("LIST").unwrap();
    assert!(list.contains("m0") && list.contains("m2"), "{list}");
    assert!(!list.contains("m1"), "LRU model must be gone: {list}");
    let bytes = client.request("BYTES").unwrap();
    let resident: u64 = bytes
        .split_whitespace()
        .find_map(|tok| tok.strip_prefix("resident="))
        .expect("BYTES reply carries resident=")
        .parse()
        .unwrap();
    assert_eq!(resident, 2 * one, "two models resident after eviction");
    assert!(resident <= store.max_resident_bytes().unwrap());
    assert!(bytes.contains("plans="), "BYTES reports plan residency: {bytes}");

    // the evicted model now errors over the wire; the connection survives
    let reply = client.request(&format!("PREDICT m1 {wire}")).unwrap();
    assert!(reply.starts_with("ERR"), "{reply}");
    let stats = client.request("STATS").unwrap();
    assert!(stats.contains("evictions=1"), "{stats}");
    server.stop();
}

#[test]
fn batcher_queues_reaped_after_model_removal() {
    let ds = synthetic::iris(95);
    let mut coord = Coordinator::native_only();
    let (_, cf, _) = coord.train_and_compress(&ds, 4, 10, &CompressOptions::default()).unwrap();
    let store = Arc::new(ModelStore::new());
    store.insert("m", &cf).unwrap();
    let server = Server::start(store.clone(), 0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();

    let wire = values_to_wire(&row_values(&ds, 0));
    assert!(client.request(&format!("PREDICT m {wire}")).unwrap().starts_with("OK"));
    assert_eq!(server.active_batchers(), 1);

    // a bad model name must not spawn a queue
    assert!(client.request("PREDICT ghost 1,2,3,4").unwrap().starts_with("ERR"));
    assert_eq!(server.active_batchers(), 1, "unknown models spawn no batcher");

    // removing the model retires its batcher on the next idle tick
    assert!(store.remove("m"));
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while server.active_batchers() > 0 && std::time::Instant::now() < deadline {
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    assert_eq!(server.active_batchers(), 0, "dead per-model queue must be reaped");

    // QUIT closes the connection cleanly (empty read on our side)
    assert_eq!(client.request("QUIT").unwrap(), "");
    server.stop();
}

#[test]
fn store_direct_api_matches_forest() {
    let ds = synthetic::naval_classification(93);
    let mut coord = Coordinator::native_only();
    let (forest, cf, report) =
        coord.train_and_compress(&ds, 4, 8, &CompressOptions::default()).unwrap();
    // 4 trees cannot amortize dictionaries; the standard baseline must
    // still lose (light-baseline wins are covered by the Table-2 bench)
    assert!(report.standard_ratio() > 1.0);
    let store = ModelStore::new();
    store.insert("naval", &cf).unwrap();
    for row in (0..ds.num_rows()).step_by(397) {
        let got = store.predict("naval", &row_values(&ds, row)).unwrap();
        assert_eq!(got, PredictOne::Class(forest.predict_class(&ds, row)));
    }
}
