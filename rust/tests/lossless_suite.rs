//! The losslessness contract across the whole synthetic suite, plus
//! lossy-path integration: every Table-2 dataset round-trips bit-exactly,
//! and quantized/subsampled forests still round-trip losslessly *after*
//! their transform.

use rf_compress::compress::{CompressOptions, CompressedForest};
use rf_compress::data::synthetic::table2_suite;
use rf_compress::forest::{Forest, ForestParams};
use rf_compress::lossy;

/// Small tree counts keep this under a minute while covering every dataset
/// shape (numeric/categorical mixes, 2–9 classes, regression).
#[test]
fn every_suite_dataset_roundtrips_losslessly() {
    for entry in table2_suite() {
        // cap the biggest datasets for test-time sanity
        let ds = (entry.make)(7);
        let n_trees = if ds.num_rows() > 20_000 { 2 } else { 3 };
        let params = if ds.target.is_classification() {
            ForestParams::classification(n_trees)
        } else {
            ForestParams::regression(n_trees)
        };
        let forest = Forest::train(&ds, &params, 11);
        let cf = CompressedForest::compress(&forest, &ds, &CompressOptions::default())
            .unwrap_or_else(|e| panic!("{}: compress failed: {e:#}", entry.key));
        let restored = cf
            .decompress()
            .unwrap_or_else(|e| panic!("{}: decompress failed: {e:#}", entry.key));
        assert!(restored.identical(&forest), "{}: round-trip differs", entry.key);
    }
}

#[test]
fn lossy_transforms_remain_losslessly_codable() {
    let ds = rf_compress::data::synthetic::airfoil_regression(17);
    let forest = Forest::train(&ds, &ForestParams::regression(10), 3);
    for bits in [4u32, 8, 12] {
        let (qf, _) = lossy::quantize_fits(&forest, bits, lossy::QuantizeMethod::Uniform).unwrap();
        let sub = lossy::subsample_trees(&qf, 5, 9);
        let cf = CompressedForest::compress(&sub, &ds, &CompressOptions::default()).unwrap();
        let restored = cf.decompress().unwrap();
        assert!(restored.identical(&sub), "{bits}-bit lossy forest must round-trip");
    }
}

#[test]
fn quantization_shrinks_compressed_regression_size() {
    let ds = rf_compress::data::synthetic::airfoil_regression(18);
    let forest = Forest::train(&ds, &ForestParams::regression(8), 4);
    let full = CompressedForest::compress(&forest, &ds, &CompressOptions::default()).unwrap();
    let (q7, _) = lossy::quantize_fits(&forest, 7, lossy::QuantizeMethod::Uniform).unwrap();
    let c7 = CompressedForest::compress(&q7, &ds, &CompressOptions::default()).unwrap();
    assert!(
        c7.total_bytes() < full.total_bytes(),
        "7-bit fits {} must beat 64-bit {}",
        c7.total_bytes(),
        full.total_bytes()
    );
    // the paper's linear-in-|A0| size trend
    let half = lossy::subsample_trees(&q7, 4, 5);
    let ch = CompressedForest::compress(&half, &ds, &CompressOptions::default()).unwrap();
    let ratio = ch.total_bytes() as f64 / c7.total_bytes() as f64;
    assert!(
        (0.3..0.8).contains(&ratio),
        "half the trees should land near half the size (ratio {ratio:.2})"
    );
}
