//! End-to-end tests of the adversarial workload harness against a live
//! server: the loadgen wire run itself, the scan-resistance comparison
//! between the `lru` and `tinylfu` admission policies (measured from the
//! store's own counters, never from timing), and the `PREFETCH` verb.
//!
//! The wire protocol is specified in `rust/PROTOCOL.md`; the operator's
//! view of these knobs lives in `rust/OPERATIONS.md`.

mod common;

use common::{row_values, values_to_wire};
use rf_compress::compress::{CompressOptions, CompressedForest};
use rf_compress::coordinator::admission::AdmissionPolicy;
use rf_compress::coordinator::server::{Client, Server, ServerConfig};
use rf_compress::coordinator::store::{ModelStore, DEFAULT_SHARDS};
use rf_compress::coordinator::Coordinator;
use rf_compress::data::synthetic;
use rf_compress::testing::loadgen::{
    generate_trace, hot_hit_rate, hot_tenants, run_trace, split_hot_cold, LoadgenConfig,
    RunOptions, Scenario,
};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn tenant_model(seed: u64) -> (rf_compress::data::Dataset, CompressedForest) {
    let ds = synthetic::iris(17);
    let (_, cf, _) = Coordinator::native_only()
        .train_and_compress(&ds, 3, seed, &CompressOptions::default())
        .unwrap();
    (ds, cf)
}

/// Unique spill directory per test run (suites run in parallel).
fn temp_spill_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("rfc-loadgen-e2e-{tag}-{}", std::process::id()))
}

#[test]
fn loadgen_wire_run_answers_every_request() {
    let (ds, cf) = tenant_model(5);
    let store = Arc::new(ModelStore::new());
    let models: Vec<String> = (0..4).map(|t| format!("t{t}")).collect();
    for m in &models {
        store.insert(m, &cf).unwrap();
    }
    let server = Server::start(store, 0).unwrap();
    let cfg = LoadgenConfig {
        tenants: 4,
        requests: 300,
        rate: 20_000.0,
        ..LoadgenConfig::quick(Scenario::Steady)
    };
    let trace = generate_trace(&cfg);
    let values = values_to_wire(&row_values(&ds, 0));

    // pipelined: every request answered OK, none lost, none errored
    let opts = RunOptions { values: values.clone(), window: 32, ..RunOptions::default() };
    let r = run_trace(server.addr(), &models, &trace, &opts).unwrap();
    assert_eq!(r.sent, trace.len() as u64);
    assert_eq!(r.ok, r.sent, "every pipelined request must be answered: {r:?}");
    assert_eq!(r.errors, 0, "{r:?}");
    assert!(r.p50_us <= r.p95_us && r.p95_us <= r.p99_us && r.p99_us <= r.max_us);

    // serial lockstep over a shorter trace agrees
    let short = LoadgenConfig { requests: 40, ..cfg.clone() };
    let strace = generate_trace(&short);
    let sopts = RunOptions { pipe: false, values, ..RunOptions::default() };
    let s = run_trace(server.addr(), &models, &strace, &sopts).unwrap();
    assert_eq!(s.ok, strace.len() as u64, "{s:?}");
    assert_eq!(s.errors, 0);
}

/// One (policy, scan-trace) measurement: hot-set hit rate and the
/// admission-reject counter delta, from a self-hosted budgeted store.
fn scan_run(policy: AdmissionPolicy) -> (f64, u64) {
    let (ds, cf) = tenant_model(9);
    let cfg = LoadgenConfig {
        seed: 11,
        tenants: 12,
        requests: 240,
        rate: 5000.0,
        hot_set: 3,
        ..LoadgenConfig::quick(Scenario::Scan)
    };
    // budget: the hot set plus slack fits, the tail does not
    let budget = cf.total_bytes() * (cfg.hot_set as u64 + 2);
    let dir = temp_spill_dir(&format!("scan-{policy}"));
    let store = Arc::new(
        ModelStore::with_config(DEFAULT_SHARDS, Some(budget))
            .admission(policy)
            .spill_dir(dir.clone()),
    );
    let models: Vec<String> = (0..cfg.tenants).map(|t| format!("t{t}")).collect();
    for m in &models {
        store.insert(m, &cf).unwrap();
    }
    let server = Server::start_with(store.clone(), 0, ServerConfig::default()).unwrap();
    let values = values_to_wire(&row_values(&ds, 0));

    // warm the hot set: resident + (under tinylfu) frequency-known
    let hot = hot_tenants(&cfg);
    let mut client = Client::connect(server.addr()).unwrap();
    for _ in 0..3 {
        for t in &hot {
            let reply = client.request(&format!("PREDICT t{t} {values}")).unwrap();
            assert!(reply.starts_with("OK"), "{reply}");
        }
    }

    let before = store.stats();
    let trace = generate_trace(&cfg);
    let opts = RunOptions { values, window: 32, ..RunOptions::default() };
    let r = run_trace(server.addr(), &models, &trace, &opts).unwrap();
    assert_eq!(r.ok, trace.len() as u64, "[{policy}] every request answered: {r:?}");
    let after = store.stats();

    let promotions =
        (after.reloads - before.reloads) + (after.pack_loads - before.pack_loads);
    let (h, c) = split_hot_cold(&trace, &hot);
    let rate = hot_hit_rate(h, c, promotions);
    let _ = std::fs::remove_dir_all(&dir);
    (rate, after.admission_rejects - before.admission_rejects)
}

#[test]
fn tinylfu_retains_the_hot_set_a_scan_erodes_under_lru() {
    let (lru_rate, lru_rejects) = scan_run(AdmissionPolicy::Lru);
    let (tiny_rate, tiny_rejects) = scan_run(AdmissionPolicy::TinyLfu);
    // the gate never fires under lru, and must have fired under tinylfu
    // (the sweep's cold loads were turned back at least once)
    assert_eq!(lru_rejects, 0, "lru must never consult the sketch");
    assert!(tiny_rejects > 0, "the sweep must trip the tinylfu gate");
    // the acceptance bar: frequency-weighted admission keeps at least the
    // hot-set hit rate recency alone manages under the same scan
    assert!(
        tiny_rate >= lru_rate,
        "tinylfu hot-hit {tiny_rate:.3} must be >= lru {lru_rate:.3}"
    );
    assert!(
        tiny_rate > 0.95,
        "with the sweep turned back, the hot set stays resident: {tiny_rate:.3}"
    );
}

#[test]
fn prefetch_warms_a_spilled_model_over_the_wire() {
    let (ds, cf) = tenant_model(23);
    let one = cf.total_bytes();
    let dir = temp_spill_dir("prefetch");
    let store = Arc::new(
        ModelStore::with_config(DEFAULT_SHARDS, Some(one + one / 2)).spill_dir(dir.clone()),
    );
    store.insert("alpha", &cf).unwrap();
    store.insert("beta", &cf).unwrap(); // displaces alpha to the spill tier
    assert!(store.is_spilled("alpha"), "alpha must start spilled");
    let server = Server::start_with(store.clone(), 0, ServerConfig::default()).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();

    assert_eq!(store.stats().prefetches, 0);
    let reply = client.request("PREFETCH alpha").unwrap();
    assert_eq!(reply, "OK warming alpha");

    // the warm-up runs in the background; wait for its reload to land
    let deadline = Instant::now() + Duration::from_secs(10);
    while store.stats().reloads == 0 {
        assert!(Instant::now() < deadline, "prefetch warm-up never reloaded alpha");
        std::thread::sleep(Duration::from_millis(5));
    }

    // a predict now serves the warmed model; PREDICT itself never counts
    // as a prefetch
    let values = values_to_wire(&row_values(&ds, 0));
    let reply = client.request(&format!("PREDICT alpha {values}")).unwrap();
    assert!(reply.starts_with("OK"), "{reply}");
    assert_eq!(store.stats().prefetches, 1, "only the cold PREFETCH counts");

    // an already-resident target acknowledges without counting
    let reply = client.request("PREFETCH alpha").unwrap();
    assert_eq!(reply, "OK resident alpha");
    assert_eq!(store.stats().prefetches, 1);

    // the pipelined form answers through the outbox with its id
    client.send("PIPE 9 PREFETCH alpha").unwrap();
    assert_eq!(client.recv().unwrap(), "OK 9 resident alpha");

    // unknown targets are a typed error, serial and pipelined
    let reply = client.request("PREFETCH ghost").unwrap();
    assert!(reply.starts_with("ERR"), "{reply}");
    client.send("PIPE 10 PREFETCH ghost").unwrap();
    let reply = client.recv().unwrap();
    assert!(reply.starts_with("ERR") && reply.ends_with("id=10"), "{reply}");

    let _ = client.send("QUIT");
    drop(server);
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
}
