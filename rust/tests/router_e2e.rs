//! End-to-end tests of the shard-routing coordinator (`Router`): routed
//! replies are bit-identical to a direct backend's, the routed reply stream
//! is a permutation of the direct one under random interleavings, and the
//! partition drill — a backend severed mid-burst is ejected within the
//! probe interval, every in-flight id resolves exactly once, and the
//! backend is re-admitted once it returns.
//!
//! Grammar and retry semantics under test: `rust/PROTOCOL.md` § Routing.

mod common;

use common::{row_values, values_to_wire};
use rf_compress::compress::CompressOptions;
use rf_compress::coordinator::health::{HealthPolicy, HealthState};
use rf_compress::coordinator::router::{Router, RouterConfig};
use rf_compress::coordinator::server::{Client, PipeReply, Server};
use rf_compress::coordinator::store::ModelStore;
use rf_compress::coordinator::Coordinator;
use rf_compress::data::{synthetic, Dataset};
use rf_compress::testing::chaos::ChaosProxy;
use rf_compress::testing::prop::{forall_cases, Gen};
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Train `models` forests once and stand up `n` identical backends — every
/// backend holds every model, so replicas answer bit-identically and any
/// backend can serve as the direct-comparison oracle.
fn fleet(n: usize, ds: &Dataset, models: &[&str]) -> Vec<Server> {
    let mut coord = Coordinator::native_only();
    let forests: Vec<_> = models
        .iter()
        .enumerate()
        .map(|(i, _)| {
            coord
                .train_and_compress(ds, 8, 100 + i as u64, &CompressOptions::default())
                .unwrap()
                .1
        })
        .collect();
    (0..n)
        .map(|_| {
            let store = Arc::new(ModelStore::new());
            for (name, cf) in models.iter().zip(&forests) {
                store.insert(name, cf).unwrap();
            }
            Server::start(store, 0).unwrap()
        })
        .collect()
}

/// A router config tuned for tests: tight timeouts, fast probes, and every
/// key hot after the first refresh (small `hot_refresh`).
fn test_router_cfg() -> RouterConfig {
    RouterConfig {
        replication: 2,
        hot_k: 32,
        hot_refresh: 8,
        max_tries: 3,
        connect_timeout: Duration::from_millis(300),
        request_timeout: Duration::from_millis(2_000),
        backoff_base: Duration::from_millis(2),
        health: HealthPolicy {
            degrade_after: 1,
            eject_after: 2,
            eject_cooldown: Duration::from_millis(200),
            probe_interval: Duration::from_millis(100),
        },
        ..RouterConfig::default()
    }
}

#[test]
fn routed_serial_replies_are_bit_identical_to_direct() {
    let ds = synthetic::iris(51);
    let models = ["alpha", "beta", "gamma"];
    let backends = fleet(3, &ds, &models);
    let addrs: Vec<SocketAddr> = backends.iter().map(|b| b.addr()).collect();
    let router = Router::start(&addrs, 0, test_router_cfg()).unwrap();

    let mut routed = Client::connect(router.addr()).unwrap();
    routed.set_deadlines(Some(Duration::from_secs(10)), Some(Duration::from_secs(10))).unwrap();
    let mut direct = Client::connect(backends[0].addr()).unwrap();

    for row in 0..12 {
        for model in &models {
            let wire = values_to_wire(&row_values(&ds, row));
            let via_router = routed.request(&format!("PREDICT {model} {wire}")).unwrap();
            let via_backend = direct.request(&format!("PREDICT {model} {wire}")).unwrap();
            assert_eq!(via_router, via_backend, "{model} row {row} diverged through the router");
        }
    }

    // LIST through the router is the deduped union (here: every backend
    // holds the same set, so it equals the direct list)
    let routed_list = routed.request("LIST").unwrap();
    let direct_list = direct.request("LIST").unwrap();
    assert_eq!(routed_list, direct_list);

    // routed STATS is the router's own counter surface, not a backend's
    let stats = routed.request("STATS").unwrap();
    assert!(stats.starts_with("OK routed="), "unexpected router STATS: {stats}");

    let s = router.stats();
    assert_eq!(s.unavailable, 0, "healthy fleet answered unavailable");
    assert_eq!(s.backends_up, 3);
    router.stop();
}

#[test]
fn prop_routed_replies_match_single_backend() {
    // for random model sets and interleavings, the routed pipelined reply
    // stream (healthy fleet) is a permutation of a single direct backend's
    // replies — same ids, bit-identical payloads
    forall_cases("routed_replies_match_single_backend", 6, &mut |g: &mut Gen| {
        let numeric = g.usize_in(1, 3);
        let categorical = g.usize_in(0, 2);
        let classification = g.u64_in(0, 1) == 1;
        let ds = g.dataset(40, numeric, categorical, classification);
        let n_models = g.usize_in(1, 4);
        let names: Vec<String> = (0..n_models).map(|i| format!("model-{i}")).collect();
        let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let backends = fleet(3, &ds, &name_refs);
        let addrs: Vec<SocketAddr> = backends.iter().map(|b| b.addr()).collect();
        let router = Router::start(&addrs, 0, test_router_cfg()).unwrap();

        // a random interleaving of (id, model, row)
        let n_requests = g.usize_in(8, 40);
        let plan: Vec<(u64, usize, usize)> = (0..n_requests)
            .map(|id| (id as u64, g.usize_in(0, n_models - 1), g.usize_in(0, 39)))
            .collect();

        let mut routed = Client::connect(router.addr()).unwrap();
        routed
            .set_deadlines(Some(Duration::from_secs(10)), Some(Duration::from_secs(10)))
            .map_err(|e| e.to_string())?;
        let mut direct = Client::connect(backends[0].addr()).unwrap();
        for &(id, m, row) in &plan {
            let wire = values_to_wire(&row_values(&ds, row));
            routed.pipe_predict(id, &names[m], &wire).map_err(|e| e.to_string())?;
            direct.pipe_predict(id, &names[m], &wire).map_err(|e| e.to_string())?;
        }
        let mut via_router = answered_pairs(&mut routed, n_requests)?;
        let mut via_backend = answered_pairs(&mut direct, n_requests)?;
        via_router.sort();
        via_backend.sort();
        if via_router != via_backend {
            return Err(format!(
                "routed replies are not a permutation of the direct backend's:\n\
                 routed:  {via_router:?}\ndirect: {via_backend:?}"
            ));
        }
        router.stop();
        Ok(())
    });
}

/// Collect `n` pipelined replies as `(id, payload)` pairs, failing the
/// property on any `ERR`.
fn answered_pairs(client: &mut Client, n: usize) -> Result<Vec<(u64, String)>, String> {
    let replies = client.collect_pipelined(n).map_err(|e| e.to_string())?;
    replies
        .into_iter()
        .map(|r| match r {
            PipeReply::Ok { id, value } => Ok((id, value)),
            PipeReply::Err { id, message } => Err(format!("id {id:?} failed: {message}")),
        })
        .collect()
}

#[test]
fn partition_midburst_ejects_resolves_every_id_and_readmits() {
    // the acceptance drill: 3 backends (each behind a chaos proxy), R=2.
    // Sever one backend mid-burst. Required: the backend ejects within the
    // probe interval, every in-flight id resolves exactly once (a replica
    // answers or a typed unavailable/upstream error arrives), no client
    // hangs, and the severed backend is re-admitted after it returns.
    let ds = synthetic::iris(61);
    let models = ["alpha", "beta", "gamma", "delta"];
    let backends = fleet(3, &ds, &models);
    let proxies: Vec<ChaosProxy> =
        backends.iter().map(|b| ChaosProxy::start(b.addr()).unwrap()).collect();
    // the router only ever sees the proxies' addresses
    let addrs: Vec<SocketAddr> = proxies.iter().map(|p| p.addr()).collect();
    let cfg = test_router_cfg();
    let probe_interval = cfg.health.probe_interval;
    let eject_bound = probe_interval * (cfg.health.eject_after + 2) + Duration::from_secs(1);
    let router = Router::start(&addrs, 0, cfg).unwrap();

    let mut client = Client::connect(router.addr()).unwrap();
    // no client hangs: a generous absolute deadline on every read
    client.set_deadlines(Some(Duration::from_secs(30)), Some(Duration::from_secs(30))).unwrap();

    // warm-up: route every model a few times so all keys enter the hot set
    // (hot keys carry the R=2 replica set reads fail over across)
    for round in 0..4 {
        for model in &models {
            let wire = values_to_wire(&row_values(&ds, round));
            let reply = client.request(&format!("PREDICT {model} {wire}")).unwrap();
            assert!(reply.starts_with("OK "), "warm-up failed: {reply}");
        }
    }

    // burst: issue a pipelined volley, severing one backend part-way in
    const BURST: usize = 60;
    for i in 0..BURST {
        let model = models[i % models.len()];
        let wire = values_to_wire(&row_values(&ds, i % 40));
        client.pipe_predict(i as u64, model, &wire).unwrap();
        if i == BURST / 3 {
            proxies[0].sever();
        }
    }

    // every in-flight id resolves exactly once: success on a replica, or a
    // typed unavailable/upstream error — never silence, never a duplicate
    let replies = client.collect_pipelined(BURST).unwrap();
    let mut seen = vec![false; BURST];
    let mut failures = 0usize;
    for r in &replies {
        let id = r.id().expect("router replies always carry the request id") as usize;
        assert!(id < BURST, "unknown id {id}");
        assert!(!seen[id], "id {id} answered twice");
        seen[id] = true;
        match r {
            PipeReply::Ok { .. } => {}
            PipeReply::Err { message, .. } => {
                assert!(
                    message.starts_with("unavailable") || message.starts_with("upstream"),
                    "id {id}: untyped failure under partition: {message:?}"
                );
                failures += 1;
            }
        }
    }
    assert!(seen.iter().all(|&s| s), "some in-flight ids never resolved");
    // R=2 on 3 backends: most keys keep a live replica, so the burst must
    // not have collapsed into all-errors
    assert!(
        failures < BURST / 2,
        "failover absorbed too little: {failures}/{BURST} failed"
    );

    // the severed backend leaves rotation within the probe bound
    let ejected_at = wait_for(eject_bound, || {
        router.backend_states()[0] == HealthState::Ejected
    });
    assert!(ejected_at, "backend 0 was not ejected within {eject_bound:?}");

    // the healthy remainder still serves every model
    for model in &models {
        let wire = values_to_wire(&row_values(&ds, 3));
        let reply = client.request(&format!("PREDICT {model} {wire}")).unwrap();
        assert!(reply.starts_with("OK "), "degraded fleet dropped {model}: {reply}");
    }

    // heal the partition: the probe loop re-admits after the cooldown
    proxies[0].restore();
    let readmitted = wait_for(Duration::from_secs(5), || {
        router.backend_states()[0] != HealthState::Ejected
    });
    assert!(readmitted, "backend 0 was not re-admitted after the partition healed");

    let stats = router.stats();
    assert!(stats.ejections >= 1, "ejection not counted: {stats:?}");
    assert!(stats.readmissions >= 1, "re-admission not counted: {stats:?}");
    assert_eq!(stats.backends_up, 3);
    router.stop();
}

#[test]
fn failover_request_trace_records_attempt_legs() {
    // a PREDICT whose first-choice replica is severed fails over to the
    // second; the router's request trace must record both legs
    // (`attempts=2`) and annotate the backend that finally answered.
    let ds = synthetic::iris(71);
    let models = ["alpha", "beta", "gamma", "delta"];
    let backends = fleet(2, &ds, &models);
    let proxies: Vec<ChaosProxy> =
        backends.iter().map(|b| ChaosProxy::start(b.addr()).unwrap()).collect();
    let addrs: Vec<SocketAddr> = proxies.iter().map(|p| p.addr()).collect();
    let mut cfg = test_router_cfg();
    cfg.slow_threshold_us = 0; // retain every request trace
    let router = Router::start(&addrs, 0, cfg).unwrap();

    let mut client = Client::connect(router.addr()).unwrap();
    client.set_deadlines(Some(Duration::from_secs(30)), Some(Duration::from_secs(30))).unwrap();

    // warm-up with both replicas healthy so every key enters the hot set
    // (hot keys carry the R=2 replica list failover walks)
    for round in 0..4 {
        for model in &models {
            let wire = values_to_wire(&row_values(&ds, round));
            let reply = client.request(&format!("PREDICT {model} {wire}")).unwrap();
            assert!(reply.starts_with("OK "), "warm-up failed: {reply}");
        }
    }

    let has_failover_trace = |router: &Router| {
        router
            .obs()
            .ring()
            .dump(usize::MAX)
            .iter()
            .any(|l| attempts_of(l) >= 2 && l.contains(" backend="))
    };

    // sever one side and route every model: any key whose first-choice
    // replica sat behind the severed proxy records a failover leg. If every
    // key happened to prefer the survivor, the second round severs the
    // other side, so one of the two rounds must force a failover.
    for severed in 0..proxies.len() {
        proxies[severed].sever();
        for model in &models {
            let wire = values_to_wire(&row_values(&ds, 5));
            let reply = client.request(&format!("PREDICT {model} {wire}")).unwrap();
            assert!(reply.starts_with("OK "), "failover round dropped {model}: {reply}");
        }
        proxies[severed].restore();
        if has_failover_trace(&router) {
            break;
        }
        // the failed legs may have ejected the severed side; wait for
        // re-admission so the next round has both replicas in rotation
        let healed = wait_for(Duration::from_secs(5), || {
            router.backend_states()[severed] != HealthState::Ejected
        });
        assert!(healed, "backend {severed} was not re-admitted after restore");
    }
    assert!(has_failover_trace(&router), "no trace recorded a failover leg");

    // the same trace is readable over the wire, and METRICS carries the
    // router's exposition surface
    let slow = client.request_block("SLOW").unwrap();
    let legs = slow
        .iter()
        .find(|l| attempts_of(l) >= 2)
        .unwrap_or_else(|| panic!("SLOW dump lost the failover trace: {slow:?}"));
    assert!(legs.contains(" backend="), "failover trace lost its backend annotation: {legs}");
    let metrics = client.request_block("METRICS").unwrap().join("\n");
    assert!(metrics.contains("# TYPE routed counter"), "{metrics}");
    assert!(metrics.contains("route_latency_us_count"), "{metrics}");
    router.stop();
}

/// Parse the `attempts=` annotation off a rendered trace line (0 if absent).
fn attempts_of(line: &str) -> u32 {
    line.split_whitespace()
        .find_map(|t| t.strip_prefix("attempts="))
        .and_then(|v| v.parse().ok())
        .unwrap_or(0)
}

/// Poll `cond` every 10 ms until it holds or `limit` elapses.
fn wait_for(limit: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + limit;
    while Instant::now() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    cond()
}
