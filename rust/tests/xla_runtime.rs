//! Integration tests for the AOT bridge: python/jax lowered the Lloyd step
//! to HLO text (`make artifacts`); here the rust PJRT runtime loads it,
//! runs it, and must agree with the native engine.
//!
//! These tests are skipped (with a loud message) when `artifacts/` has not
//! been built.

use rf_compress::cluster::kmeans::{LloydEngine, NativeEngine};
use rf_compress::compress::{CompressOptions, CompressedForest};
use rf_compress::data::synthetic;
use rf_compress::forest::{Forest, ForestParams};
use rf_compress::runtime::{HybridEngine, XlaRuntime};
use rf_compress::util::Pcg64;

fn runtime() -> Option<XlaRuntime> {
    match XlaRuntime::load_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: artifacts not built ({e}); run `make artifacts`");
            None
        }
    }
}

/// Random padded clustering problem.
fn random_problem(seed: u64, m: usize, b: usize, k: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut rng = Pcg64::new(seed);
    let mut p = vec![0.0; m * b];
    for i in 0..m {
        let row = &mut p[i * b..(i + 1) * b];
        let mut total = 0.0;
        for x in row.iter_mut() {
            *x = rng.gen_f64().powi(3);
            total += *x;
        }
        for x in row.iter_mut() {
            *x /= total;
        }
    }
    let w: Vec<f64> = (0..m).map(|_| (1 + rng.gen_range(999)) as f64).collect();
    let mut q = vec![0.0; k * b];
    for i in 0..k {
        let row = &mut q[i * b..(i + 1) * b];
        let mut total = 0.0;
        for x in row.iter_mut() {
            *x = rng.gen_f64() + 1e-3;
            total += *x;
        }
        for x in row.iter_mut() {
            *x /= total;
        }
    }
    (p, w, q)
}

#[test]
fn xla_step_matches_native() {
    let Some(rt) = runtime() else { return };
    for &(m, b, k, seed) in &[(40usize, 50usize, 4usize, 1u64), (100, 200, 8, 2), (7, 13, 2, 3)] {
        let (p, w, q) = random_problem(seed, m, b, k);
        let xla = rt
            .try_step(&p, &w, &q, m, b, k)
            .unwrap()
            .expect("bucket must fit these sizes");
        let native = NativeEngine.step(&p, &w, &q, m, b, k).unwrap();
        // assignments: identical up to f32 near-ties
        let agree = xla
            .assign
            .iter()
            .zip(&native.assign)
            .filter(|(a, b)| a == b)
            .count();
        assert!(
            agree as f64 >= 0.95 * m as f64,
            "({m},{b},{k}) assignments agree {agree}/{m}"
        );
        // objective: relative tolerance for f32 accumulation
        let rel = (xla.objective - native.objective).abs() / native.objective.max(1.0);
        assert!(rel < 1e-3, "objective rel err {rel}");
        // centroids where assignments agree fully: compare summed mass
        let sum_x: f64 = xla.new_q.iter().sum();
        let sum_n: f64 = native.new_q.iter().sum();
        assert!((sum_x - sum_n).abs() / sum_n.max(1.0) < 1e-2);
    }
}

#[test]
fn oversized_problems_report_no_fit() {
    let Some(rt) = runtime() else { return };
    // B beyond the biggest bucket
    assert!(!rt.fits(10, 1 << 20, 4));
    let (p, w, q) = random_problem(9, 4, 8, 2);
    // artificially claim a huge b: just check fits() gate
    assert!(rt.fits(4, 8, 2));
    let step = rt.try_step(&p, &w, &q, 4, 8, 2).unwrap();
    assert!(step.is_some());
}

#[test]
fn compression_with_xla_engine_is_lossless_and_close_to_native() {
    let Some(rt) = runtime() else { return };
    let ds = synthetic::wages(51);
    let forest = Forest::train(&ds, &ForestParams::classification(10), 7);
    let opts = CompressOptions::default();

    let mut hybrid = HybridEngine::with_runtime(rt);
    let cf_xla =
        CompressedForest::compress_with_engine(&forest, &ds, &opts, &mut hybrid).unwrap();
    assert!(hybrid.xla_steps > 0, "XLA engine must actually run");
    let restored = cf_xla.decompress().unwrap();
    assert!(forest.identical(&restored), "losslessness must hold under the XLA engine");

    let cf_native = CompressedForest::compress(&forest, &ds, &opts).unwrap();
    let a = cf_xla.total_bytes() as f64;
    let b = cf_native.total_bytes() as f64;
    assert!(
        (a - b).abs() / b < 0.05,
        "XLA-clustered size {a} should be within 5% of native {b}"
    );
}

#[test]
fn end_to_end_predictions_with_xla_engine() {
    let Some(rt) = runtime() else { return };
    let ds = synthetic::airfoil_classification(52);
    let forest = Forest::train(&ds, &ForestParams::classification(8), 9);
    let mut hybrid = HybridEngine::with_runtime(rt);
    let cf = CompressedForest::compress_with_engine(
        &forest,
        &ds,
        &CompressOptions::default(),
        &mut hybrid,
    )
    .unwrap();
    let pc = cf.parse().unwrap();
    let p = rf_compress::compress::CompressedPredictor::new(pc).unwrap();
    for row in (0..ds.num_rows()).step_by(251) {
        let expect = forest.predict_class(&ds, row);
        match p.predict_row(&ds, row).unwrap() {
            rf_compress::compress::predict::PredictOne::Class(c) => assert_eq!(c, expect),
            _ => panic!(),
        }
    }
}
