//! In-tree test harnesses: property-testing mini-framework (no `proptest`
//! offline) and the deterministic fault-injection proxy the router's
//! partition tests drive.

pub mod chaos;
pub mod prop;

pub use chaos::{ChaosProxy, ChaosSchedule, Fault};
pub use prop::{forall, Gen};
