//! In-tree test harnesses: property-testing mini-framework (no `proptest`
//! offline), the deterministic fault-injection proxy the router's
//! partition tests drive, the crash-injection seam the generation-chain
//! commit protocol is proven against, and the seed-replayable
//! multi-tenant workload generator behind `repro loadgen`.

pub mod chaos;
pub mod crashpoint;
pub mod loadgen;
pub mod prop;

pub use chaos::{ChaosProxy, ChaosSchedule, Fault};
pub use crashpoint::{CrashInjector, CrashPoint};
pub use prop::{forall, Gen};
