//! In-tree property-testing mini-framework (no `proptest` offline).

pub mod prop;

pub use prop::{forall, Gen};
