//! A small property-testing framework: seeded generators + `forall` runner
//! with iteration-count control and failure reporting (seed + case index, so
//! any failure replays deterministically).
//!
//! Shrinking is deliberately omitted — failures print the generator seed and
//! case index, which reproduces the exact input.

use crate::util::Pcg64;

/// A generator context handed to property closures.
pub struct Gen {
    rng: Pcg64,
}

impl Gen {
    /// A generator seeded deterministically.
    pub fn new(seed: u64) -> Self {
        Gen {
            rng: Pcg64::with_stream(seed, 0x6e6),
        }
    }

    /// Access the raw RNG.
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    /// Uniform `u64` in `[lo, hi]`.
    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.rng.gen_range(hi - lo + 1)
    }

    /// Uniform `usize` in `[lo, hi]`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.u64_in(lo as u64, hi as u64) as usize
    }

    /// Uniform `f64` in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.gen_f64() * (hi - lo)
    }

    /// Biased coin: `true` with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.gen_bool(p)
    }

    /// Byte vector with length in `[0, max_len]`.
    pub fn bytes(&mut self, max_len: usize) -> Vec<u8> {
        let n = self.usize_in(0, max_len);
        (0..n).map(|_| self.rng.next_u64() as u8).collect()
    }

    /// Vector of u32 symbols over alphabet `[0, alphabet)`.
    pub fn symbols(&mut self, max_len: usize, alphabet: u32) -> Vec<u32> {
        let n = self.usize_in(0, max_len);
        (0..n).map(|_| self.rng.gen_range(alphabet as u64) as u32).collect()
    }

    /// Probability vector of the given length (Dirichlet-ish via normalized
    /// exponentials; may contain zeros with probability `sparsity`).
    pub fn probs(&mut self, len: usize, sparsity: f64) -> Vec<f64> {
        let mut v: Vec<f64> = (0..len)
            .map(|_| {
                if self.rng.gen_bool(sparsity) {
                    0.0
                } else {
                    -self.rng.gen_f64().max(1e-12).ln()
                }
            })
            .collect();
        let total: f64 = v.iter().sum();
        if total <= 0.0 {
            v[0] = 1.0;
            return v;
        }
        for x in v.iter_mut() {
            *x /= total;
        }
        v
    }

    /// Count vector (empirical histogram) over `len` symbols.
    pub fn counts(&mut self, len: usize, max_count: u64, sparsity: f64) -> Vec<u64> {
        let mut v: Vec<u64> = (0..len)
            .map(|_| {
                if self.rng.gen_bool(sparsity) {
                    0
                } else {
                    1 + self.rng.gen_range(max_count)
                }
            })
            .collect();
        if v.iter().all(|&c| c == 0) {
            v[0] = 1;
        }
        v
    }

    /// A random dataset with `numeric` numeric and `categorical` categorical
    /// features (2–8 levels each) over `n_rows` rows. `numeric = 0` yields
    /// the all-categorical schemas the degenerate-forest properties need.
    pub fn dataset(
        &mut self,
        n_rows: usize,
        numeric: usize,
        categorical: usize,
        classification: bool,
    ) -> crate::data::Dataset {
        use crate::data::{Column, Dataset, Feature, Target};
        let mut features = Vec::with_capacity(numeric + categorical);
        for j in 0..numeric {
            let vals: Vec<f64> = (0..n_rows).map(|_| self.f64_in(-10.0, 10.0)).collect();
            features.push(Feature { name: format!("num{j}"), column: Column::Numeric(vals) });
        }
        for j in 0..categorical {
            let levels = self.usize_in(2, 8) as u32;
            let vals: Vec<u32> =
                (0..n_rows).map(|_| self.usize_in(0, levels as usize - 1) as u32).collect();
            features.push(Feature {
                name: format!("cat{j}"),
                column: Column::Categorical { values: vals, levels },
            });
        }
        let target = if classification {
            let classes = self.usize_in(2, 5) as u32;
            let labels: Vec<u32> =
                (0..n_rows).map(|_| self.usize_in(0, classes as usize - 1) as u32).collect();
            Target::Classification { labels, classes }
        } else {
            Target::Regression((0..n_rows).map(|_| self.f64_in(-100.0, 100.0)).collect())
        };
        Dataset { name: "prop".into(), features, target }
    }

    /// A leaf-only forest over `ds`'s schema: every tree is a single root
    /// leaf (the degenerate shape a `max_depth = 0` / pure-node training run
    /// produces), with fits drawn to match the target kind.
    pub fn leaf_only_forest(
        &mut self,
        ds: &crate::data::Dataset,
        n_trees: usize,
    ) -> crate::forest::Forest {
        use crate::forest::{Fit, Forest, Node, Tree};
        let classification = ds.target.is_classification();
        let classes = ds.target.num_classes();
        let trees = (0..n_trees)
            .map(|_| {
                let fit = if classification {
                    Fit::Class(self.usize_in(0, classes.max(1) as usize - 1) as u32)
                } else {
                    Fit::Regression(self.f64_in(-5.0, 5.0))
                };
                Tree { nodes: vec![Node { split: None, fit }] }
            })
            .collect();
        Forest { trees, classification, classes }
    }
}

/// Number of cases per property; override with `RF_PROP_CASES`.
pub fn default_cases() -> usize {
    std::env::var("RF_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` for `default_cases()` seeded cases; panics with the failing
/// seed/case on error. The closure returns `Result<(), String>` so
/// properties can explain *what* failed.
pub fn forall<F>(name: &str, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    forall_cases(name, default_cases(), &mut prop)
}

/// As [`forall`] with an explicit case count.
pub fn forall_cases<F>(name: &str, cases: usize, prop: &mut F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base_seed = 0xABCD_1234u64;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!("property {name:?} failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("u64_in bounds", |g| {
            let v = g.u64_in(3, 9);
            if (3..=9).contains(&v) {
                Ok(())
            } else {
                Err(format!("out of range: {v}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn forall_reports_failure() {
        forall("always fails", |_| Err("nope".into()));
    }

    #[test]
    fn probs_normalized() {
        forall("probs sum to 1", |g| {
            let len = g.usize_in(1, 50);
            let p = g.probs(len, 0.3);
            let s: f64 = p.iter().sum();
            if (s - 1.0).abs() < 1e-9 {
                Ok(())
            } else {
                Err(format!("sum={s}"))
            }
        });
    }

    #[test]
    fn counts_never_all_zero() {
        forall("counts nonzero", |g| {
            let c = g.counts(10, 100, 0.95);
            if c.iter().any(|&x| x > 0) {
                Ok(())
            } else {
                Err("all zero".into())
            }
        });
    }
}
