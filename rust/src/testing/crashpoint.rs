//! Crash-injection seam for multi-file commit protocols.
//!
//! The generation-chain manifest ([`crate::pack::generations`]) promises
//! all-or-nothing mutations: a crash at *any* instant of an
//! append/remove/compact leaves the chain readable as exactly the old or
//! exactly the new generation set. Code cannot be trusted to keep that
//! promise by inspection — it has to be driven through every crash window
//! and reopened. This module is the seam that makes those windows
//! reachable from tests without actually killing the process.
//!
//! A commit declares its crash points in protocol order
//! ([`CrashPoint::ALL`]) and calls [`CrashInjector::check`] as it passes
//! each one. A disarmed injector (the default, and the only state
//! production code ever sees) costs a single relaxed atomic load per
//! point. A test arms one point; the next commit that reaches it fails
//! with a typed error *right there*, leaving the filesystem in whatever
//! intermediate state the protocol had produced — exactly what a power
//! cut at that instant leaves behind. Firing disarms the injector
//! (one-shot), so the recovery path that reopens and retries runs clean.

use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU8, Ordering};

/// The declared crash windows of a write-tmp-then-rename commit, in
/// protocol order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CrashPoint {
    /// Before anything is written: the commit must be a pure no-op.
    PreTmp,
    /// Every `.tmp` file is written; nothing has been renamed into place.
    PostTmp,
    /// Payload files (e.g. a new generation pack) are renamed into place;
    /// the manifest rename — the commit point — has not happened.
    PreRename,
    /// The manifest rename landed: the new state is durable, but
    /// now-unreferenced old files have not been cleaned up yet.
    PostRename,
    /// Cleanup ran; the crash hits after the protocol finished.
    PostCleanup,
}

impl CrashPoint {
    /// Every crash point, in the order a commit traverses them.
    pub const ALL: [CrashPoint; 5] = [
        CrashPoint::PreTmp,
        CrashPoint::PostTmp,
        CrashPoint::PreRename,
        CrashPoint::PostRename,
        CrashPoint::PostCleanup,
    ];

    /// Stable name, used in the injected error and test diagnostics.
    pub fn name(self) -> &'static str {
        match self {
            CrashPoint::PreTmp => "pre-tmp",
            CrashPoint::PostTmp => "post-tmp",
            CrashPoint::PreRename => "pre-rename",
            CrashPoint::PostRename => "post-rename",
            CrashPoint::PostCleanup => "post-cleanup",
        }
    }

    fn code(self) -> u8 {
        match self {
            CrashPoint::PreTmp => 1,
            CrashPoint::PostTmp => 2,
            CrashPoint::PreRename => 3,
            CrashPoint::PostRename => 4,
            CrashPoint::PostCleanup => 5,
        }
    }

    fn from_code(code: u8) -> Option<CrashPoint> {
        CrashPoint::ALL.iter().copied().find(|p| p.code() == code)
    }
}

/// A one-shot crash trigger owned by the structure whose commits it can
/// interrupt (per-owner state, so parallel tests never race on a global).
#[derive(Debug, Default)]
pub struct CrashInjector {
    /// 0 = disarmed; otherwise the armed point's code.
    armed: AtomicU8,
}

impl CrashInjector {
    /// A disarmed injector.
    pub fn new() -> Self {
        Self::default()
    }

    /// Arm `point`: the next commit that reaches it fails there.
    pub fn arm(&self, point: CrashPoint) {
        self.armed.store(point.code(), Ordering::Relaxed);
    }

    /// Disarm without firing.
    pub fn disarm(&self) {
        self.armed.store(0, Ordering::Relaxed);
    }

    /// The currently armed point, if any.
    pub fn armed(&self) -> Option<CrashPoint> {
        CrashPoint::from_code(self.armed.load(Ordering::Relaxed))
    }

    /// Pass a declared crash point: `Err` (and disarm — one-shot) iff this
    /// exact point is armed. The error is typed and carries the point
    /// name, so tests can assert the simulated crash is the failure they
    /// injected and not a genuine bug on the same path.
    pub fn check(&self, point: CrashPoint) -> Result<()> {
        // a plain load first: the disarmed fast path never does a RMW
        if self.armed.load(Ordering::Relaxed) != point.code() {
            return Ok(());
        }
        if self
            .armed
            .compare_exchange(point.code(), 0, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
        {
            bail!("injected crash at {}", point.name());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_injector_passes_every_point() {
        let inj = CrashInjector::new();
        for p in CrashPoint::ALL {
            inj.check(p).unwrap();
        }
        assert_eq!(inj.armed(), None);
    }

    #[test]
    fn armed_point_fires_once_and_only_there() {
        let inj = CrashInjector::new();
        inj.arm(CrashPoint::PreRename);
        assert_eq!(inj.armed(), Some(CrashPoint::PreRename));
        // earlier points pass untouched
        inj.check(CrashPoint::PreTmp).unwrap();
        inj.check(CrashPoint::PostTmp).unwrap();
        let err = inj.check(CrashPoint::PreRename).unwrap_err().to_string();
        assert!(err.contains("injected crash at pre-rename"), "{err}");
        // one-shot: the retry passes clean
        assert_eq!(inj.armed(), None);
        inj.check(CrashPoint::PreRename).unwrap();
    }

    #[test]
    fn disarm_without_firing() {
        let inj = CrashInjector::new();
        inj.arm(CrashPoint::PostCleanup);
        inj.disarm();
        inj.check(CrashPoint::PostCleanup).unwrap();
    }
}
