//! Deterministic fault-injection harness for the router tests.
//!
//! [`ChaosProxy`] is a byte-level TCP proxy that sits between the router and
//! one backend and injects faults on demand:
//!
//! * [`Fault::None`] — transparent forwarding (the healthy baseline);
//! * [`Fault::Delay`] — every forwarded chunk sleeps first (latency spike);
//! * [`Fault::Blackhole`] — bytes are accepted and silently dropped in both
//!   directions (the peer hangs until its read deadline fires);
//! * [`Fault::Sever`] — every live connection is shut down and new ones are
//!   refused (a crashed backend / network partition).
//!
//! Faults flip at runtime via [`ChaosProxy::set_fault`]; [`ChaosProxy::sever`]
//! additionally tears down in-flight connections immediately (a blocked
//! `read` only notices a mode change when bytes arrive, so sever must
//! actively shut the sockets). [`ChaosSchedule`] derives a reproducible
//! fault sequence from a seed for soak-style tests.

use crate::util::rng::Pcg64;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

/// The fault a [`ChaosProxy`] currently injects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Forward bytes transparently.
    None,
    /// Sleep this long before forwarding each chunk.
    Delay(Duration),
    /// Accept bytes but forward nothing (peers stall on their deadlines).
    Blackhole,
    /// Shut down live connections and refuse new ones.
    Sever,
}

/// A controllable TCP proxy in front of one backend address.
pub struct ChaosProxy {
    addr: SocketAddr,
    fault: Arc<Mutex<Fault>>,
    shutdown: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
}

impl ChaosProxy {
    /// Bind an ephemeral local port and start proxying to `backend`.
    pub fn start(backend: SocketAddr) -> std::io::Result<ChaosProxy> {
        let listener = TcpListener::bind(("127.0.0.1", 0))?;
        let addr = listener.local_addr()?;
        let fault = Arc::new(Mutex::new(Fault::None));
        let shutdown = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        {
            let fault = fault.clone();
            let shutdown = shutdown.clone();
            let conns = conns.clone();
            thread::spawn(move || {
                for stream in listener.incoming() {
                    if shutdown.load(Ordering::Relaxed) {
                        break;
                    }
                    let Ok(client) = stream else { continue };
                    if *fault.lock().unwrap() == Fault::Sever {
                        let _ = client.shutdown(Shutdown::Both);
                        continue;
                    }
                    let Ok(upstream) = TcpStream::connect(backend) else {
                        let _ = client.shutdown(Shutdown::Both);
                        continue;
                    };
                    client.set_nodelay(true).ok();
                    upstream.set_nodelay(true).ok();
                    {
                        let mut held = conns.lock().unwrap();
                        held.push(client.try_clone().expect("clone proxied stream"));
                        held.push(upstream.try_clone().expect("clone upstream stream"));
                    }
                    let (c2, u2) = (
                        client.try_clone().expect("clone proxied stream"),
                        upstream.try_clone().expect("clone upstream stream"),
                    );
                    let f1 = fault.clone();
                    let f2 = fault.clone();
                    thread::spawn(move || pump(client, upstream, &f1));
                    thread::spawn(move || pump(u2, c2, &f2));
                }
            });
        }
        Ok(ChaosProxy { addr, fault, shutdown, conns })
    }

    /// The proxy's listen address — point the router's backend here.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Switch the injected fault (applies to in-flight and new connections;
    /// use [`ChaosProxy::sever`] to also tear down blocked connections).
    pub fn set_fault(&self, fault: Fault) {
        *self.fault.lock().unwrap() = fault;
    }

    /// Partition the backend: refuse new connections and immediately shut
    /// down every proxied connection, so blocked reads fail now rather than
    /// at their deadline.
    pub fn sever(&self) {
        self.set_fault(Fault::Sever);
        for conn in self.conns.lock().unwrap().drain(..) {
            let _ = conn.shutdown(Shutdown::Both);
        }
    }

    /// Heal the proxy: new connections forward transparently again.
    pub fn restore(&self) {
        self.set_fault(Fault::None);
    }

    /// Stop the accept loop and drop every proxied connection.
    pub fn stop(&self) {
        if self.shutdown.swap(true, Ordering::Relaxed) {
            return;
        }
        for conn in self.conns.lock().unwrap().drain(..) {
            let _ = conn.shutdown(Shutdown::Both);
        }
        // wake the blocking accept so the loop observes the flag
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(200));
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One direction of a proxied connection: read chunks from `src`, apply the
/// current fault, forward to `dst`. Exits on EOF, error, or sever.
fn pump(mut src: TcpStream, mut dst: TcpStream, fault: &Mutex<Fault>) {
    let mut buf = [0u8; 4096];
    loop {
        let n = match src.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => n,
        };
        let mode = *fault.lock().unwrap();
        match mode {
            Fault::Sever => break,
            Fault::Blackhole => continue,
            Fault::Delay(d) => {
                thread::sleep(d);
                if dst.write_all(&buf[..n]).is_err() {
                    break;
                }
            }
            Fault::None => {
                if dst.write_all(&buf[..n]).is_err() {
                    break;
                }
            }
        }
    }
    let _ = src.shutdown(Shutdown::Both);
    let _ = dst.shutdown(Shutdown::Both);
}

/// A reproducible fault timeline: `(hold_for, fault)` steps drawn from a
/// seeded [`Pcg64`]. Two schedules built from the same seed are identical,
/// so a chaos soak that fails can be replayed exactly.
pub struct ChaosSchedule {
    rng: Pcg64,
}

impl ChaosSchedule {
    /// A schedule deterministically derived from `seed`.
    pub fn new(seed: u64) -> Self {
        ChaosSchedule { rng: Pcg64::new(seed) }
    }

    /// Draw the next step: how long to hold the returned fault before
    /// drawing again. Healthy periods dominate (about half the steps), the
    /// rest split across delay, blackhole, and sever.
    pub fn next_step(&mut self) -> (Duration, Fault) {
        let hold = Duration::from_millis(20 + self.rng.gen_range(80));
        let fault = match self.rng.gen_range(8) {
            0..=3 => Fault::None,
            4 | 5 => Fault::Delay(Duration::from_millis(1 + self.rng.gen_range(20))),
            6 => Fault::Blackhole,
            _ => Fault::Sever,
        };
        (hold, fault)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader};

    /// A minimal line-echo server for exercising the proxy without the
    /// full coordinator stack.
    fn echo_server() -> (SocketAddr, Arc<AtomicBool>) {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        thread::spawn(move || {
            for stream in listener.incoming() {
                if flag.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                thread::spawn(move || {
                    let mut reader = BufReader::new(stream.try_clone().unwrap());
                    let mut writer = stream;
                    let mut line = String::new();
                    while let Ok(n) = reader.read_line(&mut line) {
                        if n == 0 || writer.write_all(line.as_bytes()).is_err() {
                            break;
                        }
                        line.clear();
                    }
                });
            }
        });
        (addr, stop)
    }

    fn round_trip(addr: SocketAddr, line: &str) -> std::io::Result<String> {
        let stream = TcpStream::connect_timeout(&addr, Duration::from_millis(500))?;
        stream.set_read_timeout(Some(Duration::from_millis(500)))?;
        let mut writer = stream.try_clone()?;
        writer.write_all(line.as_bytes())?;
        writer.write_all(b"\n")?;
        let mut reply = String::new();
        BufReader::new(stream).read_line(&mut reply)?;
        Ok(reply.trim_end().to_string())
    }

    #[test]
    fn transparent_then_severed_then_restored() {
        let (backend, stop) = echo_server();
        let proxy = ChaosProxy::start(backend).unwrap();

        assert_eq!(round_trip(proxy.addr(), "ping").unwrap(), "ping");

        proxy.sever();
        // either the connect is refused/reset or the read sees EOF — in no
        // case does a reply come back
        assert!(round_trip(proxy.addr(), "ping").map(|r| r.is_empty()).unwrap_or(true));

        proxy.restore();
        assert_eq!(round_trip(proxy.addr(), "pong").unwrap(), "pong");

        proxy.stop();
        stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect_timeout(&backend, Duration::from_millis(200));
    }

    #[test]
    fn blackhole_stalls_until_the_read_deadline() {
        let (backend, stop) = echo_server();
        let proxy = ChaosProxy::start(backend).unwrap();
        proxy.set_fault(Fault::Blackhole);

        let started = std::time::Instant::now();
        let out = round_trip(proxy.addr(), "ping");
        // the reply never arrives: the client's 500ms read deadline fires
        // (WouldBlock/TimedOut) or the line comes back empty
        assert!(out.map(|r| r.is_empty()).unwrap_or(true));
        assert!(
            started.elapsed() >= Duration::from_millis(300),
            "blackhole answered early: {:?}",
            started.elapsed()
        );

        proxy.stop();
        stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect_timeout(&backend, Duration::from_millis(200));
    }

    #[test]
    fn schedule_is_deterministic() {
        let mut a = ChaosSchedule::new(42);
        let mut b = ChaosSchedule::new(42);
        for _ in 0..32 {
            assert_eq!(a.next_step(), b.next_step());
        }
    }
}
