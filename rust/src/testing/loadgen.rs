//! `repro loadgen` — an open-loop, seed-replayable multi-tenant workload
//! generator speaking the real wire protocol.
//!
//! The generator is split into two halves so replay is trivial to reason
//! about:
//!
//! * **Trace generation** ([`generate_trace`]) is a pure function of a
//!   [`LoadgenConfig`]: same seed + scenario → byte-identical request trace
//!   ([`render_trace`]), every time, on every machine. Tenant popularity is
//!   Zipfian over a seed-shuffled rank permutation; arrivals are Poisson
//!   (exponential inter-arrival gaps) at the configured open-loop rate.
//! * **Trace execution** ([`run_trace`]) drives a live server — serial
//!   `PREDICT` lockstep or pipelined `PIPE` with a bounded client window —
//!   and measures latency against each request's *scheduled* send time, so
//!   a stalled server shows up as queueing delay instead of being absorbed
//!   by a slowed sender (the coordinated-omission trap a closed loop falls
//!   into). Latencies land in a log-bucketed [`Histogram`] for
//!   p50/p95/p99.
//!
//! Scenarios ([`Scenario`]) model the adversarial shapes the store's
//! admission policy has to survive: steady Zipf, diurnal rotation of the
//! popularity ranks, flash crowds onto cold tenants, one-pass scans over
//! the whole tenant population interleaved with a Zipfian hot set, and
//! cohort-correlated bursts where a pack's members spike together.

use crate::coordinator::server::{parse_pipe_reply, Client, PipeReply};
use crate::util::Pcg64;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Workload shape. Every scenario shares the same Zipfian base popularity
/// and Poisson arrivals; they differ in how tenant choice evolves over the
/// trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// Stationary Zipfian popularity — the baseline cache-friendly load.
    Steady,
    /// The popularity ranking rotates through four phases across the
    /// trace, like timezones handing traffic to each other: yesterday's
    /// hot tenants cool off and a different slice heats up.
    Diurnal,
    /// Two short windows send most traffic to a previously-cold tenant
    /// (a viral model): the admission policy must absorb a sudden new hot
    /// key without dropping the rest of the working set.
    FlashCrowd,
    /// Zipfian traffic over the hot set, interrupted at 40% of the trace
    /// by a contiguous sequential sweep over every tenant outside it — the
    /// classic LRU-killer a frequency-weighted policy exists to survive
    /// (contiguous because a scan only defeats recency when it outruns hot
    /// re-touches).
    Scan,
    /// Alternating burst windows concentrate traffic on one cohort of
    /// adjacent tenants at a time (a pack's members spike together).
    CohortBurst,
}

impl Scenario {
    /// Every scenario, in the order `--scenario` help lists them.
    pub const ALL: [Scenario; 5] = [
        Scenario::Steady,
        Scenario::Diurnal,
        Scenario::FlashCrowd,
        Scenario::Scan,
        Scenario::CohortBurst,
    ];

    /// Parse the CLI spelling. Returns `None` for unknown names so the
    /// caller can print its own usage error.
    pub fn parse(s: &str) -> Option<Scenario> {
        match s {
            "steady" => Some(Scenario::Steady),
            "diurnal" => Some(Scenario::Diurnal),
            "flash_crowd" => Some(Scenario::FlashCrowd),
            "scan" => Some(Scenario::Scan),
            "cohort_burst" => Some(Scenario::CohortBurst),
            _ => None,
        }
    }

    /// The CLI spelling (inverse of [`Scenario::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::Steady => "steady",
            Scenario::Diurnal => "diurnal",
            Scenario::FlashCrowd => "flash_crowd",
            Scenario::Scan => "scan",
            Scenario::CohortBurst => "cohort_burst",
        }
    }
}

/// Everything that determines a trace. Two equal configs generate
/// byte-identical traces (the replay contract the property suite pins).
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Replay seed: the single source of randomness.
    pub seed: u64,
    /// Workload shape.
    pub scenario: Scenario,
    /// Number of tenants (distinct model names the trace addresses).
    pub tenants: usize,
    /// Total requests in the trace.
    pub requests: usize,
    /// Open-loop arrival rate, requests per second.
    pub rate: f64,
    /// Zipf exponent of the popularity distribution (≈1.0 is the classic
    /// web-cache shape; higher skews harder).
    pub zipf_s: f64,
    /// Size of the hot set: the `scan` scenario directs its non-scan
    /// traffic at the top `hot_set` popularity ranks, and
    /// [`hot_tenants`] reports which tenants those are.
    pub hot_set: usize,
    /// `cohort_burst`: tenants per cohort (adjacent tenant ids spike
    /// together, modeling one pack's members).
    pub cohort: usize,
}

impl LoadgenConfig {
    /// Full-size defaults for a scenario (200 tenants, 20 k requests at
    /// 1 k/s). `--quick` runs shrink these via [`LoadgenConfig::quick`].
    pub fn new(scenario: Scenario) -> Self {
        LoadgenConfig {
            seed: 42,
            scenario,
            tenants: 200,
            requests: 20_000,
            rate: 1000.0,
            zipf_s: 1.1,
            hot_set: 20,
            cohort: 8,
        }
    }

    /// CI-sized defaults: 32 tenants, 1500 requests at 2 k/s (a run
    /// completes in about a second).
    pub fn quick(scenario: Scenario) -> Self {
        LoadgenConfig {
            tenants: 32,
            requests: 1500,
            rate: 2000.0,
            hot_set: 6,
            ..Self::new(scenario)
        }
    }
}

/// One scheduled request of a trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    /// Scheduled send time, µs from trace start (non-decreasing).
    pub at_us: u64,
    /// Tenant index in `0..tenants` (maps onto a model name at run time).
    pub tenant: u32,
}

/// The loadgen RNG stream tag (every derived generator forks off this).
const LOADGEN_STREAM: u64 = 0x10ad_9e64;

fn root_rng(cfg: &LoadgenConfig) -> Pcg64 {
    Pcg64::with_stream(cfg.seed, LOADGEN_STREAM)
}

/// The seed-shuffled popularity permutation: `perm[rank] = tenant`, so the
/// most popular tenant is `perm[0]`. Derived from its own RNG split, so it
/// can be recomputed standalone (e.g. by [`hot_tenants`]) without
/// disturbing trace generation.
pub fn rank_to_tenant(cfg: &LoadgenConfig) -> Vec<u32> {
    let n = cfg.tenants.max(1);
    let mut perm: Vec<u32> = (0..n as u32).collect();
    root_rng(cfg).split(1).shuffle(&mut perm);
    perm
}

/// The tenants a warm-up should make resident: the top `hot_set`
/// popularity ranks of this config.
pub fn hot_tenants(cfg: &LoadgenConfig) -> Vec<u32> {
    let hot = cfg.hot_set.clamp(1, cfg.tenants.max(1));
    rank_to_tenant(cfg)[..hot].to_vec()
}

/// Inverse-CDF sampler over Zipf(s) ranks `0..n` (rank 0 most popular).
struct ZipfCdf {
    cdf: Vec<f64>,
}

impl ZipfCdf {
    fn new(n: usize, s: f64) -> ZipfCdf {
        let mut cdf = Vec::with_capacity(n.max(1));
        let mut acc = 0.0;
        for r in 0..n.max(1) {
            acc += 1.0 / ((r + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc.max(f64::MIN_POSITIVE);
        for c in &mut cdf {
            *c /= total;
        }
        ZipfCdf { cdf }
    }

    fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.gen_f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

/// Generate the full request trace for a config — pure and deterministic:
/// equal configs produce identical traces.
pub fn generate_trace(cfg: &LoadgenConfig) -> Vec<Request> {
    let n = cfg.tenants.max(1);
    let perm = rank_to_tenant(cfg);
    let mut rng = root_rng(cfg).split(2);
    let zipf = ZipfCdf::new(n, cfg.zipf_s);
    let hot = cfg.hot_set.clamp(1, n);
    let zipf_hot = ZipfCdf::new(hot, cfg.zipf_s);
    // the scan sweeps every tenant OUTSIDE the hot set once, in id order
    let hot_set: std::collections::BTreeSet<u32> = perm[..hot].iter().copied().collect();
    let scan_list: Vec<u32> = (0..n as u32).filter(|t| !hot_set.contains(t)).collect();
    // the sweep is CONTIGUOUS, starting at 40% of the trace: a scan only
    // defeats LRU when its items arrive faster than the hot set is
    // re-touched, so spreading them out would blunt the very adversary
    // this scenario exists to model
    let sweep_start = cfg.requests * 2 / 5;
    let mut scan_idx = 0usize;
    let cohort = cfg.cohort.clamp(1, n);
    let num_cohorts = (n / cohort).max(1);
    let mean_gap_us = 1e6 / cfg.rate.max(1e-6);

    let mut at_us = 0u64;
    let mut out = Vec::with_capacity(cfg.requests);
    for i in 0..cfg.requests {
        // Poisson arrivals: exponential gaps ((1 - u) ∈ (0, 1], so the log
        // argument never hits zero)
        at_us += (-(1.0 - rng.gen_f64()).ln() * mean_gap_us) as u64;
        let tenant = match cfg.scenario {
            Scenario::Steady => perm[zipf.sample(&mut rng)],
            Scenario::Diurnal => {
                // four phases; each shifts the popularity ranking by a
                // quarter of the tenant population
                let phase = (i * 4 / cfg.requests.max(1)).min(3);
                let rot = phase * (n / 4);
                perm[(zipf.sample(&mut rng) + rot) % n]
            }
            Scenario::FlashCrowd => {
                // two burst windows at 30–40% and 60–70% of the trace,
                // each aimed at a cold rank (the bottom of the ranking)
                let frac = i * 10 / cfg.requests.max(1);
                let crowd = match frac {
                    3 => Some(perm[n - 1]),
                    6 => Some(perm[n.saturating_sub(2).max(1) - 1]),
                    _ => None,
                };
                match crowd {
                    Some(t) if rng.gen_bool(0.7) => t,
                    _ => perm[zipf.sample(&mut rng)],
                }
            }
            Scenario::Scan => {
                if i >= sweep_start && scan_idx < scan_list.len() {
                    scan_idx += 1;
                    scan_list[scan_idx - 1]
                } else {
                    perm[zipf_hot.sample(&mut rng)]
                }
            }
            Scenario::CohortBurst => {
                // alternating eighths of the trace burst onto one cohort
                let eighth = (i * 8 / cfg.requests.max(1)).min(7);
                if eighth % 2 == 1 && rng.gen_bool(0.6) {
                    let c = (eighth / 2) % num_cohorts;
                    (c * cohort + rng.gen_index(cohort)) as u32
                } else {
                    perm[zipf.sample(&mut rng)]
                }
            }
        };
        out.push(Request { at_us, tenant });
    }
    out
}

/// Render a trace to its canonical text form — the replay artifact
/// (`--trace-out`) and the byte-identity oracle CI compares.
pub fn render_trace(cfg: &LoadgenConfig, trace: &[Request]) -> String {
    let mut s = format!(
        "# loadgen trace seed={} scenario={} tenants={} requests={} rate={} zipf_s={} \
         hot_set={} cohort={}\n",
        cfg.seed,
        cfg.scenario.name(),
        cfg.tenants,
        cfg.requests,
        cfg.rate,
        cfg.zipf_s,
        cfg.hot_set,
        cfg.cohort
    );
    for r in trace {
        s.push_str(&format!("{} {}\n", r.at_us, r.tenant));
    }
    s
}

/// Split a trace's request count into (hot, cold) by hot-set membership —
/// the denominators of [`hot_hit_rate`].
pub fn split_hot_cold(trace: &[Request], hot: &[u32]) -> (u64, u64) {
    let set: std::collections::BTreeSet<u32> = hot.iter().copied().collect();
    let h = trace.iter().filter(|r| set.contains(&r.tenant)).count() as u64;
    (h, trace.len() as u64 - h)
}

/// Hot-set hit rate from STATS deltas, the scan-resistance metric: each of
/// the `cold_requests` (the scan) accounts for at most one tier promotion,
/// so any promotion beyond those displaced — and re-promoted — a hot-set
/// model. `promotions_delta` is the run's `reloads + pack_loads` delta.
/// Clamped to `[0, 1]`; an empty hot window reports 1.0.
pub fn hot_hit_rate(hot_requests: u64, cold_requests: u64, promotions_delta: u64) -> f64 {
    if hot_requests == 0 {
        return 1.0;
    }
    let hot_misses = promotions_delta.saturating_sub(cold_requests);
    (1.0 - hot_misses as f64 / hot_requests as f64).clamp(0.0, 1.0)
}

/// Latency histogram, re-exported from the shared observability layer
/// (one bucket scheme for loadgen reports and the server's `METRICS`
/// exposition alike): exact below 8 µs, then eight sub-buckets per power
/// of two (≤ 12.5% relative bucket width). Atomic, so reader threads
/// record through a shared reference without a lock.
pub use crate::obs::Histogram;

/// How [`run_trace`] speaks to the server.
#[derive(Debug, Clone)]
pub struct RunOptions {
    /// Pipelined `PIPE <id> PREDICT` (default) vs serial lockstep
    /// `PREDICT`.
    pub pipe: bool,
    /// Wire-encoded observation values sent with every `PREDICT` (see
    /// [`crate::coordinator::server::values_to_wire`]).
    pub values: String,
    /// Max client-side outstanding requests in pipelined mode. The
    /// arrival schedule still sets send times; a full window blocks the
    /// sender, which then shows up as *latency* — bounded open loop, not
    /// a closed loop.
    pub window: usize,
    /// TCP connect timeout.
    pub connect_timeout: Duration,
    /// Socket read timeout for replies (a hung server errors the run out
    /// instead of wedging it).
    pub io_timeout: Duration,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions {
            pipe: true,
            values: String::new(),
            window: 128,
            connect_timeout: Duration::from_secs(2),
            io_timeout: Duration::from_secs(30),
        }
    }
}

/// What one executed trace measured.
#[derive(Debug, Clone, Copy)]
pub struct RunReport {
    /// Requests sent.
    pub sent: u64,
    /// `OK` replies received.
    pub ok: u64,
    /// `ERR` replies (typed errors, timeouts, busy) plus unparseable lines.
    pub errors: u64,
    /// Median latency, µs from *scheduled* send to reply.
    pub p50_us: u64,
    /// 95th-percentile latency, µs.
    pub p95_us: u64,
    /// 99th-percentile latency, µs.
    pub p99_us: u64,
    /// Worst latency, µs (exact).
    pub max_us: u64,
    /// Wall-clock duration of the run, seconds.
    pub elapsed_s: f64,
}

impl RunReport {
    fn from_hist(hist: &Histogram, sent: u64, ok: u64, errors: u64, elapsed_s: f64) -> RunReport {
        RunReport {
            sent,
            ok,
            errors,
            p50_us: hist.quantile(0.50),
            p95_us: hist.quantile(0.95),
            p99_us: hist.quantile(0.99),
            max_us: hist.max(),
            elapsed_s,
        }
    }
}

/// State the pipelined sender and reply reader share.
struct RunShared {
    outstanding: Mutex<usize>,
    cv: Condvar,
    /// Atomic buckets: the reader thread records without a lock.
    hist: Histogram,
    ok: AtomicU64,
    errors: AtomicU64,
    /// Reader exited before every reply arrived (connection died): the
    /// sender must stop blocking on the window and bail.
    dead: AtomicBool,
}

/// Execute a trace against a live server at `addr`. `models[t % len]`
/// names the model tenant `t` addresses; `opts.values` rides every
/// `PREDICT`. Latency is measured from each request's **scheduled** time.
pub fn run_trace(
    addr: SocketAddr,
    models: &[String],
    trace: &[Request],
    opts: &RunOptions,
) -> Result<RunReport> {
    if models.is_empty() {
        bail!("run_trace needs at least one model name");
    }
    if trace.is_empty() {
        return Ok(RunReport::from_hist(&Histogram::new(), 0, 0, 0, 0.0));
    }
    if opts.pipe {
        run_pipelined(addr, models, trace, opts)
    } else {
        run_serial(addr, models, trace, opts)
    }
}

/// Sleep until `start + at_us` (no-op when already past — the open loop
/// sends late rather than thinning the schedule).
fn pace(start: Instant, at_us: u64) {
    let sched = Duration::from_micros(at_us);
    let now = start.elapsed();
    if now < sched {
        std::thread::sleep(sched - now);
    }
}

fn run_pipelined(
    addr: SocketAddr,
    models: &[String],
    trace: &[Request],
    opts: &RunOptions,
) -> Result<RunReport> {
    let stream = TcpStream::connect_timeout(&addr, opts.connect_timeout)
        .with_context(|| format!("loadgen connecting to {addr}"))?;
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(opts.io_timeout))
        .context("setting loadgen read timeout")?;
    let mut writer = stream.try_clone().context("cloning loadgen socket")?;
    let at_us: Arc<Vec<u64>> = Arc::new(trace.iter().map(|r| r.at_us).collect());
    let shared = Arc::new(RunShared {
        outstanding: Mutex::new(0),
        cv: Condvar::new(),
        hist: Histogram::new(),
        ok: AtomicU64::new(0),
        errors: AtomicU64::new(0),
        dead: AtomicBool::new(false),
    });
    let start = Instant::now();
    let reader = {
        let shared = shared.clone();
        let at_us = at_us.clone();
        let total = trace.len();
        std::thread::spawn(move || reader_loop(stream, &at_us, &shared, start, total))
    };
    let window = opts.window.max(1);
    for (i, req) in trace.iter().enumerate() {
        pace(start, req.at_us);
        {
            let mut g = shared.outstanding.lock().unwrap();
            while *g >= window && !shared.dead.load(Ordering::Relaxed) {
                g = shared.cv.wait(g).unwrap();
            }
            if shared.dead.load(Ordering::Relaxed) {
                bail!("loadgen connection died after {i} of {} requests", trace.len());
            }
            *g += 1;
        }
        let model = &models[req.tenant as usize % models.len()];
        writer
            .write_all(format!("PIPE {i} PREDICT {model} {}\n", opts.values).as_bytes())
            .with_context(|| format!("loadgen send (request {i})"))?;
    }
    // QUIT drains every in-flight reply, then the server closes: the
    // reader sees all replies followed by EOF
    let _ = writer.write_all(b"QUIT\n");
    let _ = reader.join();
    let elapsed_s = start.elapsed().as_secs_f64();
    Ok(RunReport::from_hist(
        &shared.hist,
        trace.len() as u64,
        shared.ok.load(Ordering::Relaxed),
        shared.errors.load(Ordering::Relaxed),
        elapsed_s,
    ))
}

/// Drain pipelined replies, attributing each to its scheduled send time.
fn reader_loop(
    stream: TcpStream,
    at_us: &[u64],
    shared: &RunShared,
    start: Instant,
    total: usize,
) {
    let reader = BufReader::new(stream);
    let mut done = 0usize;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        let now = start.elapsed().as_micros() as u64;
        match parse_pipe_reply(&line) {
            Ok(PipeReply::Ok { id, .. }) => {
                let sched = at_us.get(id as usize).copied().unwrap_or(now);
                shared.hist.record(now.saturating_sub(sched));
                shared.ok.fetch_add(1, Ordering::Relaxed);
            }
            // errors count but do not pollute the latency distribution
            Ok(PipeReply::Err { .. }) | Err(_) => {
                shared.errors.fetch_add(1, Ordering::Relaxed);
            }
        }
        {
            let mut g = shared.outstanding.lock().unwrap();
            *g = g.saturating_sub(1);
            shared.cv.notify_one();
        }
        done += 1;
        if done >= total {
            break;
        }
    }
    if done < total {
        shared.dead.store(true, Ordering::Relaxed);
    }
    shared.cv.notify_all();
}

fn run_serial(
    addr: SocketAddr,
    models: &[String],
    trace: &[Request],
    opts: &RunOptions,
) -> Result<RunReport> {
    let mut client =
        Client::connect_timeout(addr, opts.connect_timeout).context("loadgen connecting")?;
    client.set_deadlines(Some(opts.io_timeout), Some(opts.io_timeout))?;
    let hist = Histogram::new();
    let (mut ok, mut errors) = (0u64, 0u64);
    let start = Instant::now();
    for req in trace {
        pace(start, req.at_us);
        let model = &models[req.tenant as usize % models.len()];
        let reply = client.request(&format!("PREDICT {model} {}", opts.values))?;
        let now = start.elapsed().as_micros() as u64;
        if reply.starts_with("OK") {
            hist.record(now.saturating_sub(req.at_us));
            ok += 1;
        } else {
            errors += 1;
        }
    }
    let _ = client.send("QUIT");
    Ok(RunReport::from_hist(&hist, trace.len() as u64, ok, errors, start.elapsed().as_secs_f64()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(scenario: Scenario, seed: u64) -> LoadgenConfig {
        LoadgenConfig { seed, requests: 600, tenants: 24, ..LoadgenConfig::quick(scenario) }
    }

    #[test]
    fn traces_are_deterministic_and_well_formed() {
        for scenario in Scenario::ALL {
            let cfg = quick(scenario, 7);
            let a = generate_trace(&cfg);
            let b = generate_trace(&cfg);
            assert_eq!(
                render_trace(&cfg, &a),
                render_trace(&cfg, &b),
                "{scenario:?}: same config must replay byte-identically"
            );
            assert_eq!(a.len(), cfg.requests);
            let mut last = 0;
            for r in &a {
                assert!(r.at_us >= last, "{scenario:?}: arrivals must be non-decreasing");
                assert!((r.tenant as usize) < cfg.tenants, "{scenario:?}: tenant in range");
                last = r.at_us;
            }
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let a = generate_trace(&quick(Scenario::FlashCrowd, 1));
        let b = generate_trace(&quick(Scenario::FlashCrowd, 2));
        assert_ne!(a, b, "different seeds must generate different traces");
    }

    #[test]
    fn zipf_is_top_heavy_and_permuted() {
        let cfg = quick(Scenario::Steady, 11);
        let trace = generate_trace(&cfg);
        let perm = rank_to_tenant(&cfg);
        let count = |t: u32| trace.iter().filter(|r| r.tenant == t).count();
        assert!(
            count(perm[0]) > count(perm[cfg.tenants - 1]) + 5,
            "rank 0 must dominate the tail"
        );
        // the permutation really shuffles: top tenant is rarely id 0 for
        // this seed (pinned, not probabilistic — the trace is a function)
        assert_eq!(perm.len(), cfg.tenants);
    }

    #[test]
    fn scan_covers_every_non_hot_tenant_once() {
        let cfg = quick(Scenario::Scan, 13);
        let trace = generate_trace(&cfg);
        let hot = hot_tenants(&cfg);
        let hot_set: std::collections::BTreeSet<u32> = hot.iter().copied().collect();
        for t in 0..cfg.tenants as u32 {
            if !hot_set.contains(&t) {
                assert_eq!(
                    trace.iter().filter(|r| r.tenant == t).count(),
                    1,
                    "scan tenant {t} must be touched exactly once"
                );
            }
        }
        let (h, c) = split_hot_cold(&trace, &hot);
        assert_eq!(c as usize, cfg.tenants - hot.len());
        assert_eq!(h as usize + c as usize, cfg.requests);
    }

    #[test]
    fn flash_crowd_concentrates_inside_its_window() {
        let cfg = quick(Scenario::FlashCrowd, 17);
        let trace = generate_trace(&cfg);
        let crowd = rank_to_tenant(&cfg)[cfg.tenants - 1];
        let window: Vec<_> =
            trace.iter().enumerate().filter(|(i, _)| i * 10 / cfg.requests == 3).collect();
        let inside = window.iter().filter(|(_, r)| r.tenant == crowd).count();
        assert!(
            inside * 2 > window.len(),
            "the crowd tenant must take most of its burst window \
             ({inside}/{})",
            window.len()
        );
    }

    // histogram quantile tests live with the shared implementation in
    // crate::obs::metrics

    #[test]
    fn hot_hit_rate_formula() {
        // 900 hot requests, 100 scans, 100 promotions: every promotion was
        // a scan item — no hot miss
        assert_eq!(hot_hit_rate(900, 100, 100), 1.0);
        // 190 promotions: 90 of them re-promoted displaced hot models
        let r = hot_hit_rate(900, 100, 190);
        assert!((r - 0.9).abs() < 1e-9, "{r}");
        assert_eq!(hot_hit_rate(0, 10, 10), 1.0, "no hot window reads perfect");
        assert_eq!(hot_hit_rate(10, 0, 1000), 0.0, "clamped at zero");
    }
}
