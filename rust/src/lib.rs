//! # rf-compress
//!
//! Lossless (and lossy) compression of random forests — a reproduction of
//! Painsky & Rosset (2018), *"Lossless (and Lossy) Compression of Random
//! Forests"*.
//!
//! The library is organized as the paper's pipeline (eq. 1):
//!
//! ```text
//! P(tree) = P(structure) · P(nodes | structure) · P(leaves | nodes, structure)
//! ```
//!
//! * [`zaks`]   — tree-structure coding (Zaks sequences, §3.1)
//! * [`model`]  — conditional empirical distributions of variable names /
//!   split values / fits, keyed by `(depth, father)` (§3.2, §3.3)
//! * [`cluster`] — weighted-KL Bregman k-means over those distributions with
//!   a dictionary-cost penalty (eq. 6)
//! * [`compress`] — Algorithm 1: the end-to-end lossless codec, container
//!   format, and prediction straight from the compressed bytes (§5)
//! * [`lossy`]  — tree subsampling + fit quantization with the paper's
//!   rate/distortion guarantees (§7)
//!
//! Substrates built in-tree (the environment is offline; see `DESIGN.md`):
//!
//! * [`forest`] — CART trees + random-forest training (Matlab `treeBagger`
//!   semantics: unpruned, per-node fits) and completely-randomized trees
//! * [`coding`] — bit I/O, canonical Huffman, arithmetic coding, LZSS,
//!   entropy/KL utilities
//! * [`data`]   — dataset container, CSV loader, and synthetic generators
//!   standing in for the paper's UCI/Kaggle datasets
//! * [`baseline`] — the paper's "standard" and "light" gzip comparators
//! * [`runtime`] — PJRT client loading AOT-compiled JAX/Pallas artifacts
//!   (the clustering hot path), with a native fallback
//! * [`coordinator`] — the L3 system: parallel compression pipeline, a
//!   model-store prediction server answering from compressed forests, and
//!   a health-checked shard router fanning one protocol out over a fleet
//! * [`pack`]   — `RFPK` model packs: many-tenant archives with shared
//!   cross-forest codebooks, served zero-copy as the store's third tier
//! * [`obs`]    — in-process observability: lock-free metrics registry,
//!   per-request phase spans, and the slow-request ring behind the
//!   `METRICS`/`SLOW` verbs
//! * [`util`]   — RNG, stats, CLI, thread pool
//! * [`testing`] — in-tree property-testing mini-framework and the
//!   deterministic fault-injection proxy behind the partition tests
//!
//! ## Quickstart
//!
//! ```no_run
//! use rf_compress::data::synthetic;
//! use rf_compress::forest::{Forest, ForestParams};
//! use rf_compress::compress::{CompressOptions, CompressedForest};
//!
//! let ds = synthetic::airfoil_classification(42);
//! let forest = Forest::train(&ds, &ForestParams::classification(50), 7);
//! let cf = CompressedForest::compress(&forest, &ds, &CompressOptions::default()).unwrap();
//! let restored = cf.decompress().unwrap();
//! assert!(forest.identical(&restored));
//! ```

#![warn(missing_docs)]

pub mod baseline;
pub mod cluster;
pub mod coding;
pub mod compress;
pub mod coordinator;
pub mod data;
pub mod forest;
pub mod lossy;
pub mod model;
pub mod obs;
pub mod pack;
pub mod runtime;
pub mod testing;
pub mod util;
pub mod zaks;
