//! Model packs (`RFPK`) — many-tenant archives of compressed forests.
//!
//! The paper's motivating deployment is subscriber-scale: **millions of
//! user-specific ensembles, each small, each needing cheap storage** (§1).
//! Below ~4 KiB per model, the per-file overhead — filesystem page
//! granularity, inode metadata, one `open`+`mmap` per reload — dominates the
//! model bytes themselves. A pack amortizes all of it:
//!
//! * [`format`] — the `RFPK` archive: a directory index (model key →
//!   offset/len span), per-model `RFCZ` payloads stored verbatim, and an
//!   optional **shared-codebook section** holding deduplicated
//!   side-information blobs (TABLES + CLUSMAP + DICTS) that byte-identical
//!   members reference instead of carrying their own. Extraction splices the
//!   blob back — reconstruction is **bit-identical** to the source container.
//! * [`shared`] — cohort compression: run the existing [`crate::cluster`]
//!   machinery once across the **union** of every member forest's tree-model
//!   tables ([`crate::compress::CodecPlan`]), then encode each member
//!   against the shared codebooks. Members then serialize byte-identical
//!   side-information sections by construction, which is what the pack's
//!   dedup collapses to a single copy.
//!
//! Serving: one `mmap` of a pack serves every member zero-copy — a member is
//! parsed straight out of the mapping through a pack-relative
//! [`crate::compress::SharedBytes`] view ([`PackArchive::parse_member`]).
//! The model store mounts packs as a third tier (Resident → Spilled →
//! **Packed**): members load without per-model spill files and evict by
//! *releasing* back to the pack — no disk write, the archive keeps the bytes
//! ([`crate::coordinator::store::ModelStore::attach_pack`]).

//! Mutability: a pack can also live as a **generation chain** ([`generations`])
//! — the immutable base plus delta packs and tombstones under a crash-safe
//! manifest — with [`compact`] merging the chain back into a fresh base.

pub mod compact;
pub mod format;
pub mod generations;
pub mod shared;

pub use compact::{compact_chain, CompactMode, CompactStats};
pub use format::{PackArchive, PackBuilder, PackStats};
pub use generations::{ChainStats, PackChain};
pub use shared::{compress_cohort, compress_cohort_with_engine};
