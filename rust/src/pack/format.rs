//! The `RFPK` archive format.
//!
//! ```text
//! ┌─────────┬──────────────────────────────────────────────────────────┐
//! │ HEADER  │ magic "RFPK", version, member count, blob count          │
//! │ INDEX   │ per member: key, storage mode, stored length,            │
//! │         │ (shared mode) blob id + splice position                  │
//! │ BLOBS   │ shared-codebook section: deduplicated side-information   │
//! │         │ byte blobs (TABLES + CLUSMAP + DICTS of ≥ 2 members)     │
//! │ PAYLOAD │ per-member stored bytes, concatenated                    │
//! └─────────┴──────────────────────────────────────────────────────────┘
//! ```
//!
//! Two storage modes per member:
//!
//! * **verbatim** — the member's `RFCZ` container bytes, unmodified. Parsing
//!   is [`crate::compress::container::parse_arc`] over a pack-relative
//!   [`SharedBytes`] view; extraction is a plain copy.
//! * **shared** — the member's side-information span (everything between the
//!   header and the STRUCT section; see
//!   [`crate::compress::container::ParsedContainer::side_info_span`]) is
//!   excised into a pack-level blob that every byte-identical member
//!   references. The stored payload is `header ++ struct ++ payloads`,
//!   still contiguous, so the big per-tree streams parse zero-copy off the
//!   pack mapping via [`crate::compress::container::parse_packed`];
//!   extraction splices `head ++ blob ++ tail` — **bit-identical** to the
//!   source container by construction.
//!
//! The builder only assigns a member to a blob when the bytes match
//! *exactly* (losslessness is never traded for sharing); producing members
//! that actually share bytes is [`crate::pack::shared::compress_cohort`]'s
//! job. Offsets in the index are implicit — stored lengths accumulate in
//! index order — so the directory stays a few bytes per member.

use crate::coding::bitio::{BitReader, BitWriter};
use crate::compress::container::{cast_usize, parse_arc, parse_packed, ParsedContainer};
use crate::compress::SharedBytes;
use crate::util::mmap::Mmap;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::Arc;

/// Archive file magic (`RFPK`).
pub const PACK_MAGIC: &[u8; 4] = b"RFPK";
/// Archive format version this build reads and writes.
pub const PACK_VERSION: u8 = 1;

/// Storage-mode tags in the index.
const MODE_VERBATIM: u64 = 0;
const MODE_SHARED: u64 = 1;

/// Longest accepted member key (bytes).
const MAX_KEY_LEN: usize = 4096;

/// Shared key rules, enforced by builder AND reader: keys travel over the
/// space-delimited wire protocol (whitespace/control would make a member
/// unaddressable) and become filenames under `pack extract --out-dir`
/// (separators or `..` would let a hostile archive write outside the
/// output directory).
fn validate_key(key: &str) -> Result<()> {
    if key.is_empty() || key.len() > MAX_KEY_LEN {
        bail!("pack key must be 1..={MAX_KEY_LEN} bytes, got {}", key.len());
    }
    if key.chars().any(|c| c.is_whitespace() || c.is_control()) {
        bail!("pack key {key:?} may not contain whitespace or control characters");
    }
    if key.contains('/') || key.contains('\\') {
        bail!("pack key {key:?} may not contain path separators");
    }
    if key == "." || key == ".." {
        bail!("pack key {key:?} is not allowed");
    }
    Ok(())
}

/// Build-time summary of an archive (also printed by `repro pack build`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PackStats {
    /// Number of members in the archive.
    pub members: usize,
    /// Shared-codebook blobs in the archive.
    pub blobs: usize,
    /// Members stored in shared mode (side info excised).
    pub shared_members: usize,
    /// Total archive size in bytes.
    pub archive_bytes: u64,
    /// Sum of the members' standalone container sizes.
    pub logical_bytes: u64,
    /// Bytes the shared-codebook dedup removed versus storing every member
    /// verbatim in the archive.
    pub shared_saved_bytes: u64,
}

struct PendingMember {
    key: String,
    bytes: Arc<[u8]>,
    /// Side-information span within `bytes` (exact byte boundaries).
    side: (usize, usize),
}

/// Assembles an `RFPK` archive from validated `RFCZ` containers.
pub struct PackBuilder {
    members: Vec<PendingMember>,
    shared: bool,
}

impl PackBuilder {
    /// New builder with shared-codebook dedup enabled.
    pub fn new() -> Self {
        PackBuilder { members: Vec::new(), shared: true }
    }

    /// Toggle the shared-codebook section (`false` stores every member
    /// verbatim; round-trips are bit-identical either way).
    pub fn shared(mut self, on: bool) -> Self {
        self.shared = on;
        self
    }

    /// Add a member under `key`. The container is fully parsed here — a
    /// corrupt member fails the build, not some later reader — and its
    /// side-information span is recorded for the dedup pass.
    pub fn add(&mut self, key: &str, bytes: impl Into<Arc<[u8]>>) -> Result<()> {
        validate_key(key)?;
        if self.members.iter().any(|m| m.key == key) {
            bail!("duplicate pack key {key:?}");
        }
        let bytes: Arc<[u8]> = bytes.into();
        let pc = parse_arc(bytes.clone())
            .with_context(|| format!("pack member {key:?} is not a valid RFCZ container"))?;
        let side = pc.side_info_span();
        self.members.push(PendingMember { key: key.to_string(), bytes, side });
        Ok(())
    }

    /// Number of members added so far.
    pub fn len(&self) -> usize {
        self.members.len()
    }

    /// Whether no members were added yet.
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Serialize the archive. Members whose side-information bytes are
    /// byte-identical to at least one other member's share a single blob;
    /// everyone else is stored verbatim.
    pub fn build(&self) -> Result<(Vec<u8>, PackStats)> {
        if self.members.is_empty() {
            bail!("cannot build an empty pack");
        }

        // dedup pass: side-info bytes → (first-appearance order id, count)
        let mut seen: HashMap<&[u8], (usize, usize)> = HashMap::new();
        let mut order: Vec<&[u8]> = Vec::new();
        if self.shared {
            for m in &self.members {
                let span = &m.bytes[m.side.0..m.side.1];
                if span.is_empty() {
                    continue;
                }
                match seen.get_mut(span) {
                    Some((_, count)) => *count += 1,
                    None => {
                        seen.insert(span, (order.len(), 1));
                        order.push(span);
                    }
                }
            }
        }
        // only spans shared by ≥ 2 members become blobs (a singleton would
        // trade index overhead for nothing)
        let mut blob_id: HashMap<&[u8], u64> = HashMap::new();
        let mut blobs: Vec<&[u8]> = Vec::new();
        for span in &order {
            if seen[span].1 >= 2 {
                blob_id.insert(span, blobs.len() as u64);
                blobs.push(span);
            }
        }

        let mut w = BitWriter::new();
        for &b in PACK_MAGIC {
            w.write_byte(b);
        }
        w.write_bits(PACK_VERSION as u64, 8);
        w.write_varint(self.members.len() as u64);
        w.write_varint(blobs.len() as u64);
        w.align_byte();

        // ---- INDEX ----
        let mut stats = PackStats {
            members: self.members.len(),
            blobs: blobs.len(),
            ..Default::default()
        };
        for m in &self.members {
            let span = &m.bytes[m.side.0..m.side.1];
            let shared = blob_id.get(span).copied();
            w.write_varint(m.key.len() as u64);
            w.write_bytes(m.key.as_bytes());
            stats.logical_bytes += m.bytes.len() as u64;
            match shared {
                Some(id) => {
                    let stored_len = m.bytes.len() - span.len();
                    w.write_bits(MODE_SHARED, 8);
                    w.write_varint(stored_len as u64);
                    w.write_varint(id);
                    w.write_varint(m.side.0 as u64); // splice position = head length
                    stats.shared_members += 1;
                    stats.shared_saved_bytes += span.len() as u64;
                }
                None => {
                    w.write_bits(MODE_VERBATIM, 8);
                    w.write_varint(m.bytes.len() as u64);
                }
            }
        }
        w.align_byte();

        // ---- BLOBS ----
        for blob in &blobs {
            w.write_varint(blob.len() as u64);
        }
        w.align_byte();
        for blob in &blobs {
            w.write_bytes(blob);
            stats.shared_saved_bytes -= blob.len() as u64; // one copy stays
        }

        // ---- PAYLOAD ---- (byte-aligned: these are bulk appends)
        for m in &self.members {
            let span = &m.bytes[m.side.0..m.side.1];
            if blob_id.contains_key(span) {
                w.write_bytes(&m.bytes[..m.side.0]);
                w.write_bytes(&m.bytes[m.side.1..]);
            } else {
                w.write_bytes(&m.bytes);
            }
        }

        let bytes = w.into_bytes();
        stats.archive_bytes = bytes.len() as u64;
        Ok((bytes, stats))
    }

    /// Build and write the archive to `path` (write-tmp-then-rename, same
    /// crash discipline as the store's spill files).
    pub fn write(&self, path: &Path) -> Result<PackStats> {
        let (bytes, stats) = self.build()?;
        let tmp = path.with_extension("rfpk.tmp");
        std::fs::write(&tmp, &bytes)
            .and_then(|()| std::fs::rename(&tmp, path))
            .map_err(|e| {
                let _ = std::fs::remove_file(&tmp);
                e
            })
            .with_context(|| format!("writing pack {}", path.display()))?;
        Ok(stats)
    }
}

impl Default for PackBuilder {
    fn default() -> Self {
        Self::new()
    }
}

struct Member {
    key: String,
    /// Absolute span of the stored bytes within the archive buffer.
    stored: (usize, usize),
    /// `Some((blob id, splice position))` for shared-mode members.
    shared: Option<(usize, usize)>,
    /// Standalone container size (stored + referenced blob).
    logical: u64,
}

/// A parsed, immutable `RFPK` archive over one shared buffer (typically a
/// single `mmap` of the pack file — every member parse aliases it).
pub struct PackArchive {
    buf: SharedBytes,
    members: Vec<Member>,
    by_key: BTreeMap<String, usize>,
    /// Absolute spans of the shared-codebook blobs.
    blobs: Vec<(usize, usize)>,
}

impl PackArchive {
    /// Map a pack file and parse its directory. The payload bytes are not
    /// touched — the kernel pages them in as members are parsed.
    pub fn open(path: &Path) -> Result<PackArchive> {
        let map = Mmap::map_path(path)
            .with_context(|| format!("opening pack {}", path.display()))?;
        Self::from_shared(map.into())
            .with_context(|| format!("parsing pack {}", path.display()))
    }

    /// Parse an archive from heap bytes (tests, network ingestion).
    pub fn from_bytes(bytes: impl Into<Arc<[u8]>>) -> Result<PackArchive> {
        Self::from_shared(SharedBytes::Heap(bytes.into()))
    }

    /// Parse an archive over any shared buffer.
    pub fn from_shared(buf: SharedBytes) -> Result<PackArchive> {
        let (members, by_key, blobs) = {
            let bytes: &[u8] = &buf;
            let mut r = BitReader::new(bytes);
            let mut magic = [0u8; 4];
            for m in magic.iter_mut() {
                *m = r.read_byte().context("pack magic")?;
            }
            if &magic != PACK_MAGIC {
                bail!("not an RFPK archive (bad magic)");
            }
            let version = r.read_bits(8).context("pack version")? as u8;
            if version != PACK_VERSION {
                bail!("unsupported pack version {version}");
            }
            let n_members_raw = r.read_varint().context("member count")?;
            if n_members_raw == 0 || n_members_raw > 10_000_000 {
                bail!("implausible pack member count {n_members_raw}");
            }
            let n_members = cast_usize(n_members_raw, "member count")?;
            let n_blobs_raw = r.read_varint().context("blob count")?;
            if n_blobs_raw > n_members_raw {
                bail!("more blobs ({n_blobs_raw}) than members ({n_members_raw})");
            }
            let n_blobs = cast_usize(n_blobs_raw, "blob count")?;
            r.align_byte();

            // ---- INDEX ----
            struct RawMember {
                key: String,
                stored_len: usize,
                shared: Option<(usize, usize)>,
            }
            let mut raw = Vec::with_capacity(n_members);
            let mut by_key = BTreeMap::new();
            for i in 0..n_members {
                let key_len =
                    cast_usize(r.read_varint().context("key len")?, "member key length")?;
                if key_len == 0 || key_len > MAX_KEY_LEN {
                    bail!("implausible member key length {key_len}");
                }
                let mut key_bytes = Vec::with_capacity(key_len);
                for _ in 0..key_len {
                    key_bytes.push(r.read_byte().context("member key")?);
                }
                let key = String::from_utf8(key_bytes).context("member key utf8")?;
                // a hostile archive must not smuggle what the builder
                // refuses: unaddressable wire names or extract-path escapes
                validate_key(&key)?;
                if by_key.insert(key.clone(), i).is_some() {
                    bail!("duplicate member key {key:?}");
                }
                let mode = r.read_bits(8).context("storage mode")?;
                let stored_len =
                    cast_usize(r.read_varint().context("stored len")?, "stored length")?;
                let shared = match mode {
                    MODE_VERBATIM => None,
                    MODE_SHARED => {
                        let blob = cast_usize(r.read_varint().context("blob id")?, "blob id")?;
                        if blob >= n_blobs {
                            bail!("member {key:?} references blob {blob} of {n_blobs}");
                        }
                        let splice =
                            cast_usize(r.read_varint().context("splice pos")?, "splice pos")?;
                        if splice > stored_len {
                            bail!("member {key:?}: splice {splice} beyond stored {stored_len}");
                        }
                        Some((blob, splice))
                    }
                    v => bail!("unknown storage mode {v}"),
                };
                raw.push(RawMember { key, stored_len, shared });
            }
            r.align_byte();

            // ---- BLOBS ----
            let mut blob_lens = Vec::with_capacity(n_blobs);
            for _ in 0..n_blobs {
                blob_lens.push(cast_usize(r.read_varint().context("blob len")?, "blob length")?);
            }
            r.align_byte();
            let mut off = cast_usize(r.bit_pos() / 8, "blob offset")?;
            let mut blobs = Vec::with_capacity(n_blobs);
            for len in blob_lens {
                let end = off.checked_add(len).context("blob span overflow")?;
                if end > bytes.len() {
                    bail!("blob section truncated ({len} bytes at {off}, archive holds {})", bytes.len());
                }
                blobs.push((off, end));
                off = end;
            }
            r.seek_bits(off as u64 * 8);

            // ---- PAYLOAD ----
            // every blob must be referenced: the builder only emits blobs
            // shared by ≥ 2 members, and an orphan blob would corrupt the
            // shared-savings accounting (stats would underflow)
            let mut blob_refs = vec![0usize; n_blobs];
            for m in &raw {
                if let Some((b, _)) = m.shared {
                    blob_refs[b] += 1;
                }
            }
            if let Some(orphan) = blob_refs.iter().position(|&c| c == 0) {
                bail!("blob {orphan} is referenced by no member");
            }
            let mut members = Vec::with_capacity(n_members);
            for m in raw {
                let end = off.checked_add(m.stored_len).context("member span overflow")?;
                if end > bytes.len() {
                    bail!(
                        "member {:?} truncated ({} bytes at {off}, archive holds {})",
                        m.key,
                        m.stored_len,
                        bytes.len()
                    );
                }
                let logical = m.stored_len as u64
                    + m.shared
                        .map(|(b, _)| (blobs[b].1 - blobs[b].0) as u64)
                        .unwrap_or(0);
                members.push(Member { key: m.key, stored: (off, end), shared: m.shared, logical });
                off = end;
            }
            if off != bytes.len() {
                bail!("archive has {} trailing bytes past the last member", bytes.len() - off);
            }
            (members, by_key, blobs)
        };
        Ok(PackArchive { buf, members, by_key, blobs })
    }

    /// Number of members in the archive.
    pub fn member_count(&self) -> usize {
        self.members.len()
    }

    /// Whether the archive has no members.
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Member keys in index order.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.members.iter().map(|m| m.key.as_str())
    }

    /// Key of one member by index.
    pub fn key(&self, member: usize) -> &str {
        &self.members[member].key
    }

    /// Index of a member by key.
    pub fn find(&self, key: &str) -> Option<usize> {
        self.by_key.get(key).copied()
    }

    /// Number of shared-codebook blobs.
    pub fn blob_count(&self) -> usize {
        self.blobs.len()
    }

    /// Whether a member is stored in shared mode (side info in a blob).
    pub fn member_is_shared(&self, member: usize) -> bool {
        self.members[member].shared.is_some()
    }

    /// Bytes the member occupies inside the archive (excluding any shared
    /// blob, which is amortized across its referents).
    pub fn member_stored_bytes(&self, member: usize) -> u64 {
        let (s, e) = self.members[member].stored;
        (e - s) as u64
    }

    /// Size of the member's standalone `RFCZ` container (what
    /// [`Self::extract_member`] returns).
    pub fn member_logical_bytes(&self, member: usize) -> u64 {
        self.members[member].logical
    }

    /// Total archive size.
    pub fn archive_bytes(&self) -> u64 {
        self.buf.len() as u64
    }

    /// The archive's backing buffer (pointer-identity checks in tests).
    pub fn buffer(&self) -> &SharedBytes {
        &self.buf
    }

    /// Parse a member zero-copy: the returned container's payload sections
    /// alias the archive buffer (one mmap serves every member). Shared-mode
    /// members read their side information out of the referenced blob.
    pub fn parse_member(&self, member: usize) -> Result<ParsedContainer> {
        let m = self
            .members
            .get(member)
            .with_context(|| format!("pack member {member} out of range"))?;
        let view = self.buf.slice(m.stored.0, m.stored.1 - m.stored.0)?;
        match m.shared {
            None => parse_arc(view)
                .with_context(|| format!("parsing pack member {:?}", m.key)),
            Some((blob, _)) => {
                let (bs, be) = self.blobs[blob];
                parse_packed(view, &self.buf.as_slice()[bs..be])
                    .with_context(|| format!("parsing pack member {:?}", m.key))
            }
        }
    }

    /// Parse a member by key.
    pub fn parse_by_key(&self, key: &str) -> Result<ParsedContainer> {
        let i = self.find(key).with_context(|| format!("unknown pack member {key:?}"))?;
        self.parse_member(i)
    }

    /// Reconstruct the member's standalone `RFCZ` bytes — **bit-identical**
    /// to the container handed to [`PackBuilder::add`]: verbatim members
    /// copy out; shared members splice `head ++ blob ++ tail`.
    pub fn extract_member(&self, member: usize) -> Result<Vec<u8>> {
        let m = self
            .members
            .get(member)
            .with_context(|| format!("pack member {member} out of range"))?;
        let stored = &self.buf.as_slice()[m.stored.0..m.stored.1];
        Ok(match m.shared {
            None => stored.to_vec(),
            Some((blob, splice)) => {
                let (bs, be) = self.blobs[blob];
                let blob_bytes = &self.buf.as_slice()[bs..be];
                let mut out = Vec::with_capacity(stored.len() + blob_bytes.len());
                out.extend_from_slice(&stored[..splice]);
                out.extend_from_slice(blob_bytes);
                out.extend_from_slice(&stored[splice..]);
                out
            }
        })
    }

    /// Extract a member by key.
    pub fn extract_by_key(&self, key: &str) -> Result<Vec<u8>> {
        let i = self.find(key).with_context(|| format!("unknown pack member {key:?}"))?;
        self.extract_member(i)
    }

    /// Archive-level summary (mirrors the builder's [`PackStats`]).
    pub fn stats(&self) -> PackStats {
        let logical: u64 = self.members.iter().map(|m| m.logical).sum();
        let shared_members = self.members.iter().filter(|m| m.shared.is_some()).count();
        let blob_bytes: u64 = self.blobs.iter().map(|&(s, e)| (e - s) as u64).sum();
        let shared_excised: u64 = self
            .members
            .iter()
            .filter_map(|m| m.shared.map(|(b, _)| (self.blobs[b].1 - self.blobs[b].0) as u64))
            .sum();
        PackStats {
            members: self.members.len(),
            blobs: self.blobs.len(),
            shared_members,
            archive_bytes: self.archive_bytes(),
            logical_bytes: logical,
            // parse validation guarantees every blob has ≥ 1 referent, so
            // excised ≥ blob bytes; saturate anyway — a wrong stat must
            // never wrap to ~1.8e19
            shared_saved_bytes: shared_excised.saturating_sub(blob_bytes),
        }
    }
}

impl std::fmt::Debug for PackArchive {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PackArchive")
            .field("members", &self.members.len())
            .field("blobs", &self.blobs.len())
            .field("bytes", &self.buf.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{CompressOptions, CompressedForest};
    use crate::data::synthetic;
    use crate::forest::{Forest, ForestParams};

    fn containers(n: usize, seed: u64) -> (Vec<CompressedForest>, Vec<Forest>) {
        let ds = synthetic::iris(41);
        let forests: Vec<Forest> = (0..n)
            .map(|i| Forest::train(&ds, &ForestParams::classification(2), seed + i as u64))
            .collect();
        let cohort = crate::pack::compress_cohort(&forests, &ds, &CompressOptions::default())
            .unwrap();
        (cohort, forests)
    }

    #[test]
    fn build_open_extract_bit_identical() {
        let (cohort, forests) = containers(5, 100);
        let mut b = PackBuilder::new();
        for (i, cf) in cohort.iter().enumerate() {
            b.add(&format!("user-{i}"), cf.bytes.clone()).unwrap();
        }
        let (bytes, stats) = b.build().unwrap();
        assert_eq!(stats.members, 5);
        assert_eq!(stats.blobs, 1, "a cohort shares one side-info blob");
        assert_eq!(stats.shared_members, 5);
        assert!(stats.archive_bytes < stats.logical_bytes, "dedup must shrink the pack");

        let pack = PackArchive::from_bytes(bytes).unwrap();
        assert_eq!(pack.member_count(), 5);
        assert_eq!(pack.blob_count(), 1);
        for (i, cf) in cohort.iter().enumerate() {
            let key = format!("user-{i}");
            assert_eq!(pack.find(&key), Some(i));
            let extracted = pack.extract_by_key(&key).unwrap();
            assert_eq!(&extracted[..], &cf.bytes[..], "member {i} must be bit-identical");
            assert_eq!(pack.member_logical_bytes(i), cf.total_bytes());
            // and it parses straight out of the archive to the same forest
            let pc = pack.parse_member(i).unwrap();
            let g = crate::compress::pipeline::decompress_container(&pc).unwrap();
            assert!(g.identical(&forests[i]), "member {i} decodes losslessly");
        }
        assert!(pack.find("ghost").is_none());
        assert!(pack.extract_by_key("ghost").is_err());
        assert!(pack.parse_member(99).is_err());
    }

    #[test]
    fn unshared_builder_stores_verbatim() {
        let (cohort, _) = containers(3, 200);
        let mut b = PackBuilder::new().shared(false);
        for (i, cf) in cohort.iter().enumerate() {
            b.add(&format!("m{i}"), cf.bytes.clone()).unwrap();
        }
        let (bytes, stats) = b.build().unwrap();
        assert_eq!(stats.blobs, 0);
        assert_eq!(stats.shared_members, 0);
        assert_eq!(stats.shared_saved_bytes, 0);
        let pack = PackArchive::from_bytes(bytes).unwrap();
        for (i, cf) in cohort.iter().enumerate() {
            assert!(!pack.member_is_shared(i));
            assert_eq!(pack.member_stored_bytes(i), cf.total_bytes());
            assert_eq!(pack.extract_member(i).unwrap()[..], cf.bytes[..]);
        }
    }

    #[test]
    fn independently_compressed_members_fall_back_to_verbatim() {
        // two forests compressed separately almost surely differ in their
        // side bytes: the shared pass must not force a bogus match
        let ds = synthetic::iris(42);
        let mut b = PackBuilder::new();
        for i in 0..2u64 {
            let f = Forest::train(&ds, &ForestParams::classification(3), 300 + i);
            let cf = CompressedForest::compress(&f, &ds, &CompressOptions::default()).unwrap();
            b.add(&format!("solo-{i}"), cf.bytes.clone()).unwrap();
        }
        let (bytes, stats) = b.build().unwrap();
        assert_eq!(stats.blobs, 0, "distinct side bytes must not share");
        assert!(PackArchive::from_bytes(bytes).is_ok());
    }

    #[test]
    fn builder_rejects_bad_keys_and_bad_members() {
        let (cohort, _) = containers(1, 400);
        let mut b = PackBuilder::new();
        assert!(b.add("", cohort[0].bytes.clone()).is_err());
        assert!(b.add("has space", cohort[0].bytes.clone()).is_err());
        // keys become extract filenames: separators and dot-dirs are refused
        assert!(b.add("a/b", cohort[0].bytes.clone()).is_err());
        assert!(b.add("a\\b", cohort[0].bytes.clone()).is_err());
        assert!(b.add("..", cohort[0].bytes.clone()).is_err());
        assert!(b.add("ok", cohort[0].bytes.clone()).is_ok());
        assert!(b.add("ok", cohort[0].bytes.clone()).is_err(), "duplicate key");
        assert!(b.add("junk", vec![1u8, 2, 3]).is_err(), "non-RFCZ member");
        assert!(PackBuilder::new().build().is_err(), "empty pack");
    }

    #[test]
    fn corrupt_archives_error_cleanly() {
        let (cohort, _) = containers(3, 500);
        let mut b = PackBuilder::new();
        for (i, cf) in cohort.iter().enumerate() {
            b.add(&format!("m{i}"), cf.bytes.clone()).unwrap();
        }
        let (bytes, _) = b.build().unwrap();
        assert!(PackArchive::from_bytes(b"RFXX".to_vec()).is_err(), "bad magic");
        assert!(PackArchive::from_bytes(Vec::<u8>::new()).is_err(), "empty");
        for cut in [4, bytes.len() / 3, bytes.len() - 3] {
            assert!(
                PackArchive::from_bytes(bytes[..cut].to_vec()).is_err(),
                "truncation at {cut} must error"
            );
        }
        let mut padded = bytes.clone();
        padded.extend_from_slice(&[0; 7]);
        assert!(PackArchive::from_bytes(padded).is_err(), "trailing bytes must error");
    }

    /// Hand-craft an archive: one verbatim member under `key`, plus
    /// `orphan_blob` optionally appending a blob no member references.
    fn craft_archive(key: &str, payload: &[u8], orphan_blob: bool) -> Vec<u8> {
        let mut w = BitWriter::new();
        for &b in PACK_MAGIC {
            w.write_byte(b);
        }
        w.write_bits(PACK_VERSION as u64, 8);
        w.write_varint(1); // members
        w.write_varint(orphan_blob as u64); // blobs
        w.align_byte();
        w.write_varint(key.len() as u64);
        for &b in key.as_bytes() {
            w.write_byte(b);
        }
        w.write_bits(MODE_VERBATIM, 8);
        w.write_varint(payload.len() as u64);
        w.align_byte();
        if orphan_blob {
            w.write_varint(4); // one 4-byte blob
        }
        w.align_byte();
        if orphan_blob {
            for b in [1u8, 2, 3, 4] {
                w.write_byte(b);
            }
        }
        for &b in payload {
            w.write_byte(b);
        }
        w.into_bytes()
    }

    #[test]
    fn reader_rejects_hostile_archives() {
        let (cohort, _) = containers(1, 800);
        let payload = &cohort[0].bytes;
        // a clean crafted archive parses (the harness itself is sound)
        let ok = craft_archive("fine", payload, false);
        assert!(PackArchive::from_bytes(ok).is_ok());
        // whitespace in a key would make the member unaddressable over the
        // space-delimited wire protocol — the reader must refuse it
        let bad_key = craft_archive("user 1", payload, false);
        let err = PackArchive::from_bytes(bad_key).unwrap_err().to_string();
        assert!(err.contains("whitespace"), "{err}");
        // a traversal key would let `pack extract --out-dir` write outside
        // the output directory — the reader must refuse it too
        for hostile in ["../../escape", "/etc/cron.d/x", ".."] {
            let bad = craft_archive(hostile, payload, false);
            assert!(
                PackArchive::from_bytes(bad).is_err(),
                "hostile key {hostile:?} must be rejected"
            );
        }
        // an orphan blob (referenced by no member) corrupts the savings
        // accounting — refuse it at parse time
        let orphan = craft_archive("fine", payload, true);
        let err = PackArchive::from_bytes(orphan).unwrap_err().to_string();
        assert!(err.contains("referenced by no member"), "{err}");
    }

    #[test]
    fn mmap_open_serves_members_zero_copy() {
        let (cohort, _) = containers(4, 600);
        let mut b = PackBuilder::new();
        for (i, cf) in cohort.iter().enumerate() {
            b.add(&format!("m{i}"), cf.bytes.clone()).unwrap();
        }
        let path = std::env::temp_dir()
            .join(format!("rfc-pack-zero-copy-{}.rfpk", std::process::id()));
        b.write(&path).unwrap();

        let pack = PackArchive::open(&path).unwrap();
        let base = pack.buffer().as_ptr() as usize;
        let len = pack.buffer().len();
        #[cfg(all(unix, target_pointer_width = "64"))]
        assert!(pack.buffer().is_mapped(), "open must ride a mapping");
        for i in 0..pack.member_count() {
            let pc = pack.parse_member(i).unwrap();
            assert!(
                matches!(pc.buffer(), SharedBytes::View { .. }),
                "member parses over a pack-relative view"
            );
            for sect in [pc.vars_bytes(), pc.splits_bytes(), pc.fits_bytes()] {
                let p = sect.as_ptr() as usize;
                assert!(
                    p >= base && p + sect.len() <= base + len,
                    "member {i} payloads must alias the pack mapping"
                );
            }
            assert_eq!(pack.extract_member(i).unwrap()[..], cohort[i].bytes[..]);
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn archive_stats_match_builder_stats() {
        let (cohort, _) = containers(6, 700);
        let mut b = PackBuilder::new();
        for (i, cf) in cohort.iter().enumerate() {
            b.add(&format!("m{i}"), cf.bytes.clone()).unwrap();
        }
        let (bytes, built) = b.build().unwrap();
        let pack = PackArchive::from_bytes(bytes).unwrap();
        assert_eq!(pack.stats(), built);
    }
}
