//! Cohort compression: shared codebooks across many forests.
//!
//! The paper's Bregman clustering (eq. 6) finds a minimal set of
//! probabilistic models describing the trees of *one* forest. Nothing in the
//! objective is forest-specific — the count tables extend naturally across
//! forests, and for subscriber workloads (thousands of tiny per-user models
//! on a common schema) the dictionary cost `α·B·K` is paid **once per
//! cohort** instead of once per model.
//!
//! [`compress_cohort`] builds the union forest of every member's trees, runs
//! stages 2–3 of Algorithm 1 once over it
//! (`compress::pipeline::build_codec_plan`), and encodes each
//! member against the frozen [`CodecPlan`]. Each output is a fully
//! standalone `RFCZ` container — decompressible with no side information,
//! bit-exact per member — whose TABLES/CLUSMAP/DICTS sections are
//! **byte-identical across the cohort**. [`crate::pack::PackBuilder`]
//! dedupes that span into one shared-codebook blob, which is where the
//! bytes-per-model win at ≤ 4 KiB models comes from.
//!
//! Losslessness: a Huffman code built from a cluster-merged (here:
//! cohort-merged) distribution still decodes exactly (paper §5, Cover &
//! Thomas) — the union tables guarantee codebook support ⊇ every member's
//! support, so per-member round trips stay bit-exact.

use crate::cluster::kmeans::{LloydEngine, NativeEngine};
use crate::compress::pipeline::{build_codec_plan, encode_with_plan};
use crate::compress::{CodecPlan, CompressOptions, CompressedForest};
use crate::data::Dataset;
use crate::forest::Forest;
use anyhow::{bail, Context, Result};

/// Compress every forest of a cohort against codebooks clustered over the
/// union of all members' tree-model tables (native clustering engine).
///
/// Requirements: at least one member, every member non-empty, and all
/// members sharing the dataset's schema and target kind (the subscriber
/// scenario: one product model family, many per-user instances).
pub fn compress_cohort(
    forests: &[Forest],
    ds: &Dataset,
    opts: &CompressOptions,
) -> Result<Vec<CompressedForest>> {
    compress_cohort_with_engine(forests, ds, opts, &mut NativeEngine)
}

/// As [`compress_cohort`] with an explicit clustering engine.
pub fn compress_cohort_with_engine(
    forests: &[Forest],
    ds: &Dataset,
    opts: &CompressOptions,
    engine: &mut dyn LloydEngine,
) -> Result<Vec<CompressedForest>> {
    let plan = cohort_plan(forests, ds, opts, engine)?;
    forests
        .iter()
        .enumerate()
        .map(|(i, f)| {
            encode_with_plan(f, &plan, opts.workers)
                .with_context(|| format!("encoding cohort member {i}"))
        })
        .collect()
}

/// Build the cohort-wide [`CodecPlan`]: union the members' trees and run the
/// clustering sweeps once over the merged count tables.
pub(crate) fn cohort_plan(
    forests: &[Forest],
    ds: &Dataset,
    opts: &CompressOptions,
    engine: &mut dyn LloydEngine,
) -> Result<CodecPlan> {
    if forests.is_empty() {
        bail!("cannot compress an empty cohort");
    }
    let first = &forests[0];
    for (i, f) in forests.iter().enumerate() {
        if f.trees.is_empty() {
            bail!("cohort member {i} is an empty forest");
        }
        if f.classification != first.classification || f.classes != first.classes {
            bail!(
                "cohort member {i} target (classification={}, classes={}) disagrees with \
                 member 0 (classification={}, classes={})",
                f.classification,
                f.classes,
                first.classification,
                first.classes
            );
        }
    }
    let union = Forest {
        trees: forests.iter().flat_map(|f| f.trees.iter().cloned()).collect(),
        classification: first.classification,
        classes: first.classes,
    };
    build_codec_plan(&union, ds, opts, engine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::forest::ForestParams;

    fn cohort(n: usize, trees: usize, seed: u64) -> (Dataset, Vec<Forest>) {
        let ds = synthetic::iris(55);
        let forests = (0..n)
            .map(|i| Forest::train(&ds, &ForestParams::classification(trees), seed + i as u64))
            .collect();
        (ds, forests)
    }

    #[test]
    fn cohort_members_round_trip_losslessly() {
        let (ds, forests) = cohort(4, 3, 900);
        let out = compress_cohort(&forests, &ds, &CompressOptions::default()).unwrap();
        assert_eq!(out.len(), forests.len());
        for (cf, f) in out.iter().zip(&forests) {
            let g = cf.decompress().unwrap();
            assert!(g.identical(f), "cohort member must round-trip bit-exactly");
        }
    }

    #[test]
    fn cohort_members_share_side_info_bytes() {
        let (ds, forests) = cohort(5, 2, 1000);
        let out = compress_cohort(&forests, &ds, &CompressOptions::default()).unwrap();
        let spans: Vec<Vec<u8>> = out
            .iter()
            .map(|cf| {
                let pc = cf.parse().unwrap();
                let (s, e) = pc.side_info_span();
                cf.bytes[s..e].to_vec()
            })
            .collect();
        for (i, span) in spans.iter().enumerate().skip(1) {
            assert_eq!(
                span, &spans[0],
                "member {i}'s TABLES/CLUSMAP/DICTS must be byte-identical to member 0's"
            );
        }
        assert!(!spans[0].is_empty());
    }

    #[test]
    fn cohort_regression_members_stay_bit_exact() {
        let ds = synthetic::airfoil_regression(56);
        let forests: Vec<Forest> = (0..3)
            .map(|i| Forest::train(&ds, &ForestParams::regression(2), 1100 + i))
            .collect();
        let out = compress_cohort(&forests, &ds, &CompressOptions::default()).unwrap();
        for (cf, f) in out.iter().zip(&forests) {
            assert!(cf.decompress().unwrap().identical(f));
        }
    }

    #[test]
    fn cohort_rejects_mismatched_members() {
        let (ds, mut forests) = cohort(2, 2, 1200);
        assert!(compress_cohort(&[], &ds, &CompressOptions::default()).is_err());
        // a regression member in a classification cohort must be refused
        let rds = synthetic::airfoil_regression(57);
        forests.push(Forest::train(&rds, &ForestParams::regression(2), 1));
        assert!(compress_cohort(&forests, &ds, &CompressOptions::default()).is_err());
    }

    #[test]
    fn chained_cohort_keeps_side_info_byte_identity() {
        // stage chains are part of the frozen plan (recorded in the header,
        // OUTSIDE the side-info span), so the pack dedup invariant must
        // survive a chained cohort: identical TABLES/CLUSMAP/DICTS bytes,
        // version-2 containers, bit-exact members
        use crate::coding::stage::{parse_chain, SectionChains};
        let (ds, forests) = cohort(4, 2, 1400);
        let opts = CompressOptions {
            chains: SectionChains {
                structure: parse_chain("lzss").unwrap(),
                split_tables: parse_chain("delta+lzss").unwrap(),
                fit_table: parse_chain("split8+huff").unwrap(),
            },
            ..Default::default()
        };
        let out = compress_cohort(&forests, &ds, &opts).unwrap();
        let spans: Vec<Vec<u8>> = out
            .iter()
            .map(|cf| {
                assert_eq!(cf.bytes[4], crate::compress::container::VERSION_CHAINED);
                let pc = cf.parse().unwrap();
                let (s, e) = pc.side_info_span();
                cf.bytes[s..e].to_vec()
            })
            .collect();
        for span in spans.iter().skip(1) {
            assert_eq!(span, &spans[0]);
        }
        for (cf, f) in out.iter().zip(&forests) {
            assert!(cf.decompress().unwrap().identical(f));
        }
    }

    #[test]
    fn singleton_cohort_matches_plain_compression() {
        // a cohort of one builds its plan from exactly the member's trees —
        // the output must equal CompressedForest::compress byte for byte
        let (ds, forests) = cohort(1, 4, 1300);
        let opts = CompressOptions::default();
        let a = compress_cohort(&forests, &ds, &opts).unwrap().remove(0);
        let b = CompressedForest::compress(&forests[0], &ds, &opts).unwrap();
        assert_eq!(a.bytes, b.bytes);
    }
}
