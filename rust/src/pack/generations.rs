//! Generation chains: mutable model packs without giving up bit-exact
//! reconstruction.
//!
//! An immutable `RFPK` archive ([`super::format`]) is the right shape for a
//! cohort that never changes — but the paper's subscriber setting churns:
//! models are retrained and retired continuously, and paying a full
//! re-clustering over the whole cohort for every membership change is
//! exactly the rebuild cost Gieseke & Igel (2018) warn dominates at scale.
//! A **chain** makes a pack mutable LSM-style:
//!
//! ```text
//! <dir>/
//!   MANIFEST            text manifest: generation list, in commit order
//!   gen-00000001.rfpk   base generation  (immutable RFPK archive)
//!   gen-00000002.rfpk   delta generation (new / replacing members)
//!                       (a generation may instead carry only tombstones)
//! ```
//!
//! * **Reads resolve newest-first.** Replaying the manifest builds the live
//!   map: a delta entry shadows any same-keyed member of an earlier
//!   generation, and a tombstone hides the key entirely (until a later
//!   generation re-adds it). Every live member still extracts
//!   **bit-identical** to the container it was appended as — deltas are
//!   ordinary `RFPK` members, nothing is re-encoded on write.
//! * **Mutations are new generations.** [`PackChain::append_members`] and
//!   [`PackChain::remove_members`] never rewrite existing archives; they
//!   write one new generation (delta pack and/or tombstones) plus a new
//!   manifest. Generation sequence numbers are monotone and **never
//!   reused** (the manifest carries the high-water mark), so a crashed
//!   commit can never leave a stale file a later commit would trust.
//! * **Commits are crash-safe.** Everything lands under a `.tmp` name
//!   first; the single `MANIFEST` rename is the commit point. The commit
//!   protocol passes the declared [`CrashPoint`]s in order, and
//!   [`PackChain::open`] validates the manifest (magic, monotone seqs,
//!   resolvable tombstones, parseable archives) and sweeps orphan `.tmp`
//!   and unreferenced generation files — recovery is all-or-nothing by
//!   construction, and the crash-injection matrix in
//!   `tests/pack_chain_suite.rs` proves it at every point.
//! * **Compaction** ([`super::compact`]) merges the chain back into a
//!   single fresh base generation and clears every tombstone, swapping the
//!   manifest atomically while readers holding `Arc`s onto old generation
//!   mappings keep serving unharmed.

use crate::compress::container::ParsedContainer;
use crate::pack::format::{PackArchive, PackBuilder};
use crate::testing::crashpoint::{CrashInjector, CrashPoint};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Manifest file name within a chain directory.
pub const MANIFEST_NAME: &str = "MANIFEST";
/// Manifest magic token (first line: `RFPM <version>`).
pub const MANIFEST_MAGIC: &str = "RFPM";
/// Manifest format version this build reads and writes.
pub const MANIFEST_VERSION: u32 = 1;

/// Ceiling on manifest generations (a hostile manifest must not allocate
/// unboundedly).
const MAX_GENERATIONS: usize = 100_000;

/// One generation of a chain: an optional delta archive plus the keys this
/// generation tombstones.
pub struct Generation {
    /// Monotone sequence number (also baked into the file name).
    pub seq: u64,
    /// Archive file name relative to the chain directory (`None` for a
    /// tombstone-only generation).
    file: Option<String>,
    /// The generation's parsed archive (one mmap; `None` iff `file` is).
    pack: Option<Arc<PackArchive>>,
    /// Keys this generation hides from every earlier generation.
    tombstones: Vec<String>,
}

impl Generation {
    /// The generation's archive, if it has one.
    pub fn archive(&self) -> Option<&Arc<PackArchive>> {
        self.pack.as_ref()
    }

    /// Keys this generation tombstones.
    pub fn tombstones(&self) -> &[String] {
        &self.tombstones
    }
}

/// Point-in-time summary of a chain (printed by `repro pack list --chain`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ChainStats {
    /// Generations in the manifest.
    pub generations: usize,
    /// Live members after newest-first resolution.
    pub live_members: usize,
    /// Members stored across all generations (live + shadowed).
    pub stored_members: usize,
    /// Tombstone entries across all generations.
    pub tombstones: u64,
    /// Sum of the generations' archive bytes on disk.
    pub archive_bytes: u64,
}

/// A mutable pack: the ordered generation list plus the resolved live view.
pub struct PackChain {
    dir: PathBuf,
    gens: Vec<Generation>,
    /// Next sequence number to assign — strictly greater than every seq
    /// ever used by this chain, surviving compaction (the manifest
    /// persists it), so generation file names are never reused.
    next_seq: u64,
    /// Newest-first resolution: key → (index into `gens`, member index).
    live: BTreeMap<String, (usize, usize)>,
    /// Crash-injection seam for the commit protocol (disarmed outside
    /// tests; see [`crate::testing::crashpoint`]).
    crash: CrashInjector,
}

fn gen_file_name(seq: u64) -> String {
    format!("gen-{seq:08}.rfpk")
}

impl PackChain {
    /// Create an empty chain: the directory is created and a zero-generation
    /// manifest committed. Fails if a manifest already exists there.
    pub fn create(dir: &Path) -> Result<PackChain> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating chain directory {}", dir.display()))?;
        if dir.join(MANIFEST_NAME).exists() {
            bail!("chain {} already has a manifest", dir.display());
        }
        let mut chain = PackChain {
            dir: dir.to_path_buf(),
            gens: Vec::new(),
            next_seq: 1,
            live: BTreeMap::new(),
            crash: CrashInjector::new(),
        };
        chain.commit(None, Vec::new())?;
        Ok(chain)
    }

    /// Open and validate a chain directory: parse the manifest, open every
    /// generation archive, replay the generations into the live view, and
    /// sweep crash leftovers (`.tmp` files and generation files the
    /// manifest no longer references). Every structural defect — missing
    /// or truncated generation file, duplicate or non-monotone sequence
    /// numbers, a tombstone for a key that is not live at its point in the
    /// chain — surfaces as a typed error here, never a panic downstream.
    pub fn open(dir: &Path) -> Result<PackChain> {
        let manifest_path = dir.join(MANIFEST_NAME);
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading chain manifest {}", manifest_path.display()))?;
        let (entries, next_seq) = parse_manifest(&text)
            .with_context(|| format!("parsing chain manifest {}", manifest_path.display()))?;

        let mut gens = Vec::with_capacity(entries.len());
        for e in entries {
            let pack = match &e.file {
                None => None,
                Some(name) => {
                    let path = dir.join(name);
                    if !path.is_file() {
                        bail!(
                            "manifest references missing generation file {} (generation {})",
                            path.display(),
                            e.seq
                        );
                    }
                    Some(Arc::new(PackArchive::open(&path).with_context(|| {
                        format!("opening generation {} archive {name}", e.seq)
                    })?))
                }
            };
            gens.push(Generation { seq: e.seq, file: e.file, pack, tombstones: e.tombstones });
        }
        let live = replay(&gens)?;
        let chain = PackChain {
            dir: dir.to_path_buf(),
            gens,
            next_seq,
            live,
            crash: CrashInjector::new(),
        };
        chain.sweep_orphans();
        Ok(chain)
    }

    /// Remove crash leftovers: every `.tmp` file, and every `gen-*.rfpk`
    /// the manifest does not reference. Both are inert — sequence numbers
    /// are never reused, so no future commit could collide with them — but
    /// leaving them would leak disk forever.
    fn sweep_orphans(&self) {
        let referenced: Vec<&str> =
            self.gens.iter().filter_map(|g| g.file.as_deref()).collect();
        let Ok(entries) = std::fs::read_dir(&self.dir) else { return };
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if name == MANIFEST_NAME || referenced.contains(&name) {
                continue;
            }
            let orphan_tmp = name.ends_with(".tmp");
            let orphan_gen = name.starts_with("gen-") && name.ends_with(".rfpk");
            if orphan_tmp || orphan_gen {
                let _ = std::fs::remove_file(entry.path());
            }
        }
    }

    /// The chain's directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The crash-injection seam (tests arm it; production never touches it).
    pub fn crash(&self) -> &CrashInjector {
        &self.crash
    }

    /// Generations, oldest first.
    pub fn generations(&self) -> &[Generation] {
        &self.gens
    }

    /// Number of generations in the manifest.
    pub fn generation_count(&self) -> usize {
        self.gens.len()
    }

    /// Tombstone entries across all generations (compaction resets to 0).
    pub fn tombstone_count(&self) -> u64 {
        self.gens.iter().map(|g| g.tombstones.len() as u64).sum()
    }

    /// Number of live members after newest-first resolution.
    pub fn live_len(&self) -> usize {
        self.live.len()
    }

    /// Live member keys, sorted.
    pub fn live_keys(&self) -> impl Iterator<Item = &str> {
        self.live.keys().map(|k| k.as_str())
    }

    /// Whether `key` is currently live (not tombstoned, present somewhere).
    pub fn contains(&self, key: &str) -> bool {
        self.live.contains_key(key)
    }

    /// Resolve a live key to the generation archive and member index that
    /// currently serve it (the newest generation holding the key). The
    /// returned `Arc` keeps that generation's mapping alive across any
    /// concurrent compaction — in-flight readers are never torn down.
    pub fn resolve(&self, key: &str) -> Option<(&Arc<PackArchive>, usize)> {
        let &(g, m) = self.live.get(key)?;
        Some((self.gens[g].pack.as_ref().expect("live member in archive-less generation"), m))
    }

    /// The generation sequence number currently serving a live key.
    pub fn resolve_seq(&self, key: &str) -> Option<u64> {
        let &(g, _) = self.live.get(key)?;
        Some(self.gens[g].seq)
    }

    /// Reconstruct a live member's standalone `RFCZ` container bytes —
    /// bit-identical to what was appended, resolved newest-first.
    pub fn extract(&self, key: &str) -> Result<Vec<u8>> {
        let (pack, m) = self
            .resolve(key)
            .with_context(|| format!("unknown or tombstoned chain member {key:?}"))?;
        pack.extract_member(m)
    }

    /// Parse a live member zero-copy off its generation's mapping.
    pub fn parse(&self, key: &str) -> Result<ParsedContainer> {
        let (pack, m) = self
            .resolve(key)
            .with_context(|| format!("unknown or tombstoned chain member {key:?}"))?;
        pack.parse_member(m)
    }

    /// Chain summary across generations.
    pub fn stats(&self) -> ChainStats {
        ChainStats {
            generations: self.gens.len(),
            live_members: self.live.len(),
            stored_members: self
                .gens
                .iter()
                .filter_map(|g| g.pack.as_ref())
                .map(|p| p.member_count())
                .sum(),
            tombstones: self.tombstone_count(),
            archive_bytes: self
                .gens
                .iter()
                .filter_map(|g| g.pack.as_ref())
                .map(|p| p.archive_bytes())
                .sum(),
        }
    }

    /// Append (or replace) members as one new delta generation. Each
    /// `(key, container)` pair is validated like [`PackBuilder::add`]; a
    /// key already live in an earlier generation is **shadowed**, not
    /// rewritten. Returns the new generation's sequence number. For the
    /// shared-codebook win, compress the batch as one cohort
    /// ([`crate::pack::compress_cohort`]) before appending — the delta
    /// pack dedups side information within the batch exactly like a base
    /// archive does.
    pub fn append_members(&mut self, members: &[(String, Arc<[u8]>)]) -> Result<u64> {
        if members.is_empty() {
            bail!("append_members needs at least one member");
        }
        let mut builder = PackBuilder::new();
        for (key, bytes) in members {
            builder.add(key, bytes.clone())?;
        }
        let (bytes, _) = builder.build()?;
        let seq = self.next_seq;
        self.commit(Some((seq, bytes, Vec::new())), Vec::new())?;
        Ok(seq)
    }

    /// Tombstone members as one new (archive-less) generation: every key
    /// must currently be live, and duplicates are refused. Returns the new
    /// generation's sequence number. The member's bytes stay in their old
    /// generation until a compaction merges them away — removal is a
    /// manifest-only commit.
    pub fn remove_members(&mut self, keys: &[String]) -> Result<u64> {
        if keys.is_empty() {
            bail!("remove_members needs at least one key");
        }
        let mut seen = BTreeMap::new();
        for key in keys {
            if !self.live.contains_key(key) {
                bail!("cannot tombstone {key:?}: not a live chain member");
            }
            if seen.insert(key, ()).is_some() {
                bail!("duplicate tombstone key {key:?}");
            }
        }
        let seq = self.next_seq;
        self.commit(Some((seq, Vec::new(), keys.to_vec())), Vec::new())?;
        Ok(seq)
    }

    /// Install a compacted base: one fresh generation holding `bytes`
    /// replaces every existing generation, and the old generation files are
    /// cleaned up after the manifest swap. `bytes` empty means the live
    /// set is empty — the chain compacts to zero generations. Used by
    /// [`super::compact::compact_chain`].
    pub(crate) fn install_compacted(&mut self, bytes: Vec<u8>) -> Result<u64> {
        let seq = self.next_seq;
        let cleanup: Vec<String> = self.gens.iter().filter_map(|g| g.file.clone()).collect();
        let replace = if bytes.is_empty() { None } else { Some((seq, bytes, Vec::new())) };
        self.commit_replacing(replace, cleanup)?;
        Ok(seq)
    }

    /// Commit one additional generation (see [`Self::commit_replacing`]).
    fn commit(
        &mut self,
        new_gen: Option<(u64, Vec<u8>, Vec<String>)>,
        cleanup: Vec<String>,
    ) -> Result<()> {
        self.commit_inner(new_gen, cleanup, false)
    }

    /// Commit a generation that REPLACES the whole chain (compaction).
    fn commit_replacing(
        &mut self,
        new_gen: Option<(u64, Vec<u8>, Vec<String>)>,
        cleanup: Vec<String>,
    ) -> Result<()> {
        self.commit_inner(new_gen, cleanup, true)
    }

    /// The crash-safe commit protocol. `new_gen` is `(seq, archive bytes,
    /// tombstones)` — empty bytes mean a tombstone-only generation.
    /// Ordering (each [`CrashPoint`] is a declared crash window):
    ///
    /// 1. *pre-tmp* — nothing written yet; a crash is a pure no-op.
    /// 2. write `gen-<seq>.rfpk.tmp` and `MANIFEST.tmp` → *post-tmp* —
    ///    tmp files exist; open ignores and sweeps them.
    /// 3. rename the generation file into place → *pre-rename* — the new
    ///    archive exists but the manifest still describes the old chain;
    ///    open serves the old set and sweeps the unreferenced file.
    /// 4. rename `MANIFEST.tmp` over `MANIFEST` (**the commit point**;
    ///    rename is atomic on POSIX) → *post-rename* — the new set is
    ///    durable; only cleanup of old files is pending.
    /// 5. delete `cleanup` files (compaction's merged-away generations)
    ///    → *post-cleanup*.
    ///
    /// Only after the protocol finishes does the in-memory chain adopt the
    /// new state; on any error the in-memory view still describes the
    /// *old* committed state unless the manifest rename already landed, in
    /// which case reopening the directory recovers the new one — either
    /// way the disk is exactly one of the two sets, never a mix.
    fn commit_inner(
        &mut self,
        new_gen: Option<(u64, Vec<u8>, Vec<String>)>,
        cleanup: Vec<String>,
        replace: bool,
    ) -> Result<()> {
        let manifest_path = self.dir.join(MANIFEST_NAME);
        let manifest_tmp = self.dir.join(format!("{MANIFEST_NAME}.tmp"));

        // assemble the post-commit generation list (entries only; the
        // archive is opened after the protocol lands)
        let mut entries: Vec<(u64, Option<String>, Vec<String>)> = if replace {
            Vec::new()
        } else {
            self.gens
                .iter()
                .map(|g| (g.seq, g.file.clone(), g.tombstones.clone()))
                .collect()
        };
        let mut next_seq = self.next_seq;
        let mut pack_file: Option<(PathBuf, PathBuf, String)> = None; // (tmp, final, name)
        let mut pack_bytes: Option<Vec<u8>> = None;
        if let Some((seq, bytes, tombstones)) = new_gen {
            debug_assert_eq!(seq, self.next_seq, "generation seqs are assigned in order");
            let file = if bytes.is_empty() {
                if tombstones.is_empty() {
                    bail!("a generation needs an archive or at least one tombstone");
                }
                None
            } else {
                let name = gen_file_name(seq);
                pack_file = Some((
                    self.dir.join(format!("{name}.tmp")),
                    self.dir.join(&name),
                    name.clone(),
                ));
                pack_bytes = Some(bytes);
                Some(name)
            };
            entries.push((seq, file, tombstones));
            next_seq = seq + 1;
        }
        let text = render_manifest(&entries, next_seq);

        // ---- the declared crash windows, in order ----
        self.crash.check(CrashPoint::PreTmp)?;
        if let (Some((tmp, _, _)), Some(bytes)) = (&pack_file, &pack_bytes) {
            std::fs::write(tmp, bytes)
                .with_context(|| format!("writing generation tmp {}", tmp.display()))?;
        }
        std::fs::write(&manifest_tmp, &text)
            .with_context(|| format!("writing manifest tmp {}", manifest_tmp.display()))?;
        self.crash.check(CrashPoint::PostTmp)?;
        if let Some((tmp, final_path, _)) = &pack_file {
            std::fs::rename(tmp, final_path)
                .with_context(|| format!("installing generation {}", final_path.display()))?;
        }
        self.crash.check(CrashPoint::PreRename)?;
        std::fs::rename(&manifest_tmp, &manifest_path)
            .with_context(|| format!("committing manifest {}", manifest_path.display()))?;
        self.crash.check(CrashPoint::PostRename)?;
        for name in &cleanup {
            let _ = std::fs::remove_file(self.dir.join(name));
        }
        self.crash.check(CrashPoint::PostCleanup)?;

        // ---- adopt the committed state in memory ----
        let mut gens = Vec::with_capacity(entries.len());
        for (seq, file, tombstones) in entries {
            // unchanged generations keep their already-open archive (and
            // any Arc a reader holds); only the new file is opened
            let existing = self
                .gens
                .iter()
                .find(|g| g.seq == seq && g.file == file)
                .and_then(|g| g.pack.clone());
            let pack = match (&file, existing) {
                (None, _) => None,
                (Some(_), Some(p)) => Some(p),
                (Some(name), None) => Some(Arc::new(
                    PackArchive::open(&self.dir.join(name))
                        .with_context(|| format!("reopening committed generation {name}"))?,
                )),
            };
            gens.push(Generation { seq, file, pack, tombstones });
        }
        self.live = replay(&gens)?;
        self.gens = gens;
        self.next_seq = next_seq;
        Ok(())
    }
}

impl std::fmt::Debug for PackChain {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PackChain")
            .field("dir", &self.dir)
            .field("generations", &self.gens.len())
            .field("live", &self.live.len())
            .field("tombstones", &self.tombstone_count())
            .finish()
    }
}

/// Replay generations oldest→newest into the live view, validating that
/// every tombstone hides a key that is live at its point in the chain.
fn replay(gens: &[Generation]) -> Result<BTreeMap<String, (usize, usize)>> {
    let mut live: BTreeMap<String, (usize, usize)> = BTreeMap::new();
    for (gi, g) in gens.iter().enumerate() {
        for key in &g.tombstones {
            if live.remove(key).is_none() {
                bail!(
                    "generation {} tombstones {key:?}, which is not live at that \
                     point in the chain",
                    g.seq
                );
            }
        }
        if let Some(pack) = &g.pack {
            for m in 0..pack.member_count() {
                live.insert(pack.key(m).to_string(), (gi, m));
            }
        }
    }
    Ok(live)
}

/// One parsed manifest generation line.
struct ManifestEntry {
    seq: u64,
    file: Option<String>,
    tombstones: Vec<String>,
}

/// Parse the manifest text. Grammar (line-oriented, space-delimited — pack
/// keys can never contain whitespace, [`super::format`] enforces it):
///
/// ```text
/// RFPM 1
/// next <seq>
/// gen <seq> <file|-> [tombstone-key ...]
/// ```
fn parse_manifest(text: &str) -> Result<(Vec<ManifestEntry>, u64)> {
    let mut lines = text.lines();
    let header = lines.next().context("empty manifest")?;
    let expected = format!("{MANIFEST_MAGIC} {MANIFEST_VERSION}");
    if header.trim() != expected {
        bail!("bad manifest header {header:?} (expected {expected:?})");
    }
    let next_line = lines.next().context("manifest missing `next` line")?;
    let next_seq: u64 = next_line
        .strip_prefix("next ")
        .with_context(|| format!("bad manifest line {next_line:?} (expected `next <seq>`)"))?
        .trim()
        .parse()
        .with_context(|| format!("bad next-seq in {next_line:?}"))?;

    let mut entries = Vec::new();
    for line in lines {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut toks = line.split_whitespace();
        match toks.next() {
            Some("gen") => {}
            other => bail!("unknown manifest line {line:?} (token {other:?})"),
        }
        let seq: u64 = toks
            .next()
            .context("gen line missing seq")?
            .parse()
            .with_context(|| format!("bad generation seq in {line:?}"))?;
        let file_tok = toks.next().context("gen line missing file")?;
        let file = if file_tok == "-" {
            None
        } else {
            if file_tok.contains('/') || file_tok.contains('\\') || file_tok == ".." {
                bail!("generation file name {file_tok:?} may not contain path separators");
            }
            Some(file_tok.to_string())
        };
        let tombstones: Vec<String> = toks.map(|t| t.to_string()).collect();
        if file.is_none() && tombstones.is_empty() {
            bail!("generation {seq} has neither an archive nor tombstones");
        }
        if let Some(prev) = entries.last().map(|e: &ManifestEntry| e.seq) {
            if seq == prev {
                bail!("duplicate generation sequence number {seq}");
            }
            if seq < prev {
                bail!("generation sequence numbers must be monotone ({seq} after {prev})");
            }
        }
        entries.push(ManifestEntry { seq, file, tombstones });
        if entries.len() > MAX_GENERATIONS {
            bail!("implausible manifest: more than {MAX_GENERATIONS} generations");
        }
    }
    if let Some(last) = entries.last() {
        if next_seq <= last.seq {
            bail!(
                "manifest next-seq {next_seq} is not past the last generation ({}) — \
                 sequence numbers would be reused",
                last.seq
            );
        }
    }
    if next_seq == 0 {
        bail!("manifest next-seq must be positive");
    }
    Ok((entries, next_seq))
}

/// Render the manifest text for a generation list (inverse of
/// [`parse_manifest`]).
fn render_manifest(entries: &[(u64, Option<String>, Vec<String>)], next_seq: u64) -> String {
    let mut out = format!("{MANIFEST_MAGIC} {MANIFEST_VERSION}\nnext {next_seq}\n");
    for (seq, file, tombstones) in entries {
        out.push_str("gen ");
        out.push_str(&seq.to_string());
        out.push(' ');
        out.push_str(file.as_deref().unwrap_or("-"));
        for t in tombstones {
            out.push(' ');
            out.push_str(t);
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{CompressOptions, CompressedForest};
    use crate::data::synthetic;
    use crate::forest::{Forest, ForestParams};

    fn cohort(n: usize, seed: u64) -> (Vec<CompressedForest>, Vec<Forest>) {
        let ds = synthetic::iris(41);
        let forests: Vec<Forest> = (0..n)
            .map(|i| Forest::train(&ds, &ForestParams::classification(2), seed + i as u64))
            .collect();
        let cfs =
            crate::pack::compress_cohort(&forests, &ds, &CompressOptions::default()).unwrap();
        (cfs, forests)
    }

    fn temp_chain_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("rfc-chain-{tag}-{}", std::process::id()))
    }

    fn members(cfs: &[CompressedForest], keys: &[&str]) -> Vec<(String, Arc<[u8]>)> {
        keys.iter()
            .zip(cfs)
            .map(|(k, cf)| (k.to_string(), cf.bytes.clone()))
            .collect()
    }

    #[test]
    fn chain_append_remove_resolves_newest_first() {
        let dir = temp_chain_dir("resolve");
        let _ = std::fs::remove_dir_all(&dir);
        let (cfs, _) = cohort(4, 500);
        let mut chain = PackChain::create(&dir).unwrap();
        assert_eq!(chain.generation_count(), 0);
        assert_eq!(chain.live_len(), 0);

        // base generation: a, b
        let g1 = chain
            .append_members(&members(&cfs[..2], &["a", "b"]))
            .unwrap();
        // delta: c new, b replaced by a different container
        let g2 = chain
            .append_members(&members(&cfs[2..4], &["c", "b"]))
            .unwrap();
        assert!(g2 > g1);
        assert_eq!(chain.generation_count(), 2);
        assert_eq!(chain.live_len(), 3);
        // the delta entry shadows the base
        assert_eq!(chain.extract("b").unwrap()[..], cfs[3].bytes[..]);
        assert_eq!(chain.resolve_seq("b"), Some(g2));
        assert_eq!(chain.resolve_seq("a"), Some(g1));
        assert_eq!(chain.extract("a").unwrap()[..], cfs[0].bytes[..]);
        assert_eq!(chain.extract("c").unwrap()[..], cfs[2].bytes[..]);

        // tombstone hides a; the key is gone until re-added
        chain.remove_members(&["a".to_string()]).unwrap();
        assert_eq!(chain.generation_count(), 3);
        assert!(!chain.contains("a"));
        assert!(chain.extract("a").is_err());
        assert_eq!(chain.tombstone_count(), 1);
        // re-append revives it with new bytes
        chain.append_members(&members(&cfs[1..2], &["a"])).unwrap();
        assert_eq!(chain.extract("a").unwrap()[..], cfs[1].bytes[..]);

        // reopening from disk reproduces the same view exactly
        let reopened = PackChain::open(&dir).unwrap();
        assert_eq!(reopened.generation_count(), 4);
        assert_eq!(
            reopened.live_keys().collect::<Vec<_>>(),
            chain.live_keys().collect::<Vec<_>>()
        );
        for key in ["a", "b", "c"] {
            assert_eq!(reopened.extract(key).unwrap(), chain.extract(key).unwrap());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn chain_mutations_are_validated() {
        let dir = temp_chain_dir("validate");
        let _ = std::fs::remove_dir_all(&dir);
        let (cfs, _) = cohort(2, 520);
        let mut chain = PackChain::create(&dir).unwrap();
        assert!(PackChain::create(&dir).is_err(), "double create is refused");
        assert!(chain.append_members(&[]).is_err());
        assert!(chain.remove_members(&[]).is_err());
        assert!(
            chain.remove_members(&["ghost".to_string()]).is_err(),
            "tombstoning a non-member is refused"
        );
        chain.append_members(&members(&cfs, &["a", "b"])).unwrap();
        assert!(
            chain
                .remove_members(&["a".to_string(), "a".to_string()])
                .is_err(),
            "duplicate tombstones are refused"
        );
        // a failed commit leaves the chain intact
        assert_eq!(chain.live_len(), 2);
        assert!(
            chain
                .append_members(&[("junk".to_string(), vec![1u8, 2, 3].into())])
                .is_err(),
            "non-RFCZ members are refused"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_round_trips_and_rejects_defects() {
        let entries = vec![
            (1, Some("gen-00000001.rfpk".to_string()), vec![]),
            (3, None, vec!["user-1".to_string(), "user-2".to_string()]),
        ];
        let text = render_manifest(&entries, 4);
        let (parsed, next) = parse_manifest(&text).unwrap();
        assert_eq!(next, 4);
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[0].seq, 1);
        assert_eq!(parsed[0].file.as_deref(), Some("gen-00000001.rfpk"));
        assert_eq!(parsed[1].file, None);
        assert_eq!(parsed[1].tombstones, vec!["user-1", "user-2"]);

        for (bad, why) in [
            ("", "empty"),
            ("RFXX 1\nnext 1\n", "bad magic"),
            ("RFPM 9\nnext 1\n", "bad version"),
            ("RFPM 1\n", "missing next"),
            ("RFPM 1\nnext 0\n", "zero next"),
            ("RFPM 1\nnext 2\ngen 1 a.rfpk\ngen 1 b.rfpk", "duplicate seq"),
            ("RFPM 1\nnext 3\ngen 2 a.rfpk\ngen 1 b.rfpk", "non-monotone"),
            ("RFPM 1\nnext 1\ngen 1 a.rfpk", "next not past last"),
            ("RFPM 1\nnext 2\ngen 1 -", "tombstone-less empty gen"),
            ("RFPM 1\nnext 2\ngen 1 ../escape.rfpk", "traversal file"),
            ("RFPM 1\nnext 2\nbogus line", "unknown line"),
        ] {
            assert!(parse_manifest(bad).is_err(), "{why} must be rejected");
        }
    }
}
