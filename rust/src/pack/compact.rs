//! Chain compaction: merge a generation chain back into one fresh base.
//!
//! A chain accretes delta generations and tombstones with every mutation;
//! reads stay correct at any depth, but each generation is another mmap to
//! probe and every shadowed/tombstoned member is dead weight on disk.
//! Compaction collapses the chain to a single new base generation holding
//! exactly the live membership, clearing every tombstone, with the same
//! crash-safe manifest swap as any other commit — in-flight readers keep
//! serving off the old generations' `Arc`-held mappings until they drop
//! them (the unlinked files stay mapped; POSIX keeps the pages).
//!
//! Two modes:
//!
//! * [`CompactMode::Merge`] — byte-level: every live member is extracted
//!   **bit-identically** and re-packed; the pack-level blob dedup still
//!   collapses side-info spans that happen to match, but no member is
//!   re-encoded. This is the store-side default (no dataset in hand) and
//!   the mode the differential oracle in `tests/pack_chain_suite.rs` pins:
//!   a compacted chain reads byte-for-byte like a from-scratch pack of the
//!   same containers.
//! * [`CompactMode::Recluster`] — semantic: decode every live member back
//!   to its [`Forest`] and re-run [`super::compress_cohort`] over the
//!   merged membership, re-sharing codebooks across members that were
//!   appended in different delta cohorts and so never shared tables. Needs
//!   the training [`Dataset`] (the codec plan collects value alphabets
//!   from it), so it is CLI-only: `repro pack compact --chain DIR
//!   --dataset KEY`. Lossless at the forest level (decode → identical
//!   trees), not at the container-byte level.

use crate::compress::pipeline::decompress_container;
use crate::compress::CompressOptions;
use crate::data::Dataset;
use crate::forest::Forest;
use crate::pack::format::PackBuilder;
use crate::pack::generations::PackChain;
use anyhow::{Context, Result};
use std::sync::Arc;

/// How compaction rebuilds the merged membership.
pub enum CompactMode<'a> {
    /// Extract live containers bit-identically and re-pack them.
    Merge,
    /// Decode live members and re-run cohort compression over the union.
    Recluster {
        /// Training dataset the codec plan collects alphabets from.
        ds: &'a Dataset,
        /// Compression options for the re-run.
        opts: &'a CompressOptions,
    },
}

/// What a compaction did (logged by the CLI and folded into store stats).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CompactStats {
    /// Generations merged away.
    pub generations_before: usize,
    /// Live members carried into the new base.
    pub live_members: usize,
    /// Tombstone entries cleared.
    pub tombstones_cleared: u64,
    /// Archive bytes across the old generations.
    pub bytes_before: u64,
    /// Archive bytes of the new base (0 if the live set was empty).
    pub bytes_after: u64,
    /// The new base generation's sequence number.
    pub new_seq: u64,
}

/// Merge `chain` into a single fresh base generation and atomically swap
/// the manifest (old generation files are deleted after the swap; any
/// reader still holding their `Arc`s is unaffected). A chain that is
/// already a lone tombstone-free base is left untouched.
pub fn compact_chain(chain: &mut PackChain, mode: CompactMode<'_>) -> Result<CompactStats> {
    let before = chain.stats();
    if before.generations <= 1 && before.tombstones == 0 {
        return Ok(CompactStats {
            generations_before: before.generations,
            live_members: before.live_members,
            bytes_before: before.archive_bytes,
            bytes_after: before.archive_bytes,
            ..CompactStats::default()
        });
    }

    // collect the live membership in key order — deterministic, and the
    // same insertion order a from-scratch PackBuilder over the sorted
    // membership would see, which is what makes Merge bit-comparable to an
    // immutable rebuild
    let keys: Vec<String> = chain.live_keys().map(String::from).collect();
    let bytes = match mode {
        CompactMode::Merge => {
            let mut builder = PackBuilder::new();
            for key in &keys {
                let container = chain
                    .extract(key)
                    .with_context(|| format!("extracting {key:?} for compaction"))?;
                builder.add(key, Arc::<[u8]>::from(container))?;
            }
            if keys.is_empty() { Vec::new() } else { builder.build()?.0 }
        }
        CompactMode::Recluster { ds, opts } => {
            let forests: Vec<Forest> = keys
                .iter()
                .map(|key| {
                    let mut pc = chain
                        .parse(key)
                        .with_context(|| format!("parsing {key:?} for recompression"))?;
                    if pc.needs_dataset() {
                        pc.attach_dataset(ds).with_context(|| {
                            format!("attaching dataset to {key:?} for recompression")
                        })?;
                    }
                    decompress_container(&pc)
                        .with_context(|| format!("decoding {key:?} for recompression"))
                })
                .collect::<Result<_>>()?;
            let cohort = super::compress_cohort(&forests, ds, opts)
                .context("re-running cohort compression over the merged membership")?;
            let mut builder = PackBuilder::new();
            for (key, cf) in keys.iter().zip(&cohort) {
                builder.add(key, cf.bytes.clone())?;
            }
            if keys.is_empty() { Vec::new() } else { builder.build()?.0 }
        }
    };

    let new_seq = chain.install_compacted(bytes)?;
    let after = chain.stats();
    Ok(CompactStats {
        generations_before: before.generations,
        live_members: after.live_members,
        tombstones_cleared: before.tombstones,
        bytes_before: before.archive_bytes,
        bytes_after: after.archive_bytes,
        new_seq,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CompressedForest;
    use crate::data::synthetic;
    use crate::forest::ForestParams;
    use crate::pack::format::PackBuilder;
    use std::path::PathBuf;

    fn cohort(n: usize, seed: u64) -> (Vec<CompressedForest>, Dataset) {
        let ds = synthetic::iris(41);
        let forests: Vec<Forest> = (0..n)
            .map(|i| Forest::train(&ds, &ForestParams::classification(2), seed + i as u64))
            .collect();
        let cfs =
            crate::pack::compress_cohort(&forests, &ds, &CompressOptions::default()).unwrap();
        (cfs, ds)
    }

    fn temp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("rfc-compact-{tag}-{}", std::process::id()))
    }

    #[test]
    fn merge_compaction_matches_immutable_rebuild() {
        let dir = temp_dir("merge");
        let _ = std::fs::remove_dir_all(&dir);
        let (cfs, _) = cohort(5, 600);
        let mut chain = PackChain::create(&dir).unwrap();
        chain
            .append_members(&[
                ("a".to_string(), cfs[0].bytes.clone()),
                ("b".to_string(), cfs[1].bytes.clone()),
            ])
            .unwrap();
        chain
            .append_members(&[
                ("c".to_string(), cfs[2].bytes.clone()),
                ("b".to_string(), cfs[3].bytes.clone()), // replace b
            ])
            .unwrap();
        chain.remove_members(&["a".to_string()]).unwrap();
        chain
            .append_members(&[("d".to_string(), cfs[4].bytes.clone())])
            .unwrap();
        assert_eq!(chain.generation_count(), 4);

        let stats = compact_chain(&mut chain, CompactMode::Merge).unwrap();
        assert_eq!(stats.generations_before, 4);
        assert_eq!(stats.live_members, 3);
        assert_eq!(stats.tombstones_cleared, 1);
        assert_eq!(chain.generation_count(), 1);
        assert_eq!(chain.tombstone_count(), 0);

        // differential oracle: the compacted base is byte-identical to a
        // from-scratch pack of the same membership in the same key order
        let mut oracle = PackBuilder::new();
        oracle.add("b", cfs[3].bytes.clone()).unwrap();
        oracle.add("c", cfs[2].bytes.clone()).unwrap();
        oracle.add("d", cfs[4].bytes.clone()).unwrap();
        let (oracle_bytes, _) = oracle.build().unwrap();
        let base = chain.generations()[0].archive().unwrap();
        assert_eq!(
            base.archive_bytes(),
            oracle_bytes.len() as u64,
            "compacted base differs in size from the immutable rebuild"
        );
        for (key, want) in [("b", &cfs[3]), ("c", &cfs[2]), ("d", &cfs[4])] {
            assert_eq!(chain.extract(key).unwrap()[..], want.bytes[..]);
        }
        // old generation files are gone; reopen agrees
        let reopened = PackChain::open(&dir).unwrap();
        assert_eq!(reopened.generation_count(), 1);
        assert_eq!(reopened.live_len(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lone_base_compaction_is_a_noop() {
        let dir = temp_dir("noop");
        let _ = std::fs::remove_dir_all(&dir);
        let (cfs, _) = cohort(2, 620);
        let mut chain = PackChain::create(&dir).unwrap();
        chain
            .append_members(&[
                ("a".to_string(), cfs[0].bytes.clone()),
                ("b".to_string(), cfs[1].bytes.clone()),
            ])
            .unwrap();
        let seq_before = chain.resolve_seq("a").unwrap();
        let stats = compact_chain(&mut chain, CompactMode::Merge).unwrap();
        assert_eq!(stats.new_seq, 0, "noop compaction mints no generation");
        assert_eq!(chain.resolve_seq("a").unwrap(), seq_before);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recluster_compaction_is_forest_lossless() {
        let dir = temp_dir("recluster");
        let _ = std::fs::remove_dir_all(&dir);
        let ds = synthetic::iris(41);
        let forests: Vec<Forest> = (0..4)
            .map(|i| Forest::train(&ds, &ForestParams::classification(2), 640 + i as u64))
            .collect();
        let opts = CompressOptions::default();
        // two separately-compressed delta cohorts: their codebooks differ
        let c1 = crate::pack::compress_cohort(&forests[..2], &ds, &opts).unwrap();
        let c2 = crate::pack::compress_cohort(&forests[2..], &ds, &opts).unwrap();
        let mut chain = PackChain::create(&dir).unwrap();
        chain
            .append_members(&[
                ("m0".to_string(), c1[0].bytes.clone()),
                ("m1".to_string(), c1[1].bytes.clone()),
            ])
            .unwrap();
        chain
            .append_members(&[
                ("m2".to_string(), c2[0].bytes.clone()),
                ("m3".to_string(), c2[1].bytes.clone()),
            ])
            .unwrap();

        let stats =
            compact_chain(&mut chain, CompactMode::Recluster { ds: &ds, opts: &opts }).unwrap();
        assert_eq!(stats.live_members, 4);
        assert_eq!(chain.generation_count(), 1);
        // lossless at the forest level: decode → identical trees
        for (i, f) in forests.iter().enumerate() {
            let pc = chain.parse(&format!("m{i}")).unwrap();
            let decoded = decompress_container(&pc).unwrap();
            assert!(decoded.identical(f), "member m{i} changed under recluster");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compacting_to_empty_live_set_drops_every_generation() {
        let dir = temp_dir("empty");
        let _ = std::fs::remove_dir_all(&dir);
        let (cfs, _) = cohort(1, 660);
        let mut chain = PackChain::create(&dir).unwrap();
        chain
            .append_members(&[("a".to_string(), cfs[0].bytes.clone())])
            .unwrap();
        chain.remove_members(&["a".to_string()]).unwrap();
        let stats = compact_chain(&mut chain, CompactMode::Merge).unwrap();
        assert_eq!(stats.live_members, 0);
        assert_eq!(chain.generation_count(), 0);
        assert_eq!(chain.tombstone_count(), 0);
        let reopened = PackChain::open(&dir).unwrap();
        assert_eq!(reopened.generation_count(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
