//! CART tree growing (Breiman et al. 1984), as configured by Matlab's
//! `treeBagger` defaults — the trainer the paper uses (§6).
//!
//! * greedy recursive partitioning, no pruning (random-forest style)
//! * classification: Gini impurity; regression: variance reduction
//! * numeric splits: `x <= v` where `v` is an **observed value** (the left
//!   child's maximum) — the paper's index-coding of split values depends on
//!   split points being data values (§3.2.2)
//! * categorical splits: binary partition of levels found by the ordered-
//!   scan trick (exact for two classes / regression, standard heuristic for
//!   multiclass), stored as a ≤64-bit level mask
//! * a fit is computed for **every** node, not only leaves

use super::tree::{Fit, Node, Split, SplitValue, Tree};
use crate::data::{Column, Dataset, Target};
use crate::util::Pcg64;

/// Growth parameters for a single tree.
#[derive(Debug, Clone)]
pub struct TreeParams {
    /// Features tried per split; `None` ⇒ Matlab default (√d classification,
    /// max(1, d/3) regression), resolved at train time.
    pub mtry: Option<usize>,
    /// Minimum observations per leaf (`treeBagger` default: 1 classification,
    /// 5 regression).
    pub min_leaf: usize,
    /// Depth cap (u32::MAX = unpruned, the random-forest default).
    pub max_depth: u32,
}

impl TreeParams {
    /// `treeBagger` classification defaults (unpruned, min leaf 1).
    pub fn default_classification() -> Self {
        TreeParams { mtry: None, min_leaf: 1, max_depth: u32::MAX }
    }

    /// `treeBagger` regression defaults (unpruned, min leaf 5).
    pub fn default_regression() -> Self {
        TreeParams { mtry: None, min_leaf: 5, max_depth: u32::MAX }
    }

    /// Resolve `mtry` for a dataset with `d` features.
    pub fn resolved_mtry(&self, d: usize, classification: bool) -> usize {
        match self.mtry {
            Some(m) => m.clamp(1, d),
            None => {
                if classification {
                    ((d as f64).sqrt().ceil() as usize).clamp(1, d)
                } else {
                    (d / 3).max(1)
                }
            }
        }
    }
}

/// Build one CART tree over the given rows (typically a bootstrap sample).
pub fn build_tree(ds: &Dataset, rows: &[usize], params: &TreeParams, rng: &mut Pcg64) -> Tree {
    let classification = ds.target.is_classification();
    let mtry = params.resolved_mtry(ds.num_features(), classification);
    let mut ctx = BuildCtx {
        ds,
        params,
        mtry,
        rng,
        nodes: Vec::new(),
        classes: ds.target.num_classes() as usize,
    };
    let mut rows = rows.to_vec();
    ctx.grow(&mut rows, 0);
    Tree { nodes: ctx.nodes }
}

struct BuildCtx<'a> {
    ds: &'a Dataset,
    params: &'a TreeParams,
    mtry: usize,
    rng: &'a mut Pcg64,
    nodes: Vec<Node>,
    classes: usize,
}

impl<'a> BuildCtx<'a> {
    /// Grow the subtree over `rows`; returns its root's node index.
    /// Pushes the node *before* recursing ⇒ preorder storage.
    fn grow(&mut self, rows: &mut [usize], depth: u32) -> u32 {
        let idx = self.nodes.len() as u32;
        let fit = self.node_fit(rows);
        self.nodes.push(Node { split: None, fit });

        if rows.len() < 2 * self.params.min_leaf.max(1)
            || depth >= self.params.max_depth
            || self.is_pure(rows)
        {
            return idx;
        }
        let Some((split, gain)) = self.best_split(rows) else {
            return idx;
        };
        if gain <= 0.0 {
            return idx;
        }
        let mid = partition_rows(self.ds, rows, &split);
        // A degenerate partition can occur on constant features; guard.
        if mid == 0 || mid == rows.len() {
            return idx;
        }
        let (left_rows, right_rows) = rows.split_at_mut(mid);
        let l = self.grow(left_rows, depth + 1);
        let r = self.grow(right_rows, depth + 1);
        self.nodes[idx as usize].split = Some((split, l, r));
        idx
    }

    fn node_fit(&self, rows: &[usize]) -> Fit {
        match &self.ds.target {
            Target::Regression(y) => {
                let mean = rows.iter().map(|&r| y[r]).sum::<f64>() / rows.len() as f64;
                Fit::Regression(mean)
            }
            Target::Classification { labels, .. } => {
                let mut counts = vec![0u32; self.classes];
                for &r in rows {
                    counts[labels[r] as usize] += 1;
                }
                // majority; ties → smallest class index (determinism)
                let best = counts
                    .iter()
                    .enumerate()
                    .max_by_key(|&(i, &c)| (c, std::cmp::Reverse(i)))
                    .map(|(i, _)| i as u32)
                    .unwrap_or(0);
                Fit::Class(best)
            }
        }
    }

    fn is_pure(&self, rows: &[usize]) -> bool {
        match &self.ds.target {
            Target::Regression(y) => {
                let first = y[rows[0]];
                rows.iter().all(|&r| y[r] == first)
            }
            Target::Classification { labels, .. } => {
                let first = labels[rows[0]];
                rows.iter().all(|&r| labels[r] == first)
            }
        }
    }

    /// Best split over an `mtry`-sized random feature subset.
    fn best_split(&mut self, rows: &[usize]) -> Option<(Split, f64)> {
        let d = self.ds.num_features();
        let tried = self.rng.sample_indices(d, self.mtry.min(d));
        let mut best: Option<(Split, f64)> = None;
        for f in tried {
            let cand = match &self.ds.features[f].column {
                Column::Numeric(_) => self.best_numeric_split(rows, f),
                Column::Categorical { .. } => self.best_categorical_split(rows, f),
            };
            if let Some((split, gain)) = cand {
                if best.as_ref().map_or(true, |(_, g)| gain > *g) {
                    best = Some((split, gain));
                }
            }
        }
        best
    }

    fn best_numeric_split(&self, rows: &[usize], f: usize) -> Option<(Split, f64)> {
        let Column::Numeric(v) = &self.ds.features[f].column else { unreachable!() };
        let n = rows.len();
        let min_leaf = self.params.min_leaf.max(1);

        match &self.ds.target {
            Target::Regression(y) => {
                // §Perf: sort (value, target) pairs with cached keys — the
                // indirect sort_by(v[a] cmp v[b]) was the training profile's
                // top entry (random access per comparison)
                let mut pairs: Vec<(f64, f64)> =
                    rows.iter().map(|&r| (v[r], y[r])).collect();
                pairs.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
                let total_sum: f64 = pairs.iter().map(|p| p.1).sum();
                let mut left_sum = 0.0;
                let mut best_gain = 0.0;
                let mut best_value = None;
                // parent SSE-proxy: we maximize between-group sum of squares
                let parent = total_sum * total_sum / n as f64;
                for i in 0..n - 1 {
                    left_sum += pairs[i].1;
                    if pairs[i].0 == pairs[i + 1].0 {
                        continue; // not a valid cut between equal values
                    }
                    let nl = i + 1;
                    let nr = n - nl;
                    if nl < min_leaf || nr < min_leaf {
                        continue;
                    }
                    let right_sum = total_sum - left_sum;
                    // gain = reduction in SSE = BGSS (between-groups)
                    let gain =
                        left_sum * left_sum / nl as f64 + right_sum * right_sum / nr as f64 - parent;
                    if gain > best_gain {
                        best_gain = gain;
                        best_value = Some(pairs[i].0);
                    }
                }
                best_value.map(|t| {
                    (
                        Split { feature: f as u32, value: SplitValue::Numeric(t) },
                        best_gain,
                    )
                })
            }
            Target::Classification { labels, .. } => {
                let k = self.classes;
                let mut pairs: Vec<(f64, u32)> =
                    rows.iter().map(|&r| (v[r], labels[r])).collect();
                pairs.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
                let mut total = vec![0f64; k];
                for p in &pairs {
                    total[p.1 as usize] += 1.0;
                }
                let mut left = vec![0f64; k];
                let sum_sq = |c: &[f64], n: f64| -> f64 {
                    if n == 0.0 {
                        0.0
                    } else {
                        c.iter().map(|&x| x * x).sum::<f64>() / n
                    }
                };
                let parent_score = sum_sq(&total, n as f64);
                let mut best_gain = 0.0;
                let mut best_value = None;
                for i in 0..n - 1 {
                    left[pairs[i].1 as usize] += 1.0;
                    if pairs[i].0 == pairs[i + 1].0 {
                        continue;
                    }
                    let nl = (i + 1) as f64;
                    let nr = (n - i - 1) as f64;
                    if (nl as usize) < min_leaf || (nr as usize) < min_leaf {
                        continue;
                    }
                    // Gini gain ∝ Σc²/n (left) + Σc²/n (right) − Σc²/n (parent)
                    let mut lr = 0.0;
                    let mut rr = 0.0;
                    for c in 0..k {
                        lr += left[c] * left[c];
                        let r = total[c] - left[c];
                        rr += r * r;
                    }
                    let gain = lr / nl + rr / nr - parent_score;
                    if gain > best_gain {
                        best_gain = gain;
                        best_value = Some(pairs[i].0);
                    }
                }
                best_value.map(|t| {
                    (
                        Split { feature: f as u32, value: SplitValue::Numeric(t) },
                        best_gain,
                    )
                })
            }
        }
    }

    fn best_categorical_split(&self, rows: &[usize], f: usize) -> Option<(Split, f64)> {
        let Column::Categorical { values, levels } = &self.ds.features[f].column else {
            unreachable!()
        };
        let levels = *levels as usize;
        assert!(levels <= 64, "categorical features are limited to 64 levels");
        let min_leaf = self.params.min_leaf.max(1);
        let n = rows.len();

        // per-level stats
        let mut count = vec![0f64; levels];
        match &self.ds.target {
            Target::Regression(y) => {
                let mut sum = vec![0f64; levels];
                for &r in rows {
                    let l = values[r] as usize;
                    count[l] += 1.0;
                    sum[l] += y[r];
                }
                // order levels by mean target (exact scan for regression)
                let mut order: Vec<usize> = (0..levels).filter(|&l| count[l] > 0.0).collect();
                if order.len() < 2 {
                    return None;
                }
                order.sort_by(|&a, &b| {
                    (sum[a] / count[a]).partial_cmp(&(sum[b] / count[b])).unwrap()
                });
                let total_sum: f64 = sum.iter().sum();
                let mut ls = 0.0;
                let mut ln = 0.0;
                let mut best_gain = 0.0;
                let mut best_mask = None;
                let mut mask = 0u64;
                for w in 0..order.len() - 1 {
                    let l = order[w];
                    ls += sum[l];
                    ln += count[l];
                    mask |= 1 << l;
                    let rn = n as f64 - ln;
                    if (ln as usize) < min_leaf || (rn as usize) < min_leaf {
                        continue;
                    }
                    let rs = total_sum - ls;
                    let gain =
                        ls * ls / ln + rs * rs / rn - total_sum * total_sum / n as f64;
                    if gain > best_gain {
                        best_gain = gain;
                        best_mask = Some(mask);
                    }
                }
                best_mask.map(|m| {
                    (
                        Split { feature: f as u32, value: SplitValue::Categorical(m) },
                        best_gain,
                    )
                })
            }
            Target::Classification { labels, .. } => {
                let k = self.classes;
                let mut per_level = vec![vec![0f64; k]; levels];
                for &r in rows {
                    let l = values[r] as usize;
                    count[l] += 1.0;
                    per_level[l][labels[r] as usize] += 1.0;
                }
                let mut order: Vec<usize> = (0..levels).filter(|&l| count[l] > 0.0).collect();
                if order.len() < 2 {
                    return None;
                }
                // order by P(majority class | level): exact for 2 classes,
                // standard heuristic beyond
                let mut total = vec![0f64; k];
                for &r in rows {
                    total[labels[r] as usize] += 1.0;
                }
                let maj = total
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                order.sort_by(|&a, &b| {
                    (per_level[a][maj] / count[a])
                        .partial_cmp(&(per_level[b][maj] / count[b]))
                        .unwrap()
                });
                let sum_sq = |c: &[f64], nn: f64| -> f64 {
                    if nn == 0.0 {
                        0.0
                    } else {
                        c.iter().map(|&x| x * x).sum::<f64>() / nn
                    }
                };
                let parent_score = sum_sq(&total, n as f64);
                let mut left = vec![0f64; k];
                let mut ln = 0.0;
                let mut best_gain = 0.0;
                let mut best_mask = None;
                let mut mask = 0u64;
                for w in 0..order.len() - 1 {
                    let l = order[w];
                    for c in 0..k {
                        left[c] += per_level[l][c];
                    }
                    ln += count[l];
                    mask |= 1 << l;
                    let rn = n as f64 - ln;
                    if (ln as usize) < min_leaf || (rn as usize) < min_leaf {
                        continue;
                    }
                    let right: Vec<f64> = total.iter().zip(&left).map(|(t, l)| t - l).collect();
                    let gain = sum_sq(&left, ln) + sum_sq(&right, rn) - parent_score;
                    if gain > best_gain {
                        best_gain = gain;
                        best_mask = Some(mask);
                    }
                }
                best_mask.map(|m| {
                    (
                        Split { feature: f as u32, value: SplitValue::Categorical(m) },
                        best_gain,
                    )
                })
            }
        }
    }
}

/// Partition `rows` in place so rows routed left come first; returns the
/// boundary index.
fn partition_rows(ds: &Dataset, rows: &mut [usize], split: &Split) -> usize {
    let mut i = 0usize;
    let mut j = rows.len();
    while i < j {
        if super::tree::go_left(ds, rows[i], split) {
            i += 1;
        } else {
            j -= 1;
            rows.swap(i, j);
        }
    }
    i
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Column, Feature};

    fn step_ds() -> Dataset {
        // y = 1 when x > 5, else 0 — a single clean split
        let x: Vec<f64> = (0..100).map(|i| i as f64 / 10.0).collect();
        let labels: Vec<u32> = x.iter().map(|&v| (v > 5.0) as u32).collect();
        Dataset {
            name: "step".into(),
            features: vec![Feature { name: "x".into(), column: Column::Numeric(x) }],
            target: Target::Classification { labels, classes: 2 },
        }
    }

    #[test]
    fn learns_single_clean_split() {
        let ds = step_ds();
        let rows: Vec<usize> = (0..ds.num_rows()).collect();
        let mut rng = Pcg64::new(1);
        let t = build_tree(&ds, &rows, &TreeParams::default_classification(), &mut rng);
        // perfect split of a step function: one internal node
        assert_eq!(t.internal_count(), 1);
        match &t.nodes[0].split {
            Some((Split { feature: 0, value: SplitValue::Numeric(v) }, _, _)) => {
                assert!((*v - 5.0).abs() < 1e-9, "split at observed value 5.0, got {v}");
            }
            other => panic!("unexpected split {other:?}"),
        }
        for r in 0..ds.num_rows() {
            let Fit::Class(c) = t.predict_row(&ds, r) else { panic!() };
            let Target::Classification { labels, .. } = &ds.target else { panic!() };
            assert_eq!(c, labels[r]);
        }
    }

    #[test]
    fn split_value_is_observed_value() {
        // paper §3.2.2: numerical split specified by a single observation's value
        let ds = Dataset {
            name: "v".into(),
            features: vec![Feature {
                name: "x".into(),
                column: Column::Numeric(vec![1.0, 2.0, 7.0, 9.0]),
            }],
            target: Target::Regression(vec![0.0, 0.0, 10.0, 10.0]),
        };
        let rows: Vec<usize> = (0..4).collect();
        let mut rng = Pcg64::new(2);
        let params = TreeParams { mtry: Some(1), min_leaf: 1, max_depth: 1 };
        let t = build_tree(&ds, &rows, &params, &mut rng);
        if let Some((Split { value: SplitValue::Numeric(v), .. }, _, _)) = &t.nodes[0].split {
            assert!([1.0, 2.0, 7.0].contains(v), "split {v} must be an observed value");
        } else {
            panic!("expected a numeric split");
        }
    }

    #[test]
    fn regression_tree_reduces_mse() {
        let x: Vec<f64> = (0..200).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|&v| if v < 100.0 { 1.0 } else { 5.0 }).collect();
        let ds = Dataset {
            name: "r".into(),
            features: vec![Feature { name: "x".into(), column: Column::Numeric(x) }],
            target: Target::Regression(y.clone()),
        };
        let rows: Vec<usize> = (0..200).collect();
        let mut rng = Pcg64::new(3);
        let t = build_tree(&ds, &rows, &TreeParams::default_regression(), &mut rng);
        let preds: Vec<f64> = (0..200)
            .map(|r| match t.predict_row(&ds, r) {
                Fit::Regression(p) => p,
                _ => panic!(),
            })
            .collect();
        let err = crate::util::stats::mse(&preds, &y);
        assert!(err < 0.01, "mse={err}");
    }

    #[test]
    fn categorical_split_partitions_levels() {
        // level ∈ {0,2} → y=1, else y=0
        let values: Vec<u32> = (0..120).map(|i| (i % 4) as u32).collect();
        let labels: Vec<u32> = values.iter().map(|&v| (v == 0 || v == 2) as u32).collect();
        let ds = Dataset {
            name: "cat".into(),
            features: vec![Feature {
                name: "c".into(),
                column: Column::Categorical { values, levels: 4 },
            }],
            target: Target::Classification { labels: labels.clone(), classes: 2 },
        };
        let rows: Vec<usize> = (0..120).collect();
        let mut rng = Pcg64::new(4);
        let t = build_tree(&ds, &rows, &TreeParams::default_classification(), &mut rng);
        for r in 0..120 {
            let Fit::Class(c) = t.predict_row(&ds, r) else { panic!() };
            assert_eq!(c, labels[r]);
        }
        // the clean concept needs exactly one categorical split
        assert_eq!(t.internal_count(), 1);
        match &t.nodes[0].split {
            Some((Split { value: SplitValue::Categorical(m), .. }, _, _)) => {
                // mask must separate {0,2} from {1,3}
                let side0 = (m >> 0 & 1, m >> 2 & 1);
                let side1 = (m >> 1 & 1, m >> 3 & 1);
                assert_eq!(side0.0, side0.1);
                assert_eq!(side1.0, side1.1);
                assert_ne!(side0.0, side1.0);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn min_leaf_respected() {
        let ds = step_ds();
        let rows: Vec<usize> = (0..ds.num_rows()).collect();
        let mut rng = Pcg64::new(5);
        let params = TreeParams { mtry: Some(1), min_leaf: 20, max_depth: u32::MAX };
        let t = build_tree(&ds, &rows, &params, &mut rng);
        // check every leaf got >= 20 training rows by re-routing the rows
        let mut leaf_counts = vec![0usize; t.nodes.len()];
        for r in 0..ds.num_rows() {
            let mut idx = 0usize;
            loop {
                match &t.nodes[idx].split {
                    None => {
                        leaf_counts[idx] += 1;
                        break;
                    }
                    Some((s, l, rr)) => {
                        idx = if super::super::tree::go_left(&ds, r, s) {
                            *l as usize
                        } else {
                            *rr as usize
                        };
                    }
                }
            }
        }
        for (i, n) in t.nodes.iter().enumerate() {
            if n.is_leaf() {
                assert!(leaf_counts[i] >= 20, "leaf {i} has {} rows", leaf_counts[i]);
            }
        }
    }

    #[test]
    fn max_depth_respected() {
        let ds = step_ds();
        let rows: Vec<usize> = (0..ds.num_rows()).collect();
        let mut rng = Pcg64::new(6);
        let params = TreeParams { mtry: Some(1), min_leaf: 1, max_depth: 3 };
        let t = build_tree(&ds, &rows, &params, &mut rng);
        assert!(t.depth() <= 3);
    }

    #[test]
    fn constant_target_yields_single_leaf() {
        let ds = Dataset {
            name: "const".into(),
            features: vec![Feature {
                name: "x".into(),
                column: Column::Numeric(vec![1.0, 2.0, 3.0, 4.0]),
            }],
            target: Target::Regression(vec![7.0; 4]),
        };
        let rows: Vec<usize> = (0..4).collect();
        let mut rng = Pcg64::new(7);
        let t = build_tree(&ds, &rows, &TreeParams::default_regression(), &mut rng);
        assert_eq!(t.nodes.len(), 1);
        assert_eq!(t.nodes[0].fit, Fit::Regression(7.0));
    }

    #[test]
    fn constant_features_yield_single_leaf() {
        let ds = Dataset {
            name: "cf".into(),
            features: vec![Feature {
                name: "x".into(),
                column: Column::Numeric(vec![5.0; 10]),
            }],
            target: Target::Regression((0..10).map(|i| i as f64).collect()),
        };
        let rows: Vec<usize> = (0..10).collect();
        let mut rng = Pcg64::new(8);
        let t = build_tree(&ds, &rows, &TreeParams::default_regression(), &mut rng);
        assert_eq!(t.nodes.len(), 1);
    }

    #[test]
    fn trees_are_preorder() {
        let ds = step_ds();
        let rows: Vec<usize> = (0..ds.num_rows()).collect();
        let mut rng = Pcg64::new(9);
        let params = TreeParams { mtry: Some(1), min_leaf: 2, max_depth: u32::MAX };
        let t = build_tree(&ds, &rows, &params, &mut rng);
        assert!(t.is_preorder());
    }

    #[test]
    fn fits_present_at_internal_nodes() {
        let ds = step_ds();
        let rows: Vec<usize> = (0..ds.num_rows()).collect();
        let mut rng = Pcg64::new(10);
        let t = build_tree(&ds, &rows, &TreeParams::default_classification(), &mut rng);
        // every node, leaf or not, carries a usable fit
        for n in &t.nodes {
            match n.fit {
                Fit::Class(c) => assert!(c < 2),
                _ => panic!("classification tree must hold class fits"),
            }
        }
    }
}
