//! The random-forest ensemble: training (bootstrap + random feature
//! subsets), aggregation (majority vote / averaging), and the statistics the
//! compressor and benches need.

use super::builder::{build_tree, TreeParams};
use super::tree::{Fit, Tree};
use crate::data::{Dataset, Target};
use crate::util::threads::parallel_map;
use crate::util::Pcg64;

/// Ensemble training parameters.
#[derive(Debug, Clone)]
pub struct ForestParams {
    /// Number of trees (the paper uses 1000).
    pub n_trees: usize,
    /// Per-tree growth parameters.
    pub tree: TreeParams,
    /// Bootstrap-resample observations per tree (random-forest default).
    pub bootstrap: bool,
    /// Worker threads for training (1 = sequential).
    pub workers: usize,
}

impl ForestParams {
    /// `treeBagger`-default classification forest.
    pub fn classification(n_trees: usize) -> Self {
        ForestParams {
            n_trees,
            tree: TreeParams::default_classification(),
            bootstrap: true,
            workers: 1,
        }
    }

    /// `treeBagger`-default regression forest.
    pub fn regression(n_trees: usize) -> Self {
        ForestParams {
            n_trees,
            tree: TreeParams::default_regression(),
            bootstrap: true,
            workers: 1,
        }
    }
}

/// A trained random forest.
#[derive(Debug, Clone, PartialEq)]
pub struct Forest {
    /// The ensemble's trees.
    pub trees: Vec<Tree>,
    /// True when the target was classification (drives aggregation).
    pub classification: bool,
    /// Number of classes (0 for regression).
    pub classes: u32,
}

impl Forest {
    /// Train on a dataset. Each tree gets an independent RNG stream split
    /// from `seed`, so results are identical regardless of worker count.
    pub fn train(ds: &Dataset, params: &ForestParams, seed: u64) -> Forest {
        assert!(params.n_trees > 0, "need at least one tree");
        ds.validate().expect("invalid dataset");
        let mut root_rng = Pcg64::new(seed);
        let tree_rngs: Vec<Pcg64> = (0..params.n_trees)
            .map(|t| root_rng.split(t as u64))
            .collect();
        let n = ds.num_rows();
        let trees = parallel_map(&tree_rngs, params.workers, |_, rng| {
            let mut rng = rng.clone();
            let rows: Vec<usize> = if params.bootstrap {
                rng.bootstrap(n)
            } else {
                (0..n).collect()
            };
            build_tree(ds, &rows, &params.tree, &mut rng)
        });
        Forest {
            trees,
            classification: ds.target.is_classification(),
            classes: ds.target.num_classes(),
        }
    }

    /// Number of trees in the ensemble.
    pub fn num_trees(&self) -> usize {
        self.trees.len()
    }

    /// Total node count across trees.
    pub fn total_nodes(&self) -> usize {
        self.trees.iter().map(|t| t.nodes.len()).sum()
    }

    /// Mean tree depth (the paper quotes ~40 levels for Liberty).
    pub fn mean_depth(&self) -> f64 {
        if self.trees.is_empty() {
            return 0.0;
        }
        self.trees.iter().map(|t| t.depth() as f64).sum::<f64>() / self.trees.len() as f64
    }

    /// Maximal depth over all trees (the `T` of Algorithm 1).
    pub fn max_depth(&self) -> u32 {
        self.trees.iter().map(|t| t.depth()).max().unwrap_or(0)
    }

    /// Regression prediction: mean of tree predictions.
    pub fn predict_regression(&self, ds: &Dataset, row: usize) -> f64 {
        let mut sum = 0.0;
        for t in &self.trees {
            match t.predict_row(ds, row) {
                Fit::Regression(v) => sum += v,
                Fit::Class(_) => panic!("classification tree in regression forest"),
            }
        }
        sum / self.trees.len() as f64
    }

    /// Classification prediction: majority vote (ties → smaller class).
    pub fn predict_class(&self, ds: &Dataset, row: usize) -> u32 {
        let mut votes = vec![0u32; self.classes.max(1) as usize];
        for t in &self.trees {
            match t.predict_row(ds, row) {
                Fit::Class(c) => votes[c as usize] += 1,
                Fit::Regression(_) => panic!("regression tree in classification forest"),
            }
        }
        votes
            .iter()
            .enumerate()
            .max_by_key(|&(i, &v)| (v, std::cmp::Reverse(i)))
            .map(|(i, _)| i as u32)
            .unwrap_or(0)
    }

    /// Predict for all rows of a dataset.
    pub fn predict_all(&self, ds: &Dataset) -> Predictions {
        if self.classification {
            Predictions::Classes((0..ds.num_rows()).map(|r| self.predict_class(ds, r)).collect())
        } else {
            Predictions::Values(
                (0..ds.num_rows()).map(|r| self.predict_regression(ds, r)).collect(),
            )
        }
    }

    /// Test-set error: MSE for regression, misclassification rate otherwise.
    pub fn test_error(&self, ds: &Dataset) -> f64 {
        match (self.predict_all(ds), &ds.target) {
            (Predictions::Values(p), Target::Regression(y)) => crate::util::stats::mse(&p, y),
            (Predictions::Classes(p), Target::Classification { labels, .. }) => {
                crate::util::stats::misclassification(&p, labels)
            }
            _ => panic!("prediction/target kind mismatch"),
        }
    }

    /// Structural + fit equality (the losslessness check). `PartialEq` on
    /// `Forest` already does this; the method exists for call-site clarity.
    pub fn identical(&self, other: &Forest) -> bool {
        self == other
    }

    /// Append another forest's trees (paper §8: because the codec is
    /// lossless, an ensemble can be decompressed, *extended* with more
    /// trees, and recompressed — unlike the mimicking/pruning schemes).
    /// The target kinds must match.
    pub fn extend(&mut self, more: Forest) {
        assert_eq!(self.classification, more.classification, "target kind mismatch");
        assert_eq!(self.classes, more.classes, "class count mismatch");
        self.trees.extend(more.trees);
    }

    /// Train `extra` additional trees (with fresh RNG streams disjoint from
    /// the first `self.trees.len()` ones for the same `seed`) and append.
    pub fn grow_more(&mut self, ds: &Dataset, extra: usize, params: &ForestParams, seed: u64) {
        let offset = self.trees.len();
        let mut root_rng = Pcg64::new(seed);
        // burn the streams already used
        for t in 0..offset {
            let _ = root_rng.split(t as u64);
        }
        let tree_rngs: Vec<Pcg64> =
            (0..extra).map(|t| root_rng.split((offset + t) as u64)).collect();
        let n = ds.num_rows();
        let new_trees = parallel_map(&tree_rngs, params.workers, |_, rng| {
            let mut rng = rng.clone();
            let rows: Vec<usize> = if params.bootstrap {
                rng.bootstrap(n)
            } else {
                (0..n).collect()
            };
            super::builder::build_tree(ds, &rows, &params.tree, &mut rng)
        });
        self.trees.extend(new_trees);
    }
}

/// Forest predictions for a whole dataset.
#[derive(Debug, Clone, PartialEq)]
pub enum Predictions {
    /// Regression means, one per row.
    Values(Vec<f64>),
    /// Majority-vote class labels, one per row.
    Classes(Vec<u32>),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn train_and_predict_classification() {
        let ds = synthetic::iris(42);
        let f = Forest::train(&ds, &ForestParams::classification(15), 7);
        assert_eq!(f.num_trees(), 15);
        assert!(f.classification);
        // in-sample error of an unpruned forest should be very low
        let err = f.test_error(&ds);
        assert!(err < 0.15, "in-sample error {err}");
    }

    #[test]
    fn train_and_predict_regression() {
        let ds = synthetic::airfoil_regression(42);
        let f = Forest::train(&ds, &ForestParams::regression(10), 7);
        assert!(!f.classification);
        let err = f.test_error(&ds);
        // compare against predicting the mean (variance of y)
        let y = match &ds.target {
            crate::data::Target::Regression(y) => y,
            _ => unreachable!(),
        };
        let mean = y.iter().sum::<f64>() / y.len() as f64;
        let var = y.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / y.len() as f64;
        assert!(err < var * 0.5, "err {err} should beat mean-predictor var {var}");
    }

    #[test]
    fn deterministic_in_seed_and_worker_count() {
        let ds = synthetic::iris(1);
        let mut p = ForestParams::classification(6);
        let a = Forest::train(&ds, &p, 99);
        p.workers = 4;
        let b = Forest::train(&ds, &p, 99);
        assert!(a.identical(&b), "training must not depend on worker count");
        let c = Forest::train(&ds, &p, 100);
        assert!(!a.identical(&c));
    }

    #[test]
    fn trees_differ_across_ensemble() {
        let ds = synthetic::iris(2);
        let f = Forest::train(&ds, &ForestParams::classification(8), 3);
        // bootstrap + feature sampling ⇒ trees should not all be equal
        let all_same = f.trees.windows(2).all(|w| w[0] == w[1]);
        assert!(!all_same);
    }

    #[test]
    fn unpruned_depth_grows_with_data() {
        let small = synthetic::iris(3);
        let big = synthetic::airfoil_regression(3);
        let fs = Forest::train(&small, &ForestParams::classification(3), 1);
        let fb = Forest::train(&big, &ForestParams::regression(3), 1);
        assert!(
            fb.mean_depth() > fs.mean_depth(),
            "bigger dataset ⇒ deeper unpruned trees ({} vs {})",
            fb.mean_depth(),
            fs.mean_depth()
        );
    }

    #[test]
    fn grow_more_matches_larger_forest() {
        // §8 extension property: train 4 then grow 4 more == train 8 at once
        let ds = synthetic::iris(6);
        let params = ForestParams::classification(4);
        let mut grown = Forest::train(&ds, &params, 77);
        grown.grow_more(&ds, 4, &params, 77);
        let full = Forest::train(&ds, &ForestParams::classification(8), 77);
        assert!(grown.identical(&full), "incremental growth must match one-shot training");
    }

    #[test]
    fn extend_and_recompress_roundtrip() {
        // decompress → extend → recompress stays lossless (the paper's
        // "future modification" claim, §8)
        use crate::compress::{CompressOptions, CompressedForest};
        let ds = synthetic::iris(7);
        let f1 = Forest::train(&ds, &ForestParams::classification(3), 1);
        let cf = CompressedForest::compress(&f1, &ds, &CompressOptions::default()).unwrap();
        let mut restored = cf.decompress().unwrap();
        let f2 = Forest::train(&ds, &ForestParams::classification(2), 2);
        restored.extend(f2);
        assert_eq!(restored.num_trees(), 5);
        let cf2 = CompressedForest::compress(&restored, &ds, &CompressOptions::default()).unwrap();
        assert!(cf2.decompress().unwrap().identical(&restored));
    }

    #[test]
    fn ensemble_beats_single_tree_out_of_sample() {
        let ds = synthetic::wages(5);
        let mut rng = Pcg64::new(8);
        let tt = ds.train_test_split(0.8, &mut rng);
        let single = Forest::train(&tt.train, &ForestParams::classification(1), 4);
        let many = Forest::train(&tt.train, &ForestParams::classification(25), 4);
        let e1 = single.test_error(&tt.test);
        let e25 = many.test_error(&tt.test);
        assert!(
            e25 <= e1 + 0.02,
            "forest ({e25}) should not be much worse than single tree ({e1})"
        );
    }
}
