//! Random-forest substrate: CART trees grown with Matlab `treeBagger`
//! semantics (the paper's §6 setup) — unpruned, bootstrap-resampled, random
//! feature subsets per split, and a fit stored at **every** node ("in many
//! popular decision tree implementations … each node of the tree holds a
//! fit, in case of missing values during prediction", §3.3).
//!
//! * [`tree`]    — node/tree data structures, prediction, traversals
//! * [`builder`] — the CART growing algorithm (gini / variance reduction)
//! * [`forest`]  — the ensemble: training, aggregation, equality
//! * [`crt`]     — Completely-Randomized Trees (paper §8 discussion variant)

pub mod builder;
pub mod crt;
pub mod forest;
pub mod tree;

pub use builder::TreeParams;
pub use forest::{Forest, ForestParams};
pub use tree::{Fit, Node, Split, SplitValue, Tree};
