//! Decision-tree data structures.
//!
//! Nodes are stored **in preorder** (root first, then the whole left subtree,
//! then the right subtree). This is exactly the traversal order of the Zaks
//! sequence (§3.1), so the `i`-th `1` in a tree's Zaks string corresponds to
//! `nodes[i']` where `i'` counts internal nodes in storage order, which makes
//! the compressed representation and the in-memory one line up without any
//! index translation tables.

use crate::data::{Column, Dataset};

/// A split decision at an internal node.
#[derive(Debug, Clone, PartialEq)]
pub enum SplitValue {
    /// Numeric: rows with `x <= v` go left. `v` is always one of the feature's
    /// observed values (the paper relies on this to index split values by
    /// observation rank, §3.2.2).
    Numeric(f64),
    /// Categorical: rows whose level bit is set go left. Levels are capped at
    /// 64 (bitmask); the synthetic suite stays far below.
    Categorical(u64),
}

/// Feature index + split value.
#[derive(Debug, Clone, PartialEq)]
pub struct Split {
    /// Index of the feature this node splits on.
    pub feature: u32,
    /// The split threshold or category mask.
    pub value: SplitValue,
}

/// The fitted value stored at a node.
///
/// Bit-exact equality of fits is part of the losslessness contract, so
/// regression fits compare by `to_bits()`.
#[derive(Debug, Clone, Copy)]
pub enum Fit {
    /// A regression mean.
    Regression(f64),
    /// A class label.
    Class(u32),
}

impl PartialEq for Fit {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (Fit::Regression(a), Fit::Regression(b)) => a.to_bits() == b.to_bits(),
            (Fit::Class(a), Fit::Class(b)) => a == b,
            _ => false,
        }
    }
}
impl Eq for Fit {}

/// A tree node. `children = None` ⇒ leaf.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    /// Split and (left, right) child indices into `Tree::nodes`; `None` for
    /// leaves.
    pub split: Option<(Split, u32, u32)>,
    /// Fit value (present at every node, internal or leaf).
    pub fit: Fit,
}

impl Node {
    /// Whether the node has no split (a leaf).
    pub fn is_leaf(&self) -> bool {
        self.split.is_none()
    }
}

/// A decision tree with preorder node storage; `nodes[0]` is the root.
#[derive(Debug, Clone, PartialEq)]
pub struct Tree {
    /// Nodes in preorder; `nodes[0]` is the root.
    pub nodes: Vec<Node>,
}

impl Tree {
    /// Number of internal (split) nodes.
    pub fn internal_count(&self) -> usize {
        self.nodes.iter().filter(|n| !n.is_leaf()).count()
    }

    /// Number of leaves.
    pub fn leaf_count(&self) -> usize {
        self.nodes.len() - self.internal_count()
    }

    /// Maximum depth (root = depth 0); 0 for a single-leaf tree.
    pub fn depth(&self) -> u32 {
        if self.nodes.is_empty() {
            return 0;
        }
        let mut max = 0;
        let mut stack = vec![(0u32, 0u32)];
        while let Some((idx, d)) = stack.pop() {
            max = max.max(d);
            if let Some((_, l, r)) = &self.nodes[idx as usize].split {
                stack.push((*l, d + 1));
                stack.push((*r, d + 1));
            }
        }
        max
    }

    /// Predict for row `row` of `ds`; returns the fit at the reached leaf.
    pub fn predict_row(&self, ds: &Dataset, row: usize) -> Fit {
        let mut idx = 0usize;
        loop {
            let node = &self.nodes[idx];
            match &node.split {
                None => return node.fit,
                Some((split, l, r)) => {
                    idx = if go_left(ds, row, split) { *l as usize } else { *r as usize };
                }
            }
        }
    }

    /// Visit nodes in preorder with their depth and father's feature index
    /// (`None` at the root) — the exact conditioning information the paper's
    /// probabilistic models use (Algorithm 1 lines 8–12).
    pub fn visit_preorder<F>(&self, mut f: F)
    where
        F: FnMut(usize, &Node, u32, Option<u32>),
    {
        if self.nodes.is_empty() {
            return;
        }
        // (node index, depth, father feature)
        let mut stack = vec![(0u32, 0u32, None::<u32>)];
        while let Some((idx, depth, father)) = stack.pop() {
            let node = &self.nodes[idx as usize];
            f(idx as usize, node, depth, father);
            if let Some((split, l, r)) = &node.split {
                // push right first so left is visited first (preorder)
                stack.push((*r, depth + 1, Some(split.feature)));
                stack.push((*l, depth + 1, Some(split.feature)));
            }
        }
    }

    /// Check that node storage really is preorder (used by tests and by the
    /// container decoder, which rebuilds trees in preorder).
    pub fn is_preorder(&self) -> bool {
        let mut expected = 0usize;
        let mut ok = true;
        self.visit_preorder(|idx, _, _, _| {
            if idx != expected {
                ok = false;
            }
            expected += 1;
        });
        ok && expected == self.nodes.len()
    }
}

/// Split routing shared by trees and the compressed-format predictor.
pub fn go_left(ds: &Dataset, row: usize, split: &Split) -> bool {
    match (&ds.features[split.feature as usize].column, &split.value) {
        (Column::Numeric(v), SplitValue::Numeric(t)) => v[row] <= *t,
        (Column::Categorical { values, .. }, SplitValue::Categorical(mask)) => {
            mask >> values[row] & 1 == 1
        }
        _ => panic!("split kind does not match column kind"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Column, Feature, Target};

    /// Hand-built tree:        (x<=2)
    ///                        /      \
    ///                     leaf A   (x<=4)
    ///                              /    \
    ///                          leaf B  leaf C
    fn sample_tree() -> Tree {
        Tree {
            nodes: vec![
                Node {
                    split: Some((
                        Split { feature: 0, value: SplitValue::Numeric(2.0) },
                        1,
                        2,
                    )),
                    fit: Fit::Regression(10.0),
                },
                Node { split: None, fit: Fit::Regression(1.0) }, // A
                Node {
                    split: Some((
                        Split { feature: 0, value: SplitValue::Numeric(4.0) },
                        3,
                        4,
                    )),
                    fit: Fit::Regression(20.0),
                },
                Node { split: None, fit: Fit::Regression(2.0) }, // B
                Node { split: None, fit: Fit::Regression(3.0) }, // C
            ],
        }
    }

    fn sample_ds() -> Dataset {
        Dataset {
            name: "t".into(),
            features: vec![Feature {
                name: "x".into(),
                column: Column::Numeric(vec![1.0, 3.0, 5.0]),
            }],
            target: Target::Regression(vec![0.0, 0.0, 0.0]),
        }
    }

    #[test]
    fn counts_and_depth() {
        let t = sample_tree();
        assert_eq!(t.internal_count(), 2);
        assert_eq!(t.leaf_count(), 3);
        assert_eq!(t.depth(), 2);
    }

    #[test]
    fn prediction_routes_correctly() {
        let t = sample_tree();
        let ds = sample_ds();
        assert_eq!(t.predict_row(&ds, 0), Fit::Regression(1.0));
        assert_eq!(t.predict_row(&ds, 1), Fit::Regression(2.0));
        assert_eq!(t.predict_row(&ds, 2), Fit::Regression(3.0));
    }

    #[test]
    fn preorder_traversal_order_and_fathers() {
        let t = sample_tree();
        let mut visits = Vec::new();
        t.visit_preorder(|idx, _, depth, father| visits.push((idx, depth, father)));
        assert_eq!(
            visits,
            vec![
                (0, 0, None),
                (1, 1, Some(0)),
                (2, 1, Some(0)),
                (3, 2, Some(0)),
                (4, 2, Some(0)),
            ]
        );
        assert!(t.is_preorder());
    }

    #[test]
    fn categorical_routing() {
        let ds = Dataset {
            name: "c".into(),
            features: vec![Feature {
                name: "c".into(),
                column: Column::Categorical { values: vec![0, 1, 2], levels: 3 },
            }],
            target: Target::Regression(vec![0.0; 3]),
        };
        let split = Split { feature: 0, value: SplitValue::Categorical(0b101) };
        assert!(go_left(&ds, 0, &split)); // level 0 in mask
        assert!(!go_left(&ds, 1, &split)); // level 1 not
        assert!(go_left(&ds, 2, &split)); // level 2 in mask
    }

    #[test]
    fn fit_equality_is_bit_exact() {
        assert_eq!(Fit::Regression(0.1 + 0.2), Fit::Regression(0.1 + 0.2));
        assert_ne!(Fit::Regression(0.3), Fit::Regression(0.1 + 0.2));
        assert_eq!(Fit::Class(2), Fit::Class(2));
        assert_ne!(Fit::Class(2), Fit::Regression(2.0));
    }

    #[test]
    fn single_leaf_tree() {
        let t = Tree {
            nodes: vec![Node { split: None, fit: Fit::Class(1) }],
        };
        assert_eq!(t.depth(), 0);
        assert_eq!(t.leaf_count(), 1);
        assert!(t.is_preorder());
    }
}
