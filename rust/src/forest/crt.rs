//! Completely-Randomized Trees (CRT / extremely-randomized trees,
//! Geurts et al. 2006) — the variant the paper's discussion (§8) predicts
//! should compress *worse*: splits are chosen at random rather than
//! optimized, so the per-depth split distributions are closer to uniform and
//! entropy coding gains shrink. The `ablations` bench measures exactly that.

use super::builder::TreeParams;
use super::forest::{Forest, ForestParams};
use super::tree::{Fit, Node, Split, SplitValue, Tree};
use crate::data::{Column, Dataset, Target};
use crate::util::threads::parallel_map;
use crate::util::Pcg64;

/// Train a completely-randomized forest: each split picks a random feature
/// and a random split value (a uniformly drawn observation value for numeric
/// features, a random level subset for categorical ones).
pub fn train_crt(ds: &Dataset, params: &ForestParams, seed: u64) -> Forest {
    assert!(params.n_trees > 0);
    ds.validate().expect("invalid dataset");
    let mut root_rng = Pcg64::with_stream(seed, 0xc47);
    let tree_rngs: Vec<Pcg64> = (0..params.n_trees).map(|t| root_rng.split(t as u64)).collect();
    let n = ds.num_rows();
    let trees = parallel_map(&tree_rngs, params.workers, |_, rng| {
        let mut rng = rng.clone();
        let rows: Vec<usize> = if params.bootstrap {
            rng.bootstrap(n)
        } else {
            (0..n).collect()
        };
        let mut ctx = CrtCtx { ds, params: &params.tree, rng, nodes: Vec::new() };
        let mut rows = rows;
        ctx.grow(&mut rows, 0);
        Tree { nodes: ctx.nodes }
    });
    Forest {
        trees,
        classification: ds.target.is_classification(),
        classes: ds.target.num_classes(),
    }
}

struct CrtCtx<'a> {
    ds: &'a Dataset,
    params: &'a TreeParams,
    rng: Pcg64,
    nodes: Vec<Node>,
}

impl<'a> CrtCtx<'a> {
    fn grow(&mut self, rows: &mut [usize], depth: u32) -> u32 {
        let idx = self.nodes.len() as u32;
        let fit = self.fit(rows);
        self.nodes.push(Node { split: None, fit });
        if rows.len() < 2 * self.params.min_leaf.max(1)
            || depth >= self.params.max_depth
            || self.pure(rows)
        {
            return idx;
        }
        // try a handful of random splits until one produces two non-empty sides
        for _ in 0..8 {
            let Some(split) = self.random_split(rows) else { continue };
            let mid = {
                // partition in place
                let mut i = 0usize;
                let mut j = rows.len();
                while i < j {
                    if super::tree::go_left(self.ds, rows[i], &split) {
                        i += 1;
                    } else {
                        j -= 1;
                        rows.swap(i, j);
                    }
                }
                i
            };
            let min_leaf = self.params.min_leaf.max(1);
            if mid < min_leaf || rows.len() - mid < min_leaf {
                continue;
            }
            let (lrows, rrows) = rows.split_at_mut(mid);
            let l = self.grow(lrows, depth + 1);
            let r = self.grow(rrows, depth + 1);
            self.nodes[idx as usize].split = Some((split, l, r));
            return idx;
        }
        idx
    }

    fn random_split(&mut self, rows: &[usize]) -> Option<Split> {
        let f = self.rng.gen_index(self.ds.num_features());
        match &self.ds.features[f].column {
            Column::Numeric(v) => {
                let pick = v[rows[self.rng.gen_index(rows.len())]];
                // ensure both sides can be non-empty
                if rows.iter().all(|&r| v[r] <= pick) {
                    return None;
                }
                Some(Split { feature: f as u32, value: SplitValue::Numeric(pick) })
            }
            Column::Categorical { levels, .. } => {
                let mut mask = 0u64;
                for l in 0..*levels {
                    if self.rng.gen_bool(0.5) {
                        mask |= 1 << l;
                    }
                }
                if mask == 0 || mask == (1u64 << levels) - 1 {
                    mask = 1;
                }
                Some(Split { feature: f as u32, value: SplitValue::Categorical(mask) })
            }
        }
    }

    fn fit(&self, rows: &[usize]) -> Fit {
        match &self.ds.target {
            Target::Regression(y) => {
                Fit::Regression(rows.iter().map(|&r| y[r]).sum::<f64>() / rows.len() as f64)
            }
            Target::Classification { labels, classes } => {
                let mut counts = vec![0u32; *classes as usize];
                for &r in rows {
                    counts[labels[r] as usize] += 1;
                }
                Fit::Class(
                    counts
                        .iter()
                        .enumerate()
                        .max_by_key(|&(i, &c)| (c, std::cmp::Reverse(i)))
                        .map(|(i, _)| i as u32)
                        .unwrap_or(0),
                )
            }
        }
    }

    fn pure(&self, rows: &[usize]) -> bool {
        match &self.ds.target {
            Target::Regression(y) => rows.iter().all(|&r| y[r] == y[rows[0]]),
            Target::Classification { labels, .. } => {
                rows.iter().all(|&r| labels[r] == labels[rows[0]])
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn crt_trains_and_predicts() {
        let ds = synthetic::iris(11);
        let f = train_crt(&ds, &ForestParams::classification(10), 3);
        assert_eq!(f.num_trees(), 10);
        let err = f.test_error(&ds);
        assert!(err < 0.5, "CRT should still beat random guessing, err={err}");
        for t in &f.trees {
            assert!(t.is_preorder());
        }
    }

    #[test]
    fn crt_split_features_more_uniform_than_cart() {
        // §8: CRT splits are random ⇒ the root-feature distribution should be
        // closer to uniform than CART's (which concentrates on informative
        // features). Compare entropies of root split features.
        let ds = synthetic::wages(13);
        let cart = Forest::train(&ds, &ForestParams::classification(30), 5);
        let crt = train_crt(&ds, &ForestParams::classification(30), 5);
        let root_feature_entropy = |f: &Forest| {
            let d = ds.num_features();
            let mut counts = vec![0u64; d];
            for t in &f.trees {
                if let Some((s, _, _)) = &t.nodes[0].split {
                    counts[s.feature as usize] += 1;
                }
            }
            crate::coding::entropy::entropy_counts(&counts)
        };
        let h_cart = root_feature_entropy(&cart);
        let h_crt = root_feature_entropy(&crt);
        assert!(
            h_crt > h_cart,
            "CRT root features should be higher-entropy (crt={h_crt:.2} cart={h_cart:.2})"
        );
    }

    #[test]
    fn crt_deterministic() {
        let ds = synthetic::iris(21);
        let a = train_crt(&ds, &ForestParams::classification(4), 9);
        let b = train_crt(&ds, &ForestParams::classification(4), 9);
        assert!(a.identical(&b));
    }
}
