//! The K sweep of Algorithm 1 (lines 22–30): run the clustering for each
//! candidate K and keep the minimizer of
//!
//! ```text
//! J(K) = Σᵢ nᵢ·D_KL(Pᵢ‖Q_{aᵢ})  +  α·B·K          (eq. 6)
//! ```
//!
//! where `α` is the per-dictionary-line cost ([`DictCost`]) and `B` the
//! alphabet size (the paper's upper bound on `‖Q_k‖₀`).

use super::kmeans::{cluster_k, Clustering, LloydEngine};
use crate::coding::entropy::DictCost;
use anyhow::Result;
use std::collections::BTreeMap;

use crate::model::extract::CountTable;

/// Result of a K sweep.
#[derive(Debug, Clone)]
pub struct SweepResult {
    /// The winning clustering over the swept K values.
    pub best: Clustering,
    /// Total objective of the winner (data bits + α·B·K).
    pub objective: f64,
    /// Objective per tried K (for the ablation bench / diagnostics).
    pub per_k: Vec<(usize, f64)>,
    /// The conditioning keys in input order (row i of the matrix).
    pub keys: Vec<crate::model::ContextKey>,
}

/// Sweep K from 1 to `k_max` (clamped to the number of distinct models) and
/// return the objective minimizer. `table` maps context keys to count
/// vectors over a common alphabet.
pub fn sweep_k(
    table: &CountTable,
    alpha: DictCost,
    k_max: usize,
    seed: u64,
    engine: &mut dyn LloydEngine,
) -> Result<SweepResult> {
    let (keys, p, w, b) = table_to_matrix(table);
    let m = keys.len();
    assert!(m > 0, "no models to cluster");
    let k_cap = k_max.clamp(1, m);

    let mut best: Option<(Clustering, f64)> = None;
    let mut per_k = Vec::new();
    for k in 1..=k_cap {
        let c = cluster_k(&p, &w, m, b, k, seed ^ (k as u64) << 32, engine)?;
        let obj = c.data_bits + alpha.alpha * b as f64 * k as f64;
        per_k.push((k, obj));
        if best.as_ref().map_or(true, |(_, bo)| obj < *bo) {
            best = Some((c, obj));
        }
        // early exit: once the penalty alone exceeds the current best,
        // larger K cannot win (data term is non-negative)
        if let Some((_, bo)) = &best {
            if alpha.alpha * b as f64 * (k + 1) as f64 > *bo {
                break;
            }
        }
    }
    let (best, objective) = best.unwrap();
    Ok(SweepResult { best, objective, per_k, keys })
}

/// Flatten a count table into (keys, row-major P, weights, alphabet size).
/// Rows are normalized; weights are the sequence lengths `n_i`.
pub fn table_to_matrix(
    table: &CountTable,
) -> (Vec<crate::model::ContextKey>, Vec<f64>, Vec<f64>, usize) {
    let b = table.values().map(|v| v.len()).max().unwrap_or(1);
    let mut keys = Vec::with_capacity(table.len());
    let mut p = Vec::with_capacity(table.len() * b);
    let mut w = Vec::with_capacity(table.len());
    for (key, counts) in table {
        let total: u64 = counts.iter().sum();
        if total == 0 {
            continue; // empty context — nothing to encode
        }
        keys.push(*key);
        w.push(total as f64);
        for i in 0..b {
            let c = counts.get(i).copied().unwrap_or(0);
            p.push(c as f64 / total as f64);
        }
    }
    (keys, p, w, b)
}

/// Aggregate member counts per cluster — the exact codebook inputs
/// (losslessness requires codebook support ⊇ member support, which summing
/// counts guarantees).
pub fn cluster_counts(
    table: &CountTable,
    keys: &[crate::model::ContextKey],
    assignments: &[u32],
    k: usize,
) -> Vec<Vec<u64>> {
    let b = table.values().map(|v| v.len()).max().unwrap_or(1);
    let mut out = vec![vec![0u64; b]; k];
    for (key, &a) in keys.iter().zip(assignments) {
        if let Some(counts) = table.get(key) {
            for (dst, &c) in out[a as usize].iter_mut().zip(counts) {
                *dst += c;
            }
        }
    }
    out
}

/// Map every context key to its cluster id.
pub fn assignment_map(
    keys: &[crate::model::ContextKey],
    assignments: &[u32],
) -> BTreeMap<crate::model::ContextKey, u32> {
    keys.iter().copied().zip(assignments.iter().copied()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::kmeans::NativeEngine;
    use crate::model::ContextKey;

    fn table_from(rows: &[(u16, u32, Vec<u64>)]) -> CountTable {
        rows.iter()
            .map(|(d, f, c)| (ContextKey { depth: *d, father: *f }, c.clone()))
            .collect()
    }

    #[test]
    fn sweep_prefers_few_clusters_when_alpha_large() {
        // two similar models + one different; huge alpha ⇒ K=1 wins
        let table = table_from(&[
            (0, 0, vec![90, 5, 5]),
            (1, 0, vec![85, 10, 5]),
            (2, 0, vec![5, 5, 90]),
        ]);
        let mut eng = NativeEngine;
        let r = sweep_k(&table, DictCost { alpha: 1e9 }, 3, 1, &mut eng).unwrap();
        assert_eq!(r.best.k, 1);
    }

    #[test]
    fn sweep_prefers_more_clusters_when_alpha_small() {
        let table = table_from(&[
            (0, 0, vec![900, 50, 50]),
            (1, 0, vec![850, 100, 50]),
            (2, 0, vec![50, 50, 900]),
            (3, 0, vec![40, 60, 900]),
        ]);
        let mut eng = NativeEngine;
        let r = sweep_k(&table, DictCost { alpha: 0.01 }, 4, 1, &mut eng).unwrap();
        assert!(r.best.k >= 2, "tiny alpha should allow separation, k={}", r.best.k);
        // the two dissimilar groups must land in different clusters (they may
        // be split further — with tiny alpha even K=4 can win)
        let a = &r.best.assignments;
        assert_ne!(a[0], a[2]);
        assert_ne!(a[1], a[3]);
    }

    #[test]
    fn alpha_tradeoff_is_monotone_in_cluster_count() {
        // the paper's §6 observation (64-bit α ⇒ 2–3 clusters; 32-bit ⇒ ~7):
        // smaller alpha must never yield fewer clusters
        let table = table_from(&[
            (0, 0, vec![980, 10, 5, 5]),
            (1, 0, vec![800, 100, 50, 50]),
            (2, 0, vec![500, 300, 100, 100]),
            (3, 0, vec![300, 300, 200, 200]),
            (4, 0, vec![250, 250, 250, 250]),
            (5, 0, vec![100, 200, 350, 350]),
        ]);
        let mut eng = NativeEngine;
        let mut prev_k = 0usize;
        for alpha in [1000.0, 100.0, 10.0, 0.1] {
            let r = sweep_k(&table, DictCost { alpha }, 6, 2, &mut eng).unwrap();
            assert!(
                r.best.k >= prev_k,
                "alpha {alpha}: k={} should be >= previous {prev_k} (smaller α ⇒ more clusters)",
                r.best.k
            );
            prev_k = r.best.k;
        }
        assert!(prev_k >= 2, "smallest alpha should separate models");
    }

    #[test]
    fn cluster_counts_cover_member_support() {
        let table = table_from(&[
            (0, 0, vec![10, 0, 0]),
            (1, 0, vec![0, 10, 0]),
        ]);
        let mut eng = NativeEngine;
        let r = sweep_k(&table, DictCost { alpha: 1e9 }, 2, 3, &mut eng).unwrap();
        assert_eq!(r.best.k, 1);
        let cc = cluster_counts(&table, &r.keys, &r.best.assignments, 1);
        // merged cluster must have support over symbols 0 and 1
        assert!(cc[0][0] > 0 && cc[0][1] > 0);
    }

    #[test]
    fn empty_contexts_skipped() {
        let table = table_from(&[
            (0, 0, vec![10, 10]),
            (1, 0, vec![0, 0]),
        ]);
        let mut eng = NativeEngine;
        let r = sweep_k(&table, DictCost { alpha: 1.0 }, 2, 1, &mut eng).unwrap();
        assert_eq!(r.keys.len(), 1);
    }

    #[test]
    fn per_k_records_objectives() {
        let table = table_from(&[
            (0, 0, vec![9, 1]),
            (1, 0, vec![1, 9]),
        ]);
        let mut eng = NativeEngine;
        let r = sweep_k(&table, DictCost { alpha: 0.5 }, 2, 1, &mut eng).unwrap();
        assert!(!r.per_k.is_empty());
        let min = r.per_k.iter().map(|&(_, o)| o).fold(f64::INFINITY, f64::min);
        assert!((min - r.objective).abs() < 1e-9);
    }
}
