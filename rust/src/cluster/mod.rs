//! Model clustering under weighted KL divergence with a dictionary-cost
//! penalty — eq. (6) of the paper, the "Bregman divergence clustering" of
//! its title. The cluster means under KL are plain weighted averages
//! (Banerjee et al. 2005), so this is a K-means variant with KL as the
//! distortion.
//!
//! * [`kmeans`] — one clustering at fixed K (Lloyd iterations, k-means++
//!   init, empty-cluster repair), generic over a [`LloydEngine`] so the
//!   inner iteration can run natively or on the AOT-compiled XLA artifact
//!   (see `runtime::xla_engine`)
//! * [`sweep`]  — the K sweep of Algorithm 1 (lines 22–30): minimize
//!   `Σᵢ nᵢ·D_KL(Pᵢ‖Q_{aᵢ}) + α·B·K` over K
//!
//! The data term is in bits (log₂), matching the α constants of
//! [`crate::coding::entropy::DictCost`].

pub mod kmeans;
pub mod sweep;

pub use kmeans::{cluster_k, Clustering, LloydEngine, LloydStep, NativeEngine};
pub use sweep::{sweep_k, SweepResult};
