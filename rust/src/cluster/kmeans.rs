//! Weighted-KL K-means (Lloyd) at fixed K.
//!
//! Input: M empirical distributions `P_i` over a common alphabet of size B,
//! with sequence-length weights `n_i`. Distortion: `n_i · D_KL(P_i ‖ Q_k)`.
//! Centroids: weighted means of members (the KL/Bregman centroid).
//!
//! The inner iteration — the M×K divergence matrix, the argmin assignment,
//! and the centroid update — is behind the [`LloydEngine`] trait. The
//! [`NativeEngine`] here is the reference implementation; the AOT-compiled
//! JAX/Pallas version (`runtime::xla_engine`) must match it to ~1e-6
//! (asserted by integration tests).

use crate::util::Pcg64;
use anyhow::Result;

/// Smoothing mixed into centroids for divergence computation, keeping
/// `D_KL(P_i ‖ Q_k)` finite when a candidate cluster lacks a member's
/// support. Final codebooks are built from exact member counts, so this
/// never affects losslessness — only assignment decisions at the margin.
pub const CENTROID_EPS: f64 = 1e-9;

/// One Lloyd iteration's outputs.
#[derive(Debug, Clone)]
pub struct LloydStep {
    /// Per-input cluster assignment.
    pub assign: Vec<u32>,
    /// Updated centroids, row-major K×B (weighted means of members).
    pub new_q: Vec<f64>,
    /// Data term of the objective: `Σᵢ nᵢ·D_KL(Pᵢ‖Q_{aᵢ})` in bits,
    /// evaluated at the *input* centroids.
    pub objective: f64,
}

/// The inner-iteration engine: everything that is matmul-shaped and worth
/// offloading to the AOT XLA artifact.
pub trait LloydEngine {
    /// One iteration. `p` is M×B row-major, `w` has length M, `q` is K×B
    /// row-major (already smoothed/normalized).
    fn step(&mut self, p: &[f64], w: &[f64], q: &[f64], m: usize, b: usize, k: usize)
        -> Result<LloydStep>;

    /// Engine label for logs/benches.
    fn name(&self) -> &'static str {
        "native"
    }
}

/// Pure-rust reference engine.
///
/// Uses the cross-entropy decomposition the Pallas kernel also uses:
/// `n_i·KL(P_i‖Q_k) = n_i·Σ_b P_ib·log(P_ib) − Σ_b (n_i·P_ib)·log(Q_kb)` —
/// the first term is assignment-invariant, the second is a weighted matmul
/// against `log Q`.
#[derive(Debug, Default, Clone)]
pub struct NativeEngine;

impl LloydEngine for NativeEngine {
    fn step(
        &mut self,
        p: &[f64],
        w: &[f64],
        q: &[f64],
        m: usize,
        b: usize,
        k: usize,
    ) -> Result<LloydStep> {
        debug_assert_eq!(p.len(), m * b);
        debug_assert_eq!(w.len(), m);
        debug_assert_eq!(q.len(), k * b);
        // precompute log q (clamped: q is smoothed so strictly positive)
        let log_q: Vec<f64> = q.iter().map(|&x| x.max(f64::MIN_POSITIVE).log2()).collect();
        let mut assign = vec![0u32; m];
        let mut objective = 0.0;
        // §Perf: split-value distributions are extremely sparse over large
        // alphabets (a (depth, father) context uses a handful of the
        // feature's thresholds), so gather each row's support once and run
        // the K-way cross-entropy over the non-zeros only.
        let mut support: Vec<(u32, f64)> = Vec::with_capacity(b.min(64));
        for i in 0..m {
            let pi = &p[i * b..(i + 1) * b];
            support.clear();
            let mut self_term = 0.0;
            for (j, &x) in pi.iter().enumerate() {
                if x > 0.0 {
                    support.push((j as u32, x));
                    self_term += x * x.log2();
                }
            }
            let mut best = f64::INFINITY;
            let mut best_k = 0u32;
            for kk in 0..k {
                let lq = &log_q[kk * b..(kk + 1) * b];
                let mut ce = 0.0;
                for &(j, x) in &support {
                    ce += x * lq[j as usize];
                }
                let kl = self_term - ce;
                if kl < best {
                    best = kl;
                    best_k = kk as u32;
                }
            }
            assign[i] = best_k;
            objective += w[i] * best.max(0.0);
        }
        // centroid update: weighted mean of members (sparse rows again)
        let mut new_q = vec![0.0f64; k * b];
        let mut mass = vec![0.0f64; k];
        for i in 0..m {
            let kk = assign[i] as usize;
            mass[kk] += w[i];
            let pi = &p[i * b..(i + 1) * b];
            let row = &mut new_q[kk * b..(kk + 1) * b];
            for (j, &x) in pi.iter().enumerate() {
                if x > 0.0 {
                    row[j] += w[i] * x;
                }
            }
        }
        for kk in 0..k {
            if mass[kk] > 0.0 {
                for x in new_q[kk * b..(kk + 1) * b].iter_mut() {
                    *x /= mass[kk];
                }
            }
        }
        Ok(LloydStep { assign, new_q, objective })
    }
}

/// A fixed-K clustering result.
#[derive(Debug, Clone)]
pub struct Clustering {
    /// Number of clusters.
    pub k: usize,
    /// Cluster index per input row.
    pub assignments: Vec<u32>,
    /// Final centroids (K×B row-major), un-smoothed weighted means.
    pub centroids: Vec<f64>,
    /// Data term `Σᵢ nᵢ·D_KL` in bits at convergence.
    pub data_bits: f64,
}

/// Smooth + renormalize a centroid matrix for divergence computation.
fn smooth(q: &[f64], k: usize, b: usize) -> Vec<f64> {
    let mut out = vec![0.0; k * b];
    for kk in 0..k {
        let row = &q[kk * b..(kk + 1) * b];
        let total: f64 = row.iter().sum();
        let out_row = &mut out[kk * b..(kk + 1) * b];
        if total <= 0.0 {
            for x in out_row.iter_mut() {
                *x = 1.0 / b as f64;
            }
        } else {
            let scale = 1.0 / (total * (1.0 + CENTROID_EPS * b as f64));
            for (o, &x) in out_row.iter_mut().zip(row) {
                *o = (x + total * CENTROID_EPS) * scale;
            }
        }
    }
    out
}

/// Cluster M weighted distributions into (at most) `k` groups.
///
/// `p` is M×B row-major with rows summing to 1; `w` are the sequence
/// lengths `n_i`. Deterministic in `seed`.
pub fn cluster_k(
    p: &[f64],
    w: &[f64],
    m: usize,
    b: usize,
    k: usize,
    seed: u64,
    engine: &mut dyn LloydEngine,
) -> Result<Clustering> {
    assert!(m > 0 && b > 0);
    let k = k.clamp(1, m);
    let mut rng = Pcg64::with_stream(seed, 0xc1u64);

    // --- k-means++ init over KL distance ---
    let mut centroid_rows: Vec<usize> = Vec::with_capacity(k);
    // first: weight-proportional draw
    let total_w: f64 = w.iter().sum();
    let first = weighted_pick(&mut rng, w, total_w);
    centroid_rows.push(first);
    let mut min_d: Vec<f64> = (0..m)
        .map(|i| kl_rows(p, i, p, first, b).max(0.0) * w[i])
        .collect();
    while centroid_rows.len() < k {
        let total: f64 = min_d.iter().sum();
        let next = if total <= 0.0 {
            // all points identical to chosen centroids: pick arbitrary distinct
            (0..m).find(|i| !centroid_rows.contains(i)).unwrap_or(0)
        } else {
            weighted_pick(&mut rng, &min_d, total)
        };
        centroid_rows.push(next);
        for i in 0..m {
            let d = kl_rows(p, i, p, next, b).max(0.0) * w[i];
            if d < min_d[i] {
                min_d[i] = d;
            }
        }
    }
    let mut q: Vec<f64> = Vec::with_capacity(k * b);
    for &r in &centroid_rows {
        q.extend_from_slice(&p[r * b..(r + 1) * b]);
    }

    // --- Lloyd iterations ---
    let mut prev_assign: Option<Vec<u32>> = None;
    let mut prev_obj = f64::INFINITY;
    let mut last = LloydStep { assign: vec![0; m], new_q: q.clone(), objective: f64::INFINITY };
    for _iter in 0..40 {
        let sq = smooth(&q, k, b);
        let mut step = engine.step(p, w, &sq, m, b, k)?;
        // empty-cluster repair: move the worst-fitting point into the hole
        let mut counts = vec![0usize; k];
        for &a in &step.assign {
            counts[a as usize] += 1;
        }
        for kk in 0..k {
            if counts[kk] == 0 {
                // point with max weighted divergence from its centroid
                let sq2 = smooth(&step.new_q, k, b);
                let worst = (0..m)
                    .max_by(|&a2, &b2| {
                        let da = w[a2] * kl_rows(p, a2, &sq2, step.assign[a2] as usize, b);
                        let db = w[b2] * kl_rows(p, b2, &sq2, step.assign[b2] as usize, b);
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap();
                if counts[step.assign[worst] as usize] > 1 {
                    counts[step.assign[worst] as usize] -= 1;
                    step.assign[worst] = kk as u32;
                    counts[kk] = 1;
                    // recompute centroids for the affected clusters
                    recompute_centroids(p, w, &step.assign, m, b, k, &mut step.new_q);
                }
            }
        }
        // converged when assignments are stable or the objective stops
        // moving (relative 1e-6 — avoids oscillation on near-ties)
        let converged = prev_assign.as_ref() == Some(&step.assign)
            || (prev_obj - step.objective).abs() <= 1e-6 * prev_obj.abs().max(1.0);
        prev_obj = step.objective;
        q = step.new_q.clone();
        prev_assign = Some(step.assign.clone());
        last = step;
        if converged {
            break;
        }
    }

    // final data term evaluated at the final (smoothed) centroids
    let sq = smooth(&q, k, b);
    let mut data_bits = 0.0;
    for i in 0..m {
        data_bits += w[i] * kl_rows(p, i, &sq, last.assign[i] as usize, b).max(0.0);
    }
    Ok(Clustering { k, assignments: last.assign, centroids: q, data_bits })
}

fn recompute_centroids(
    p: &[f64],
    w: &[f64],
    assign: &[u32],
    m: usize,
    b: usize,
    k: usize,
    q: &mut Vec<f64>,
) {
    q.iter_mut().for_each(|x| *x = 0.0);
    let mut mass = vec![0.0f64; k];
    for i in 0..m {
        let kk = assign[i] as usize;
        mass[kk] += w[i];
        for (dst, x) in q[kk * b..(kk + 1) * b].iter_mut().zip(&p[i * b..(i + 1) * b]) {
            *dst += w[i] * x;
        }
    }
    for kk in 0..k {
        if mass[kk] > 0.0 {
            for x in q[kk * b..(kk + 1) * b].iter_mut() {
                *x /= mass[kk];
            }
        }
    }
}

#[inline]
fn kl_rows(p: &[f64], i: usize, q: &[f64], kk: usize, b: usize) -> f64 {
    let pi = &p[i * b..(i + 1) * b];
    let qk = &q[kk * b..(kk + 1) * b];
    let mut d = 0.0;
    for (&x, &y) in pi.iter().zip(qk) {
        if x > 0.0 {
            if y <= 0.0 {
                return f64::INFINITY;
            }
            d += x * (x / y).log2();
        }
    }
    d
}

fn weighted_pick(rng: &mut Pcg64, weights: &[f64], total: f64) -> usize {
    if total <= 0.0 {
        return 0;
    }
    let mut u = rng.gen_f64() * total;
    for (i, &w) in weights.iter().enumerate() {
        if u < w {
            return i;
        }
        u -= w;
    }
    weights.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three obvious groups of distributions.
    fn three_groups() -> (Vec<f64>, Vec<f64>, usize, usize) {
        let rows: Vec<[f64; 4]> = vec![
            [0.97, 0.01, 0.01, 0.01],
            [0.94, 0.02, 0.02, 0.02],
            [0.95, 0.03, 0.01, 0.01],
            [0.01, 0.97, 0.01, 0.01],
            [0.02, 0.94, 0.02, 0.02],
            [0.25, 0.25, 0.25, 0.25],
            [0.22, 0.28, 0.25, 0.25],
        ];
        let p: Vec<f64> = rows.iter().flatten().copied().collect();
        let w = vec![100.0, 90.0, 80.0, 100.0, 95.0, 50.0, 40.0];
        (p, w, rows.len(), 4)
    }

    #[test]
    fn recovers_three_groups() {
        let (p, w, m, b) = three_groups();
        let mut eng = NativeEngine;
        let c = cluster_k(&p, &w, m, b, 3, 7, &mut eng).unwrap();
        assert_eq!(c.assignments[0], c.assignments[1]);
        assert_eq!(c.assignments[1], c.assignments[2]);
        assert_eq!(c.assignments[3], c.assignments[4]);
        assert_eq!(c.assignments[5], c.assignments[6]);
        assert_ne!(c.assignments[0], c.assignments[3]);
        assert_ne!(c.assignments[0], c.assignments[5]);
        // clean separation ⇒ tiny data term
        assert!(c.data_bits < 10.0, "data_bits={}", c.data_bits);
    }

    #[test]
    fn k1_centroid_is_weighted_mean() {
        let (p, w, m, b) = three_groups();
        let mut eng = NativeEngine;
        let c = cluster_k(&p, &w, m, b, 1, 3, &mut eng).unwrap();
        let total_w: f64 = w.iter().sum();
        for bb in 0..b {
            let expect: f64 =
                (0..m).map(|i| w[i] * p[i * b + bb]).sum::<f64>() / total_w;
            assert!((c.centroids[bb] - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn objective_decreases_with_k() {
        let (p, w, m, b) = three_groups();
        let mut eng = NativeEngine;
        let mut prev = f64::INFINITY;
        for k in 1..=4 {
            let c = cluster_k(&p, &w, m, b, k, 5, &mut eng).unwrap();
            assert!(
                c.data_bits <= prev + 1e-9,
                "data term must be monotone in K: k={k} {} > {prev}",
                c.data_bits
            );
            prev = c.data_bits;
        }
    }

    #[test]
    fn k_clamped_to_m() {
        let p = vec![0.5, 0.5, 0.9, 0.1];
        let w = vec![1.0, 1.0];
        let mut eng = NativeEngine;
        let c = cluster_k(&p, &w, 2, 2, 10, 1, &mut eng).unwrap();
        assert_eq!(c.k, 2);
    }

    #[test]
    fn identical_inputs_one_effective_cluster() {
        let p = vec![0.3, 0.7].repeat(5);
        let w = vec![1.0; 5];
        let mut eng = NativeEngine;
        let c = cluster_k(&p, &w, 5, 2, 3, 2, &mut eng).unwrap();
        assert!(c.data_bits < 1e-9);
    }

    #[test]
    fn deterministic_in_seed() {
        let (p, w, m, b) = three_groups();
        let mut eng = NativeEngine;
        let a = cluster_k(&p, &w, m, b, 3, 11, &mut eng).unwrap();
        let c = cluster_k(&p, &w, m, b, 3, 11, &mut eng).unwrap();
        assert_eq!(a.assignments, c.assignments);
    }

    #[test]
    fn sparse_support_handled() {
        // members with disjoint support: smoothing must keep KL finite and
        // clustering must separate them
        let p = vec![
            1.0, 0.0, 0.0, 0.0, //
            0.0, 0.0, 0.0, 1.0,
        ];
        let w = vec![10.0, 10.0];
        let mut eng = NativeEngine;
        let c = cluster_k(&p, &w, 2, 4, 2, 3, &mut eng).unwrap();
        assert_ne!(c.assignments[0], c.assignments[1]);
        assert!(c.data_bits.is_finite());
    }

    #[test]
    fn native_step_objective_matches_manual_kl() {
        let p = vec![0.8, 0.2, 0.3, 0.7];
        let w = vec![5.0, 2.0];
        let q = smooth(&[0.5, 0.5], 1, 2);
        let mut eng = NativeEngine;
        let s = eng.step(&p, &w, &q, 2, 2, 1).unwrap();
        let manual = 5.0 * kl_rows(&p, 0, &q, 0, 2) + 2.0 * kl_rows(&p, 1, &q, 0, 2);
        assert!((s.objective - manual).abs() < 1e-9);
    }
}
