//! Shard-routing coordinator: one protocol front-end fanning out to N
//! backend model-store servers.
//!
//! `repro serve --route` starts a [`Router`] instead of a single-node
//! [`Server`](super::server::Server). The router speaks the same
//! line protocol downstream (clients cannot tell it from a backend) and
//! pipelined `PIPE` upstream, through per-backend connection pools:
//!
//! ```text
//!                         ┌── Router ───────────────────────────────┐
//! client ── PIPE/PREDICT ─►  rendezvous-hash(model) → candidate set │
//!                         │  try replicas in score order:           │
//!                         │    pool conn → PIPE <uid> PREDICT …     │
//!                         │    failure → health.note_failure,       │
//!                         │    jittered backoff, next replica       │──► backend 0
//!                         │  all replicas down →                    │──► backend 1
//!                         │    ERR unavailable model=<k>            │──► backend 2
//!                         │  probe loop: STATS every interval,      │
//!                         │  eject / re-admit per HealthPolicy      │
//!                         └─────────────────────────────────────────┘
//! ```
//!
//! **Placement.** Every model key rendezvous-hashes (highest-random-weight)
//! to a deterministic preference order over the backends. Cold keys route to
//! their primary only; the top-K **hot** keys (by router-observed request
//! count) use the top-R candidates as a replica set — reads fail over down
//! that list. Rendezvous hashing means adding or removing a backend only
//! remaps the keys that scored it highest; everything else stays put.
//!
//! **Robustness.** Each backend carries a
//! [`BackendHealth`](super::health::BackendHealth) machine (`Up → Degraded →
//! Ejected`) fed by connect failures, request timeouts, and a background
//! `STATS` probe loop; ejected backends leave rotation and are re-admitted
//! by a successful probe after the cooldown. Upstream exchanges are
//! duplicate-id-safe: every upstream attempt uses a fresh router-global uid
//! on an exclusively-checked-out pool connection, and a connection whose
//! exchange failed is destroyed, never returned to the pool — a late reply
//! can only die with its socket.
//!
//! Grammar, retry semantics, and the router's counter glossary are
//! specified in `rust/PROTOCOL.md` § Routing, enforced by the
//! `protocol_doc_covers_every_counter` drift guard.

use super::health::{BackendHealth, HealthPolicy, HealthState};
use super::server::{block_reply, parse_pipe_reply, wake_accept_loop, Client, PipeReply};
use crate::obs::{Obs, Phase, Span};
use crate::util::rng::Pcg64;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeSet, HashMap, HashSet};
use std::io::{BufRead, BufReader};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Router tuning knobs (replication, retry budget, timeouts, health).
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Replica-set size for hot keys (clamped to the backend count).
    pub replication: usize,
    /// How many of the most-requested keys count as hot.
    pub hot_k: usize,
    /// Recompute the hot set every this many routed requests.
    pub hot_refresh: u64,
    /// Upstream attempts per request across the whole replica set.
    pub max_tries: u32,
    /// Connect timeout for pool and probe connections.
    pub connect_timeout: Duration,
    /// Read/write deadline on upstream sockets — bounds one exchange.
    pub request_timeout: Duration,
    /// Base of the jittered exponential backoff between failed attempts.
    pub backoff_base: Duration,
    /// Per-connection pipelined in-flight cap (mirrors the backend cap).
    pub inflight_cap: usize,
    /// Pooled idle connections kept per backend.
    pub pool_cap: usize,
    /// Health thresholds, cooldown, and probe interval.
    pub health: HealthPolicy,
    /// Seed for the backoff jitter (deterministic fault tests).
    pub seed: u64,
    /// Routed requests at or above this wall time (µs) retain their trace
    /// in the router's `SLOW` ring (0 retains everything).
    pub slow_threshold_us: u64,
    /// Capacity of the router's slow-request ring.
    pub trace_ring: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            replication: 2,
            hot_k: 8,
            hot_refresh: 64,
            max_tries: 3,
            connect_timeout: Duration::from_millis(500),
            request_timeout: Duration::from_secs(5),
            backoff_base: Duration::from_millis(5),
            inflight_cap: 256,
            pool_cap: 8,
            health: HealthPolicy::default(),
            seed: 0x5EED_0007,
            slow_threshold_us: crate::obs::DEFAULT_SLOW_THRESHOLD_US,
            trace_ring: crate::obs::DEFAULT_TRACE_RING,
        }
    }
}

/// Snapshot of the router's serving counters (the `STATS` payload).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RouterStats {
    /// Requests answered via a backend (success or passed-through error).
    pub routed: u64,
    /// Upstream attempts beyond the first for a request.
    pub retries: u64,
    /// Requests ultimately answered by a non-primary replica.
    pub failovers: u64,
    /// Lifetime backend ejections (summed over backends).
    pub ejections: u64,
    /// Lifetime backend re-admissions (summed over backends).
    pub readmissions: u64,
    /// Requests answered `ERR unavailable` — every replica down.
    pub unavailable: u64,
    /// Gauge: backends currently routable (`Up` or `Degraded`).
    pub backends_up: u64,
}

/// The router's `STATS` counter list — every key named here must be
/// documented in `rust/PROTOCOL.md` (§ Routing); the
/// `protocol_doc_covers_every_counter` drift guard enforces it.
pub fn router_stats_payload(s: &RouterStats) -> String {
    format!(
        "routed={} retries={} failovers={} ejections={} readmissions={} \
         unavailable={} backends_up={}",
        s.routed, s.retries, s.failovers, s.ejections, s.readmissions, s.unavailable, s.backends_up
    )
}

/// Jittered exponential backoff: `base × 2^attempt`, scaled by a uniform
/// factor in `[0.5, 1.5)` drawn from `rng`. The exponent saturates at 10
/// (×1024) so a large retry budget cannot overflow into hour-long sleeps.
pub fn jittered_backoff(base: Duration, attempt: u32, rng: &mut Pcg64) -> Duration {
    let micros = (base.as_micros() as u64).saturating_mul(1u64 << attempt.min(10));
    let factor = 0.5 + rng.gen_f64();
    Duration::from_micros((micros as f64 * factor) as u64)
}

/// Rendezvous (highest-random-weight) score of `key` on `backend`:
/// FNV-1a over both strings, finished with a splitmix64 avalanche. Each
/// (key, backend) pair scores independently; a key routes to the backends
/// in descending score order.
pub fn rendezvous_score(key: &str, backend: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.bytes().chain([0u8]).chain(backend.bytes()) {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    // splitmix64 finisher: FNV alone mixes low bits poorly
    h = (h ^ (h >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h = (h ^ (h >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    h ^ (h >> 31)
}

/// One upstream backend: its address, idle-connection pool, and health.
struct Backend {
    addr: SocketAddr,
    addr_str: String,
    pool: Mutex<Vec<Client>>,
    health: Mutex<BackendHealth>,
}

/// Request-count bookkeeping behind hot-key replication.
struct HotTracker {
    counts: HashMap<String, u64>,
    hot: HashSet<String>,
    since_refresh: u64,
}

/// How one routed prediction resolved.
enum RouteOutcome {
    /// A backend answered `OK` — the prediction value.
    Value(String),
    /// A backend answered a non-retryable `ERR` — passed through.
    Upstream(String),
    /// Every replica was down or failed: `ERR unavailable model=<k>`.
    Unavailable,
}

struct RouterInner {
    cfg: RouterConfig,
    backends: Vec<Backend>,
    shutdown: AtomicBool,
    uid: AtomicU64,
    routed: AtomicU64,
    retries: AtomicU64,
    failovers: AtomicU64,
    unavailable: AtomicU64,
    rng: Mutex<Pcg64>,
    hot: Mutex<HotTracker>,
    /// Router-role observability: `route_latency_us` histogram, routing
    /// counters mirrored at `METRICS` time, and the slow-route ring.
    obs: Obs,
}

/// The running routing coordinator: accept loop + probe loop + a reader
/// thread (and per-request workers) per downstream connection.
pub struct Router {
    inner: Arc<RouterInner>,
    addr: SocketAddr,
}

impl Router {
    /// Bind `127.0.0.1:port` (0 = ephemeral) and start routing across
    /// `backends` with the given config.
    pub fn start(backends: &[SocketAddr], port: u16, cfg: RouterConfig) -> Result<Router> {
        if backends.is_empty() {
            bail!("router needs at least one backend");
        }
        let listener = TcpListener::bind(("127.0.0.1", port)).context("binding router socket")?;
        let addr = listener.local_addr()?;
        let inner = Arc::new(RouterInner {
            backends: backends
                .iter()
                .map(|&addr| Backend {
                    addr,
                    addr_str: addr.to_string(),
                    pool: Mutex::new(Vec::new()),
                    health: Mutex::new(BackendHealth::new(cfg.health.clone())),
                })
                .collect(),
            shutdown: AtomicBool::new(false),
            uid: AtomicU64::new(0),
            routed: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
            unavailable: AtomicU64::new(0),
            rng: Mutex::new(Pcg64::new(cfg.seed)),
            hot: Mutex::new(HotTracker {
                counts: HashMap::new(),
                hot: HashSet::new(),
                since_refresh: 0,
            }),
            obs: Obs::for_router(cfg.slow_threshold_us, cfg.trace_ring),
            cfg,
        });

        {
            // accept loop: blocking, woken by stop() exactly like Server's
            let inner = inner.clone();
            thread::spawn(move || loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if inner.shutdown.load(Ordering::Relaxed) {
                            break;
                        }
                        let inner = inner.clone();
                        thread::spawn(move || {
                            let _ = handle_router_conn(stream, &inner);
                        });
                    }
                    Err(_) => {
                        if inner.shutdown.load(Ordering::Relaxed) {
                            break;
                        }
                        thread::sleep(Duration::from_millis(10));
                    }
                }
            });
        }
        {
            // probe loop: STATS every probe_interval against each backend
            // that is routable or due a re-admission probe
            let inner = inner.clone();
            thread::spawn(move || {
                while !inner.shutdown.load(Ordering::Relaxed) {
                    thread::sleep(inner.cfg.health.probe_interval);
                    if inner.shutdown.load(Ordering::Relaxed) {
                        break;
                    }
                    for i in 0..inner.backends.len() {
                        let due = {
                            let h = inner.backends[i].health.lock().unwrap();
                            h.is_available() || h.probe_due_at(Instant::now())
                        };
                        if !due {
                            continue;
                        }
                        let ok = inner.probe(i);
                        let mut h = inner.backends[i].health.lock().unwrap();
                        if ok {
                            h.note_success_at(Instant::now());
                        } else {
                            h.note_failure_at(Instant::now());
                        }
                    }
                }
            });
        }
        Ok(Router { inner, addr })
    }

    /// The router's bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Snapshot the router's serving counters.
    pub fn stats(&self) -> RouterStats {
        self.inner.stats()
    }

    /// The router's observability hub (metrics registry, `route_latency_us`
    /// histogram, slow-route ring) — what `METRICS`/`SLOW` expose.
    pub fn obs(&self) -> &Obs {
        &self.inner.obs
    }

    /// Current health state per backend, in construction order (test hook).
    pub fn backend_states(&self) -> Vec<HealthState> {
        self.inner.backends.iter().map(|b| b.health.lock().unwrap().state()).collect()
    }

    /// Signal shutdown and wake the accept loop (bounded, like
    /// [`Server::stop`](super::server::Server::stop)).
    pub fn stop(&self) {
        if self.inner.shutdown.swap(true, Ordering::Relaxed) {
            return;
        }
        wake_accept_loop(self.addr);
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.stop();
    }
}

impl RouterInner {
    fn stats(&self) -> RouterStats {
        let (mut ejections, mut readmissions, mut up) = (0, 0, 0);
        for b in &self.backends {
            let h = b.health.lock().unwrap();
            ejections += h.ejections;
            readmissions += h.readmissions;
            if h.is_available() {
                up += 1;
            }
        }
        RouterStats {
            routed: self.routed.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            failovers: self.failovers.load(Ordering::Relaxed),
            ejections,
            readmissions,
            unavailable: self.unavailable.load(Ordering::Relaxed),
            backends_up: up,
        }
    }

    /// Record a request against `model` and refresh the hot set every
    /// `hot_refresh` requests: the top `hot_k` keys by lifetime count.
    fn note_request(&self, model: &str) {
        let mut hot = self.hot.lock().unwrap();
        *hot.counts.entry(model.to_string()).or_insert(0) += 1;
        hot.since_refresh += 1;
        if hot.since_refresh >= self.cfg.hot_refresh {
            hot.since_refresh = 0;
            let mut by_count: Vec<(&String, &u64)> = hot.counts.iter().collect();
            by_count.sort_by(|a, b| b.1.cmp(a.1).then_with(|| a.0.cmp(b.0)));
            let top: HashSet<String> =
                by_count.into_iter().take(self.cfg.hot_k).map(|(k, _)| k.clone()).collect();
            hot.hot = top;
        }
    }

    fn is_hot(&self, model: &str) -> bool {
        self.hot.lock().unwrap().hot.contains(model)
    }

    /// The backends that may serve `model`, best rendezvous score first:
    /// the top-R candidates for a hot key, the primary alone for a cold one.
    fn candidates_for(&self, model: &str) -> Vec<usize> {
        let mut scored: Vec<(u64, usize)> = self
            .backends
            .iter()
            .enumerate()
            .map(|(i, b)| (rendezvous_score(model, &b.addr_str), i))
            .collect();
        scored.sort_by(|a, b| b.0.cmp(&a.0).then_with(|| a.1.cmp(&b.1)));
        let want = if self.is_hot(model) { self.cfg.replication.max(1) } else { 1 };
        scored.into_iter().take(want.min(self.backends.len())).map(|(_, i)| i).collect()
    }

    /// Check a pooled connection out of `backend`'s pool, or dial a fresh
    /// one with the connect timeout and per-exchange deadlines set.
    fn checkout(&self, bi: usize) -> Result<Client> {
        if let Some(client) = self.backends[bi].pool.lock().unwrap().pop() {
            return Ok(client);
        }
        let client = Client::connect_timeout(self.backends[bi].addr, self.cfg.connect_timeout)?;
        client.set_deadlines(Some(self.cfg.request_timeout), Some(self.cfg.request_timeout))?;
        Ok(client)
    }

    /// Return a connection whose exchange fully completed. A connection is
    /// only ever checked in with **no outstanding replies**, which is what
    /// makes pool reuse duplicate-id-safe.
    fn checkin(&self, bi: usize, client: Client) {
        let mut pool = self.backends[bi].pool.lock().unwrap();
        if pool.len() < self.cfg.pool_cap {
            pool.push(client);
        }
    }

    /// One pipelined upstream exchange: send `line` (which carries `uid`),
    /// read until the reply for `uid` arrives. `Err` means a transport
    /// failure (connect/send/recv/EOF) — the connection is destroyed, the
    /// caller notes a health failure and may fail over.
    fn exchange_pipe(&self, bi: usize, uid: u64, line: &str) -> Result<PipeReply, String> {
        let mut client = self.checkout(bi).map_err(|e| format!("connect: {e}"))?;
        client.send(line).map_err(|e| format!("send: {e}"))?;
        // exclusive checkout means the next reply is ours; tolerate a few
        // stray lines defensively (they would indicate a protocol bug, not
        // a routine race — stale replies die with their socket)
        for _ in 0..4 {
            let reply = match client.recv() {
                Ok(r) if !r.is_empty() => r,
                Ok(_) => return Err("eof mid-exchange".to_string()),
                Err(e) => return Err(format!("recv: {e}")),
            };
            let parsed = parse_pipe_reply(&reply).map_err(|e| format!("bad reply: {e}"))?;
            if parsed.id() == Some(uid) {
                self.checkin(bi, client);
                return Ok(parsed);
            }
        }
        Err("no reply for this exchange's id".to_string())
    }

    /// Serial upstream exchange (`LIST`, probe `STATS`): one line out, one
    /// line back, on a pooled connection.
    fn exchange_serial(&self, bi: usize, line: &str) -> Result<String, String> {
        let mut client = self.checkout(bi).map_err(|e| format!("connect: {e}"))?;
        client.send(line).map_err(|e| format!("send: {e}"))?;
        match client.recv() {
            Ok(r) if !r.is_empty() => {
                self.checkin(bi, client);
                Ok(r)
            }
            Ok(_) => Err("eof mid-exchange".to_string()),
            Err(e) => Err(format!("recv: {e}")),
        }
    }

    /// Probe one backend: fresh dial (a pooled conn would hide a dead
    /// listener) + `STATS` round trip under the usual deadlines.
    fn probe(&self, bi: usize) -> bool {
        let Ok(client) = Client::connect_timeout(self.backends[bi].addr, self.cfg.connect_timeout)
        else {
            return false;
        };
        if client
            .set_deadlines(Some(self.cfg.request_timeout), Some(self.cfg.request_timeout))
            .is_err()
        {
            return false;
        }
        let mut client = client;
        client.request("STATS").map(|r| r.starts_with("OK ")).unwrap_or(false)
    }

    /// Stamp a routed request's span (attempt legs, answering backend),
    /// finish it, and feed the router's [`Obs`] hub — the
    /// `route_latency_us` histogram and, past the threshold, the `SLOW`
    /// ring.
    fn observe_route(&self, mut span: Span, attempts: u32, backend: Option<&str>) {
        span.attempts = attempts;
        span.backend = backend.map(str::to_string);
        span.finish();
        self.obs.record_latency(span.wall_us(), 1);
        self.obs.observe(&span);
    }

    /// Route one prediction: walk the replica set in rendezvous order, up
    /// to `max_tries` upstream attempts, jittered backoff after failures.
    /// Transport failures and upstream timeouts count against the
    /// backend's health and fail over; other upstream errors pass through.
    /// Every routed request leaves a trace span: upstream exchange time is
    /// charged to the execute phase (accumulating across failover legs),
    /// and the span records the attempt count and answering backend.
    fn route_predict(&self, model: &str, values: &str) -> RouteOutcome {
        self.note_request(model);
        let mut span = Span::begin(model);
        let candidates = self.candidates_for(model);
        let primary = candidates.first().copied();
        let mut attempts: u32 = 0;
        'rounds: for round in 0.. {
            let mut any_available = false;
            for &bi in &candidates {
                if !self.backends[bi].health.lock().unwrap().is_available() {
                    continue;
                }
                any_available = true;
                if attempts >= self.cfg.max_tries {
                    break 'rounds;
                }
                if attempts > 0 {
                    self.retries.fetch_add(1, Ordering::Relaxed);
                }
                attempts += 1;
                let uid = self.uid.fetch_add(1, Ordering::Relaxed);
                let line = format!("PIPE {uid} PREDICT {model} {values}");
                let t_x = Instant::now();
                let exchanged = self.exchange_pipe(bi, uid, &line);
                span.add(Phase::Execute, t_x.elapsed().as_micros() as u64);
                match exchanged {
                    Ok(PipeReply::Ok { value, .. }) => {
                        self.backends[bi].health.lock().unwrap().note_success_at(Instant::now());
                        self.routed.fetch_add(1, Ordering::Relaxed);
                        if primary != Some(bi) {
                            self.failovers.fetch_add(1, Ordering::Relaxed);
                        }
                        self.observe_route(span, attempts, Some(&self.backends[bi].addr_str));
                        return RouteOutcome::Value(value);
                    }
                    Ok(PipeReply::Err { message, .. }) => {
                        if message == "timeout" || message.starts_with("timeout ") {
                            // a request timeout counts against health and
                            // fails over like a transport failure
                            self.backends[bi]
                                .health
                                .lock()
                                .unwrap()
                                .note_failure_at(Instant::now());
                        } else {
                            // semantic error (schema, unknown model): the
                            // backend is alive and retrying is pointless
                            self.backends[bi]
                                .health
                                .lock()
                                .unwrap()
                                .note_success_at(Instant::now());
                            self.routed.fetch_add(1, Ordering::Relaxed);
                            if primary != Some(bi) {
                                self.failovers.fetch_add(1, Ordering::Relaxed);
                            }
                            self.observe_route(span, attempts, Some(&self.backends[bi].addr_str));
                            return RouteOutcome::Upstream(message);
                        }
                    }
                    Err(_transport) => {
                        self.backends[bi].health.lock().unwrap().note_failure_at(Instant::now());
                    }
                }
                if attempts < self.cfg.max_tries {
                    let delay = {
                        let mut rng = self.rng.lock().unwrap();
                        jittered_backoff(self.cfg.backoff_base, attempts - 1, &mut rng)
                    };
                    thread::sleep(delay);
                }
            }
            if !any_available || attempts >= self.cfg.max_tries || round >= self.cfg.max_tries {
                break;
            }
        }
        self.unavailable.fetch_add(1, Ordering::Relaxed);
        self.observe_route(span, attempts, None);
        RouteOutcome::Unavailable
    }

    /// The router's `LIST`: the sorted, deduplicated union of every
    /// routable backend's model list. `ERR unavailable` when none answer.
    fn list_reply(&self) -> String {
        let mut names = BTreeSet::new();
        let mut answered = false;
        for bi in 0..self.backends.len() {
            if !self.backends[bi].health.lock().unwrap().is_available() {
                continue;
            }
            if let Ok(reply) = self.exchange_serial(bi, "LIST") {
                if let Some(list) = reply.strip_prefix("OK") {
                    answered = true;
                    for name in list.split_whitespace() {
                        names.insert(name.to_string());
                    }
                }
            }
        }
        if !answered {
            return "ERR unavailable".to_string();
        }
        let joined = names.into_iter().collect::<Vec<_>>().join(" ");
        format!("OK {}", joined).trim_end().to_string()
    }
}

/// Render the router's `METRICS` exposition: mirror the point-in-time
/// [`RouterStats`] snapshot into the registry's named counters/gauges,
/// then expose everything (mirrors, the route phase totals, the
/// `route_latency_us` histogram) sorted by metric name.
fn router_metrics_lines(inner: &RouterInner) -> Vec<String> {
    let s = inner.stats();
    let reg = inner.obs.registry();
    reg.set("routed", s.routed);
    reg.set("retries", s.retries);
    reg.set("failovers", s.failovers);
    reg.set("ejections", s.ejections);
    reg.set("readmissions", s.readmissions);
    reg.set("unavailable", s.unavailable);
    reg.set("backends_up", s.backends_up);
    inner.obs.expose()
}

/// Write one reply line under the connection's socket-write mutex (shared
/// by the reader and every per-request worker).
fn write_router_line(stream: &Mutex<TcpStream>, line: &str) -> std::io::Result<()> {
    use std::io::Write;
    let mut s = stream.lock().unwrap();
    s.write_all(line.as_bytes())?;
    s.write_all(b"\n")
}

/// One downstream connection: a reader thread parsing lines; serial verbs
/// answer inline (blocking, in order), `PIPE <id> PREDICT` admits into the
/// connection's in-flight set and routes on a worker thread, answering out
/// of order. On `QUIT`/EOF the reader stops and in-flight workers drain
/// before the socket closes — every admitted id is answered exactly once.
fn handle_router_conn(stream: TcpStream, inner: &Arc<RouterInner>) -> Result<()> {
    stream.set_nodelay(true).ok();
    let wire = Arc::new(Mutex::new(stream.try_clone()?));
    let inflight: Arc<Mutex<HashSet<u64>>> = Arc::new(Mutex::new(HashSet::new()));
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if inner.shutdown.load(Ordering::Relaxed) {
            break;
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.splitn(3, ' ');
        let reply = match parts.next().unwrap_or("") {
            "PREDICT" => {
                let (Some(model), Some(values)) = (parts.next(), parts.next()) else {
                    let _ = write_router_line(&wire, "ERR PREDICT needs a model and values");
                    continue;
                };
                Some(match inner.route_predict(model, values) {
                    RouteOutcome::Value(v) => format!("OK {v}"),
                    RouteOutcome::Upstream(m) => format!("ERR upstream {m}"),
                    RouteOutcome::Unavailable => format!("ERR unavailable model={model}"),
                })
            }
            "PIPE" => {
                let id: Option<u64> = parts.next().and_then(|t| t.parse().ok());
                let Some(id) = id else {
                    let _ = write_router_line(&wire, "ERR PIPE id must be an unsigned integer");
                    continue;
                };
                let Some(body) = parts.next() else {
                    let _ =
                        write_router_line(&wire, &format!("ERR PIPE needs a request body id={id}"));
                    continue;
                };
                handle_router_pipe(id, body, inner, &wire, &inflight)
            }
            "LIST" => Some(inner.list_reply()),
            "STATS" => Some(format!("OK {}", router_stats_payload(&inner.stats()))),
            // METRICS/SLOW answer from the router's own hub — routing
            // latency and failover legs are exactly what a single backend
            // cannot see. Multi-line blocks write as one string under the
            // socket mutex, so concurrent pipelined replies cannot
            // interleave mid-block.
            "METRICS" => Some(block_reply(None, &router_metrics_lines(inner))),
            "SLOW" => match parts.next().map(|t| t.trim().parse::<usize>()) {
                None => Some(block_reply(None, &inner.obs.ring().dump(usize::MAX))),
                Some(Ok(n)) => Some(block_reply(None, &inner.obs.ring().dump(n))),
                Some(Err(_)) => Some("ERR SLOW count must be an unsigned integer".to_string()),
            },
            "BYTES" => Some("ERR BYTES is not routed (ask a backend directly)".to_string()),
            "QUIT" => break,
            other => Some(format!("ERR unknown verb {other:?}")),
        };
        if let Some(r) = reply {
            if write_router_line(&wire, &r).is_err() {
                break;
            }
        }
    }
    // drain-then-close: every admitted id answers (route_predict is bounded
    // by max_tries × request_timeout, so this always terminates)
    let deadline = Instant::now()
        + inner.cfg.request_timeout * (inner.cfg.max_tries + 1)
        + Duration::from_secs(1);
    while !inflight.lock().unwrap().is_empty() && Instant::now() < deadline {
        thread::sleep(Duration::from_millis(5));
    }
    Ok(())
}

/// Admit and dispatch one `PIPE` body. Returns an admission-error line to
/// write now, or `None` when the request was dispatched (or answered
/// inline, for `LIST`/`STATS`).
fn handle_router_pipe(
    id: u64,
    body: &str,
    inner: &Arc<RouterInner>,
    wire: &Arc<Mutex<TcpStream>>,
    inflight: &Arc<Mutex<HashSet<u64>>>,
) -> Option<String> {
    let mut parts = body.splitn(2, ' ');
    let verb = parts.next().unwrap_or("");
    let tail = parts.next().unwrap_or("");
    match verb {
        "PREDICT" => {
            let Some((model, values)) = tail.split_once(' ') else {
                return Some(format!("ERR PREDICT needs a model and values id={id}"));
            };
            {
                // admission order matches the backend protocol: duplicate
                // before cap, so a duplicate is never misreported as busy
                let mut inf = inflight.lock().unwrap();
                if inf.contains(&id) {
                    return Some(format!("ERR duplicate id id={id}"));
                }
                if inf.len() >= inner.cfg.inflight_cap {
                    return Some(format!("ERR busy id={id}"));
                }
                inf.insert(id);
            }
            let inner = inner.clone();
            let wire = wire.clone();
            let inflight = inflight.clone();
            let model = model.to_string();
            let values = values.to_string();
            thread::spawn(move || {
                let reply = match inner.route_predict(&model, &values) {
                    RouteOutcome::Value(v) => format!("OK {id} {v}"),
                    RouteOutcome::Upstream(m) => format!("ERR upstream {m} id={id}"),
                    RouteOutcome::Unavailable => {
                        format!("ERR unavailable model={model} id={id}")
                    }
                };
                let _ = write_router_line(&wire, &reply);
                inflight.lock().unwrap().remove(&id);
            });
            None
        }
        // LIST/STATS complete immediately: duplicate-checked, answered
        // inline under the write mutex, never counted in flight
        "LIST" => {
            if inflight.lock().unwrap().contains(&id) {
                return Some(format!("ERR duplicate id id={id}"));
            }
            let payload = inner.list_reply();
            Some(match payload.strip_prefix("OK") {
                Some(rest) => format!("OK {id}{rest}"),
                None => format!("ERR unavailable id={id}"),
            })
        }
        "STATS" => {
            if inflight.lock().unwrap().contains(&id) {
                return Some(format!("ERR duplicate id id={id}"));
            }
            Some(format!("OK {id} {}", router_stats_payload(&inner.stats())))
        }
        // METRICS/SLOW answer inline like LIST/STATS; the block travels as
        // one write so it stays contiguous among out-of-order replies
        "METRICS" => {
            if inflight.lock().unwrap().contains(&id) {
                return Some(format!("ERR duplicate id id={id}"));
            }
            Some(block_reply(Some(id), &router_metrics_lines(inner)))
        }
        "SLOW" => {
            let n = match tail.trim() {
                "" => usize::MAX,
                tok => match tok.parse::<usize>() {
                    Ok(n) => n,
                    Err(_) => {
                        return Some(format!("ERR SLOW count must be an unsigned integer id={id}"))
                    }
                },
            };
            if inflight.lock().unwrap().contains(&id) {
                return Some(format!("ERR duplicate id id={id}"));
            }
            Some(block_reply(Some(id), &inner.obs.ring().dump(n)))
        }
        other => Some(format!("ERR unknown pipe verb {other:?} id={id}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rendezvous_is_deterministic_and_spreads_keys() {
        let backends = ["127.0.0.1:9001", "127.0.0.1:9002", "127.0.0.1:9003"];
        // determinism: same inputs, same scores
        for b in &backends {
            assert_eq!(rendezvous_score("tenant-42", b), rendezvous_score("tenant-42", b));
        }
        // spread: over many keys every backend is primary for some key
        let mut primaries = [0usize; 3];
        for k in 0..200 {
            let key = format!("tenant-{k}");
            let best = (0..3).max_by_key(|&i| rendezvous_score(&key, backends[i])).unwrap();
            primaries[best] += 1;
        }
        for (i, &n) in primaries.iter().enumerate() {
            assert!(n > 20, "backend {i} is primary for only {n}/200 keys: {primaries:?}");
        }
    }

    #[test]
    fn removing_a_backend_only_remaps_its_own_keys() {
        // the rendezvous property: dropping backend 2 must not move any key
        // whose primary was backend 0 or 1
        let backends = ["127.0.0.1:9001", "127.0.0.1:9002", "127.0.0.1:9003"];
        for k in 0..100 {
            let key = format!("tenant-{k}");
            let best3 = (0..3).max_by_key(|&i| rendezvous_score(&key, backends[i])).unwrap();
            if best3 < 2 {
                let best2 = (0..2).max_by_key(|&i| rendezvous_score(&key, backends[i])).unwrap();
                assert_eq!(best3, best2, "key {key} moved although its primary survived");
            }
        }
    }

    #[test]
    fn jittered_backoff_is_bounded_and_seed_deterministic() {
        let base = Duration::from_millis(10);
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for attempt in 0..6 {
            let d1 = jittered_backoff(base, attempt, &mut a);
            let d2 = jittered_backoff(base, attempt, &mut b);
            assert_eq!(d1, d2, "same seed must give the same jitter");
            let nominal = base * 2u32.pow(attempt);
            assert!(d1 >= nominal / 2, "attempt {attempt}: {d1:?} < half of {nominal:?}");
            assert!(d1 < nominal * 3 / 2, "attempt {attempt}: {d1:?} ≥ 1.5 × {nominal:?}");
        }
        // the exponent saturates: attempt 40 must not overflow
        let big = jittered_backoff(base, 40, &mut a);
        assert!(big <= base * 1024 * 2, "saturated backoff escaped its cap: {big:?}");
    }

    #[test]
    fn stats_payload_names_every_counter() {
        let line = router_stats_payload(&RouterStats::default());
        for key in
            ["routed", "retries", "failovers", "ejections", "readmissions", "unavailable", "backends_up"]
        {
            assert!(line.contains(&format!("{key}=0")), "missing {key} in {line:?}");
        }
    }

    #[test]
    fn unavailable_without_any_backend_listening() {
        // one backend address that refuses connections: the router must
        // answer a typed unavailable error, not hang or die
        let dead = {
            let l = TcpListener::bind(("127.0.0.1", 0)).unwrap();
            l.local_addr().unwrap()
        };
        let cfg = RouterConfig {
            connect_timeout: Duration::from_millis(100),
            request_timeout: Duration::from_millis(200),
            backoff_base: Duration::from_millis(1),
            max_tries: 2,
            health: HealthPolicy {
                probe_interval: Duration::from_millis(50),
                ..HealthPolicy::default()
            },
            slow_threshold_us: 0, // retain every trace
            ..RouterConfig::default()
        };
        let router = Router::start(&[dead], 0, cfg).unwrap();
        let mut client = Client::connect(router.addr()).unwrap();
        client.set_deadlines(Some(Duration::from_secs(5)), Some(Duration::from_secs(5))).unwrap();
        let reply = client.request("PREDICT nobody 1.0").unwrap();
        assert_eq!(reply, "ERR unavailable model=nobody");
        let stats = router.stats();
        assert_eq!(stats.unavailable, 1);
        // the failed route still left a trace: no backend answered, so the
        // span records the legs attempted and no backend= annotation
        let traces = router.obs().ring().dump(10);
        assert_eq!(traces.len(), 1, "{traces:?}");
        assert!(traces[0].contains("model=nobody"), "{}", traces[0]);
        assert!(!traces[0].contains(" backend="), "{}", traces[0]);
        // SLOW over the wire frames the same ring as a block reply
        let block = client.request_block("SLOW 5").unwrap();
        assert_eq!(block.len(), 1, "{block:?}");
        assert!(block[0].contains("model=nobody"), "{}", block[0]);
        // METRICS names the routing counters and the latency histogram
        let metrics = client.request_block("METRICS").unwrap().join("\n");
        assert!(metrics.contains("unavailable 1"), "{metrics}");
        assert!(metrics.contains("route_latency_us_count 1"), "{metrics}");
        router.stop();
    }
}
