//! Frequency-weighted admission for the model store: a TinyLFU-style
//! sketch (4-bit count-min rows plus a doorkeeper bloom filter, with
//! periodic halving) and the [`AdmissionPolicy`] knob that selects between
//! plain LRU and sketch-gated admission.
//!
//! The problem this solves is the classic scan collapse: under pure LRU,
//! one pass over a million cold tenants evicts the entire hot working set,
//! because recency alone cannot tell "touched once, never again" from
//! "touched constantly". TinyLFU (Einziger, Friedman & Manes, 2017) fixes
//! this with an approximate frequency history: before a newly loaded model
//! may displace the LRU victim, their estimated frequencies are compared —
//! if the victim is hotter than the candidate, the *candidate* is demoted
//! instead and the working set survives the scan.
//!
//! The sketch is deliberately compact (a few tens of KiB for the default
//! width) and entirely in-tree: four rows of 4-bit saturating counters
//! packed sixteen to a `u64`, a doorkeeper bloom filter that absorbs
//! one-hit wonders before they touch the counters, and a sample-count
//! reset that halves every counter once enough touches accumulate — the
//! aging mechanism that keeps the history a sliding window rather than an
//! ever-growing total.

use std::fmt;

/// Which admission policy a [`crate::coordinator::ModelStore`] runs under
/// budget pressure (`repro serve --admission lru|tinylfu`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Pure recency: the least-recently-used resident model is always the
    /// demotion victim. Simple, scan-vulnerable.
    #[default]
    Lru,
    /// Frequency-weighted: a [`FrequencySketch`] estimates how often each
    /// model is requested; a get-path load whose frequency is below the
    /// LRU victim's is itself demoted instead of displacing the victim,
    /// and cold first-touch loads skip the shared plan cache.
    TinyLfu,
}

impl AdmissionPolicy {
    /// Parse the CLI spelling (`lru` / `tinylfu`). Returns `None` for
    /// anything else so the caller can print its own usage error.
    pub fn parse(s: &str) -> Option<AdmissionPolicy> {
        match s {
            "lru" => Some(AdmissionPolicy::Lru),
            "tinylfu" => Some(AdmissionPolicy::TinyLfu),
            _ => None,
        }
    }
}

impl fmt::Display for AdmissionPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdmissionPolicy::Lru => write!(f, "lru"),
            AdmissionPolicy::TinyLfu => write!(f, "tinylfu"),
        }
    }
}

/// Number of count-min rows (independent hash functions).
const ROWS: usize = 4;
/// 4-bit counters saturate here.
const COUNTER_MAX: u64 = 15;
/// Doorkeeper bits per counter (the bloom filter is this factor wider than
/// one counter row, keeping its false-positive rate low at sketch scale).
const DOORKEEPER_FACTOR: usize = 8;

/// Stable 64-bit hash of a model name for the sketch (FNV-1a folded through
/// a splitmix finalizer so the low bits are well mixed).
pub fn sketch_hash(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in name.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    mix(h)
}

/// splitmix64 finalizer — also used to derive per-row probe positions.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// TinyLFU frequency sketch: `ROWS` rows of 4-bit saturating counters (the
/// count-min part), a doorkeeper bloom filter in front of them, and
/// halving-based aging once `sample_cap` touches accumulate.
///
/// Estimates are **approximate and one-sided**: hash collisions can only
/// inflate a frequency, never lose one, which is the safe direction for an
/// admission gate (a falsely-hot victim keeps its seat; a falsely-hot
/// candidate gets admitted — either way nothing hot is dropped by mistake).
pub struct FrequencySketch {
    /// Counter words, `ROWS` rows of `width / 16` words each, flattened.
    words: Vec<u64>,
    /// Counters per row (power of two; the probe mask is `width - 1`).
    width: usize,
    /// Doorkeeper bloom bits, packed (bit count = `width × DOORKEEPER_FACTOR`).
    door: Vec<u64>,
    /// Touches since the last reset; at `sample_cap` every counter halves.
    samples: u64,
    /// Reset threshold (10× width, the standard TinyLFU sample size).
    sample_cap: u64,
}

impl FrequencySketch {
    /// Sketch with `counters` 4-bit counters per row (rounded up to a power
    /// of two, minimum 64). The default store sketch uses [`Self::default`].
    pub fn new(counters: usize) -> Self {
        let width = counters.max(64).next_power_of_two();
        FrequencySketch {
            words: vec![0; ROWS * width / 16],
            width,
            door: vec![0; width * DOORKEEPER_FACTOR / 64],
            samples: 0,
            sample_cap: 10 * width as u64,
        }
    }

    /// Record one touch of `h` (a [`sketch_hash`]). The first touch of a
    /// key only sets its doorkeeper bits; repeat touches increment the
    /// count-min rows — one-hit wonders never dirty the counters.
    pub fn touch(&mut self, h: u64) {
        if !self.door_check_and_set(h) {
            // first sighting: the doorkeeper absorbed it
        } else {
            for row in 0..ROWS {
                let idx = self.probe(h, row);
                let word = &mut self.words[idx / 16];
                let shift = (idx % 16) * 4;
                if (*word >> shift) & 0xf < COUNTER_MAX {
                    *word += 1 << shift;
                }
            }
        }
        self.samples += 1;
        if self.samples >= self.sample_cap {
            self.reset();
        }
    }

    /// Estimated touch count of `h`: the count-min minimum plus one if the
    /// doorkeeper has seen the key. Never under-counts a real touch within
    /// the current sample window.
    pub fn estimate(&self, h: u64) -> u32 {
        let mut min = u64::MAX;
        for row in 0..ROWS {
            let idx = self.probe(h, row);
            min = min.min((self.words[idx / 16] >> ((idx % 16) * 4)) & 0xf);
        }
        min as u32 + u32::from(self.door_check(h))
    }

    /// Flattened counter index of `h`'s probe in `row`.
    fn probe(&self, h: u64, row: usize) -> usize {
        let slot = mix(h ^ (row as u64).wrapping_mul(0xa076_1d64_78bd_642f)) as usize
            & (self.width - 1);
        row * self.width + slot
    }

    /// The two doorkeeper bit positions of `h`.
    fn door_bits(&self, h: u64) -> (usize, usize) {
        let bits = self.door.len() * 64;
        let a = mix(h ^ 0x8f14) as usize % bits;
        let b = mix(h ^ 0x51f2) as usize % bits;
        (a, b)
    }

    /// Whether both doorkeeper bits of `h` are already set.
    fn door_check(&self, h: u64) -> bool {
        let (a, b) = self.door_bits(h);
        self.door[a / 64] >> (a % 64) & 1 == 1 && self.door[b / 64] >> (b % 64) & 1 == 1
    }

    /// Doorkeeper membership test that also inserts: returns whether the
    /// key was present *before* this call.
    fn door_check_and_set(&mut self, h: u64) -> bool {
        let present = self.door_check(h);
        let (a, b) = self.door_bits(h);
        self.door[a / 64] |= 1 << (a % 64);
        self.door[b / 64] |= 1 << (b % 64);
        present
    }

    /// Aging: halve every counter (one shift-and-mask per word) and clear
    /// the doorkeeper, turning the history into a sliding window.
    fn reset(&mut self) {
        for w in &mut self.words {
            // shifting the whole word right by one then masking the high
            // bit of every nibble halves all sixteen counters at once
            *w = (*w >> 1) & 0x7777_7777_7777_7777;
        }
        self.door.iter_mut().for_each(|w| *w = 0);
        self.samples /= 2;
    }
}

impl Default for FrequencySketch {
    /// The store's default sketch: 16 Ki counters per row (~32 KiB of
    /// counters + ~16 KiB of doorkeeper) — room for far more tenants than
    /// fit any realistic resident budget.
    fn default() -> Self {
        FrequencySketch::new(16 * 1024)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parses_and_displays() {
        assert_eq!(AdmissionPolicy::parse("lru"), Some(AdmissionPolicy::Lru));
        assert_eq!(AdmissionPolicy::parse("tinylfu"), Some(AdmissionPolicy::TinyLfu));
        assert_eq!(AdmissionPolicy::parse("arc"), None);
        assert_eq!(AdmissionPolicy::TinyLfu.to_string(), "tinylfu");
        assert_eq!(AdmissionPolicy::default(), AdmissionPolicy::Lru);
    }

    #[test]
    fn first_touch_stops_at_the_doorkeeper() {
        let mut sk = FrequencySketch::new(256);
        let h = sketch_hash("tenant-7");
        assert_eq!(sk.estimate(h), 0);
        sk.touch(h);
        // one touch: doorkeeper only, counters untouched
        assert_eq!(sk.estimate(h), 1);
        sk.touch(h);
        assert_eq!(sk.estimate(h), 2);
    }

    #[test]
    fn hot_keys_estimate_above_cold_keys() {
        let mut sk = FrequencySketch::new(1024);
        let hot = sketch_hash("hot");
        for _ in 0..12 {
            sk.touch(hot);
        }
        for i in 0..200 {
            sk.touch(sketch_hash(&format!("cold-{i}")));
        }
        let hot_est = sk.estimate(hot);
        let cold_est = sk.estimate(sketch_hash("cold-42"));
        assert!(hot_est > cold_est, "hot {hot_est} !> cold {cold_est}");
        assert!(cold_est <= 2, "a one-touch key stays near the floor: {cold_est}");
    }

    #[test]
    fn counters_saturate_instead_of_wrapping() {
        let mut sk = FrequencySketch::new(64);
        let h = sketch_hash("pinned");
        for _ in 0..100 {
            sk.touch(h);
        }
        // 15 (counter cap) + 1 (doorkeeper); never wraps past the nibble
        assert_eq!(sk.estimate(h), COUNTER_MAX as u32 + 1);
    }

    #[test]
    fn reset_halves_counters_and_clears_the_doorkeeper() {
        let mut sk = FrequencySketch::new(64);
        let h = sketch_hash("aging");
        for _ in 0..9 {
            sk.touch(h);
        }
        let before = sk.estimate(h);
        sk.reset();
        let after = sk.estimate(h);
        // doorkeeper contribution (+1) is gone and the counters halved
        assert!(after <= before / 2 + 1, "reset must halve: {before} -> {after}");
        assert!(after >= 1, "history survives a reset, halved: {after}");
    }

    #[test]
    fn reset_fires_from_sample_cap() {
        let mut sk = FrequencySketch::new(64);
        let h = sketch_hash("windowed");
        for _ in 0..20 {
            sk.touch(h);
        }
        // 10×width = 640 touches trips at least one halving
        for i in 0..700 {
            sk.touch(sketch_hash(&format!("filler-{i}")));
        }
        assert!(
            sk.estimate(h) < COUNTER_MAX as u32 + 1,
            "an old hot key decays once the sample window rolls"
        );
    }
}
