//! Leader/worker compression orchestration and reporting.
//!
//! The coordinator owns: worker count, the clustering engine (XLA artifacts
//! when present, native otherwise), timing, and the comparison against the
//! paper's baseline compressors. One `Coordinator` can serve many jobs; the
//! engine (and its compiled PJRT executables) is reused across them.

use crate::baseline;
use crate::cluster::kmeans::LloydEngine;
use crate::compress::{CompressOptions, CompressedForest};
use crate::data::Dataset;
use crate::forest::{Forest, ForestParams};
use crate::runtime::HybridEngine;
use anyhow::Result;
use std::time::Instant;

/// Everything a compression job reports — the benches and the CLI print
/// straight from this.
#[derive(Debug, Clone)]
pub struct CompressionReport {
    /// Dataset name.
    pub dataset: String,
    /// Number of trees compressed.
    pub n_trees: usize,
    /// Total nodes across the forest.
    pub total_nodes: usize,
    /// Mean tree depth.
    pub mean_depth: f64,
    /// paper's comparators (bytes, after gzip)
    pub standard_bytes: u64,
    /// The "light" baseline's bytes.
    pub light_bytes: u64,
    /// Algorithm 1 (bytes) + per-section breakdown
    pub ours_bytes: u64,
    /// Per-section byte breakdown of the container.
    pub sections: crate::compress::SectionSizes,
    /// chosen cluster counts per model family
    pub cluster_ks: Vec<(String, usize)>,
    /// timings (seconds)
    pub train_s: f64,
    /// Compression wall time, seconds.
    pub compress_s: f64,
    /// Baseline (gzip comparators) wall time, seconds.
    pub baseline_s: f64,
    /// engine used and how many Lloyd steps ran where
    pub engine: &'static str,
    /// Lloyd steps answered by the XLA artifact.
    pub xla_steps: u64,
    /// Lloyd steps answered by the native fallback.
    pub native_steps: u64,
}

impl CompressionReport {
    /// Compression ratio vs the "standard" baseline.
    pub fn standard_ratio(&self) -> f64 {
        self.standard_bytes as f64 / self.ours_bytes.max(1) as f64
    }

    /// Compression ratio vs the "light" baseline.
    pub fn light_ratio(&self) -> f64 {
        self.light_bytes as f64 / self.ours_bytes.max(1) as f64
    }

    /// A Table-2-style row.
    pub fn table_row(&self) -> String {
        use crate::util::stats::human_bytes;
        format!(
            "{:<22} {:>12} {:>12} {:>12}  (1:{:.1} / 1:{:.1})",
            self.dataset,
            human_bytes(self.standard_bytes),
            human_bytes(self.light_bytes),
            human_bytes(self.ours_bytes),
            self.standard_ratio(),
            self.light_ratio(),
        )
    }
}

/// The coordinator: a reusable engine + worker configuration.
pub struct Coordinator {
    engine: HybridEngine,
    /// Worker threads for the extraction/encoding passes.
    pub workers: usize,
}

impl Coordinator {
    /// With XLA artifacts when available.
    pub fn new() -> Self {
        Coordinator { engine: HybridEngine::new(), workers: crate::util::threads::default_workers() }
    }

    /// Native-only (tests, ablations).
    pub fn native_only() -> Self {
        Coordinator { engine: HybridEngine::native_only(), workers: 1 }
    }

    /// Label of the clustering engine in use (logs/benches).
    pub fn engine_name(&self) -> &'static str {
        self.engine.name()
    }

    /// Train a forest on a dataset (bootstrap `treeBagger` defaults).
    pub fn train(&self, ds: &Dataset, n_trees: usize, seed: u64) -> Forest {
        let mut params = if ds.target.is_classification() {
            ForestParams::classification(n_trees)
        } else {
            ForestParams::regression(n_trees)
        };
        params.workers = self.workers;
        Forest::train(ds, &params, seed)
    }

    /// The full job: train (or take) a forest, compress it, run both
    /// baselines, assemble the report.
    pub fn run_job(
        &mut self,
        ds: &Dataset,
        forest: &Forest,
        opts: &CompressOptions,
        train_s: f64,
    ) -> Result<(CompressedForest, CompressionReport)> {
        let mut opts = opts.clone();
        opts.workers = self.workers;

        let t0 = Instant::now();
        let cf = CompressedForest::compress_with_engine(forest, ds, &opts, &mut self.engine)?;
        let compress_s = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let standard = baseline::gzip::gzip(&baseline::standard_representation(forest, ds));
        let (light_raw, _) = baseline::light_representation(forest);
        let light = baseline::gzip::gzip(&light_raw);
        let baseline_s = t0.elapsed().as_secs_f64();

        let report = CompressionReport {
            dataset: ds.name.clone(),
            n_trees: forest.num_trees(),
            total_nodes: forest.total_nodes(),
            mean_depth: forest.mean_depth(),
            standard_bytes: standard.len() as u64,
            light_bytes: light.len() as u64,
            ours_bytes: cf.total_bytes(),
            sections: cf.sizes,
            cluster_ks: cf.cluster_ks.clone(),
            train_s,
            compress_s,
            baseline_s,
            engine: self.engine.name(),
            xla_steps: self.engine.xla_steps,
            native_steps: self.engine.native_steps,
        };
        Ok((cf, report))
    }

    /// Convenience: train + compress + report in one call.
    pub fn train_and_compress(
        &mut self,
        ds: &Dataset,
        n_trees: usize,
        seed: u64,
        opts: &CompressOptions,
    ) -> Result<(Forest, CompressedForest, CompressionReport)> {
        let t0 = Instant::now();
        let forest = self.train(ds, n_trees, seed);
        let train_s = t0.elapsed().as_secs_f64();
        let (cf, report) = self.run_job(ds, &forest, opts, train_s)?;
        Ok((forest, cf, report))
    }
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn job_produces_consistent_report() {
        let ds = synthetic::iris(71);
        let mut c = Coordinator::native_only();
        let (forest, cf, report) =
            c.train_and_compress(&ds, 6, 3, &CompressOptions::default()).unwrap();
        assert_eq!(report.n_trees, 6);
        assert_eq!(report.ours_bytes, cf.total_bytes());
        assert!(report.standard_bytes > report.light_bytes);
        // on a 6-tree iris forest the fixed dictionary overhead is not yet
        // amortized, so only the standard baseline must be beaten here; the
        // light-baseline win at realistic tree counts is asserted by the
        // integration tests and the Table-2 bench
        assert!(report.ours_bytes < report.standard_bytes, "ours must beat standard");
        assert!(report.standard_ratio() > report.light_ratio());
        assert!(!report.cluster_ks.is_empty());
        // losslessness through the coordinator path too
        assert!(cf.decompress().unwrap().identical(&forest));
        // a printable row
        assert!(report.table_row().contains(&ds.name));
    }
}
