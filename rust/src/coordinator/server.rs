//! TCP front-end over the [`ModelStore`] with per-model micro-batching —
//! the "subscriber" serving loop of the end-to-end example.
//!
//! Line protocol (UTF-8, one request per line):
//!
//! ```text
//! PREDICT <model> <v1>,<v2>,...     → OK <class|value>       (numeric vi;
//!                                      categorical levels as c<idx>, e.g. c3)
//! LIST                              → OK <model> <model> ...
//! STATS                             → OK requests=.. batches=.. mean_us=.. max_us=..
//! BYTES                             → OK resident=<bytes>
//! QUIT                              → connection closes
//! ```
//!
//! Batching: every `PREDICT` goes into a per-model queue; a batcher thread
//! drains whatever accumulated within [`BATCH_WINDOW`] (up to
//! [`BATCH_MAX`]) and answers the whole batch against the store at once.
//! With one queued request the store takes the cheap prefix-decode path;
//! bigger flash crowds amortize a full per-tree decode across the batch.

use super::store::{ModelStore, ObsValue};
use crate::compress::predict::PredictOne;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Max requests answered in one batch.
pub const BATCH_MAX: usize = 64;
/// How long the batcher waits to accumulate a batch.
pub const BATCH_WINDOW: Duration = Duration::from_millis(2);

struct Job {
    values: Vec<ObsValue>,
    reply: Sender<Result<PredictOne, String>>,
}

/// The running server: listener thread + per-model batcher threads.
pub struct Server {
    store: Arc<ModelStore>,
    addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    queues: Arc<Mutex<HashMap<String, Sender<Job>>>>,
}

impl Server {
    /// Bind and start serving on `127.0.0.1:port` (0 = ephemeral).
    pub fn start(store: Arc<ModelStore>, port: u16) -> Result<Server> {
        let listener =
            TcpListener::bind(("127.0.0.1", port)).context("binding server socket")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let queues: Arc<Mutex<HashMap<String, Sender<Job>>>> =
            Arc::new(Mutex::new(HashMap::new()));

        {
            let store = store.clone();
            let shutdown = shutdown.clone();
            let queues = queues.clone();
            std::thread::spawn(move || {
                while !shutdown.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let store = store.clone();
                            let queues = queues.clone();
                            let shutdown = shutdown.clone();
                            std::thread::spawn(move || {
                                let _ = handle_conn(stream, &store, &queues, &shutdown);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            });
        }
        Ok(Server { store, addr, shutdown, queues })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn store(&self) -> &Arc<ModelStore> {
        &self.store
    }

    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
    }

    /// Number of per-model batcher threads spawned so far.
    pub fn active_batchers(&self) -> usize {
        self.queues.lock().unwrap().len()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Get (or start) the batcher queue for a model.
fn batcher_for(
    model: &str,
    store: &Arc<ModelStore>,
    queues: &Arc<Mutex<HashMap<String, Sender<Job>>>>,
    shutdown: &Arc<AtomicBool>,
) -> Sender<Job> {
    let mut map = queues.lock().unwrap();
    if let Some(tx) = map.get(model) {
        return tx.clone();
    }
    let (tx, rx): (Sender<Job>, Receiver<Job>) = channel();
    let store = store.clone();
    let shutdown = shutdown.clone();
    let name = model.to_string();
    std::thread::spawn(move || {
        while !shutdown.load(Ordering::Relaxed) {
            // block for the first job, then drain the window
            let first = match rx.recv_timeout(Duration::from_millis(100)) {
                Ok(j) => j,
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                Err(_) => break,
            };
            let mut jobs = vec![first];
            let deadline = std::time::Instant::now() + BATCH_WINDOW;
            while jobs.len() < BATCH_MAX {
                let now = std::time::Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(j) => jobs.push(j),
                    Err(_) => break,
                }
            }
            let rows: Vec<Vec<ObsValue>> = jobs.iter().map(|j| j.values.clone()).collect();
            match store.predict_batch(&name, &rows) {
                Ok(outs) => {
                    for (job, out) in jobs.into_iter().zip(outs) {
                        let _ = job.reply.send(Ok(out));
                    }
                }
                Err(e) => {
                    // batch-level failure (e.g. one bad row): answer each
                    // individually so good rows still succeed
                    for job in jobs {
                        let out = store
                            .predict(&name, &job.values)
                            .map_err(|e| e.to_string());
                        let _ = job.reply.send(out);
                    }
                    let _ = e; // recorded via per-row errors
                }
            }
        }
    });
    map.insert(model.to_string(), tx.clone());
    tx
}

fn handle_conn(
    stream: TcpStream,
    store: &Arc<ModelStore>,
    queues: &Arc<Mutex<HashMap<String, Sender<Job>>>>,
    shutdown: &Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        let reply = match handle_line(&line, store, queues, shutdown) {
            Ok(Some(s)) => s,
            Ok(None) => break, // QUIT
            Err(e) => format!("ERR {e}"),
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

fn handle_line(
    line: &str,
    store: &Arc<ModelStore>,
    queues: &Arc<Mutex<HashMap<String, Sender<Job>>>>,
    shutdown: &Arc<AtomicBool>,
) -> Result<Option<String>> {
    let mut parts = line.trim().splitn(3, ' ');
    match parts.next().unwrap_or("") {
        "PREDICT" => {
            let model = parts.next().context("PREDICT needs a model name")?;
            let values = parse_values(parts.next().context("PREDICT needs values")?)?;
            let (rtx, rrx) = channel();
            let q = batcher_for(model, store, queues, shutdown);
            q.send(Job { values, reply: rtx }).ok().context("batcher gone")?;
            let out = rrx
                .recv_timeout(Duration::from_secs(30))
                .context("prediction timed out")?;
            match out {
                Ok(PredictOne::Class(c)) => Ok(Some(format!("OK {c}"))),
                Ok(PredictOne::Value(v)) => Ok(Some(format!("OK {v}"))),
                Err(e) => Ok(Some(format!("ERR {e}"))),
            }
        }
        "LIST" => Ok(Some(format!("OK {}", store.names().join(" ")))),
        "STATS" => {
            let s = store.stats();
            let mean = if s.batches > 0 { s.total_latency_us / s.batches } else { 0 };
            Ok(Some(format!(
                "OK requests={} batches={} mean_us={} max_us={}",
                s.requests, s.batches, mean, s.max_latency_us
            )))
        }
        "BYTES" => Ok(Some(format!("OK resident={}", store.resident_bytes()))),
        "QUIT" => Ok(None),
        other => bail!("unknown verb {other:?}"),
    }
}

/// Parse `1.5,c3,0.25` → [Num(1.5), Cat(3), Num(0.25)].
pub fn parse_values(s: &str) -> Result<Vec<ObsValue>> {
    s.split(',')
        .map(|tok| {
            let tok = tok.trim();
            if let Some(cat) = tok.strip_prefix('c') {
                Ok(ObsValue::Cat(cat.parse().with_context(|| format!("bad level {tok:?}"))?))
            } else {
                Ok(ObsValue::Num(tok.parse().with_context(|| format!("bad number {tok:?}"))?))
            }
        })
        .collect()
}

/// Blocking client helper (used by tests/examples/benches).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting")?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn request(&mut self, line: &str) -> Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        Ok(reply.trim_end().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_values_mixed() {
        let v = parse_values("1.5,c3,0.25,c0").unwrap();
        assert_eq!(
            v,
            vec![ObsValue::Num(1.5), ObsValue::Cat(3), ObsValue::Num(0.25), ObsValue::Cat(0)]
        );
        assert!(parse_values("x").is_err());
        assert!(parse_values("cX").is_err());
    }

    // live server tests are in rust/tests/coordinator_e2e.rs
}
