//! TCP front-end over the [`ModelStore`] with per-model micro-batching and
//! per-connection request pipelining.
//!
//! **Wire protocol:** see [`PROTOCOL.md`](../../PROTOCOL.md) (in the
//! `rust/` crate root) for the complete specification — every verb
//! (`PREDICT`, `PIPE`, `LIST`, `STATS`, `BYTES`, `PREFETCH`, `METRICS`,
//! `SLOW`, `QUIT`), the reply and error-line grammar, ordering guarantees,
//! timeout/backpressure behavior, and the glossary of every
//! `STATS`/`BYTES`/`METRICS` counter. A unit test in this module
//! (`protocol_doc_covers_every_counter`) keeps that document, the `STATS`
//! renderer, and the metrics registry from drifting apart.
//!
//! Observability: every request carries a [`Span`] from parse to reply.
//! The batcher charges batch wait, the traced store call attributes
//! reload/pack-load/plan/execute time, and the finished span feeds the
//! store's [`crate::obs::Obs`] hub — phase counters, the
//! `request_latency_us` histogram behind `STATS`' `p50_us`/`p99_us`, and
//! the slow-request ring that `SLOW [n]` dumps. `METRICS` (serial or
//! `PIPE`d) renders the Prometheus-style exposition as a multi-line block
//! reply: a `OK lines=<n>` header followed by `n` payload lines, written
//! contiguously under the socket mutex so pipelined replies never
//! interleave mid-block.
//!
//! Connection anatomy (one TCP connection):
//!
//! ```text
//!            ┌─────────────── reader thread ────────────────┐ serial replies
//! client ──► │ parse line → verb                            │ written directly,
//!            │   PREDICT …      rendezvous with the batcher │ in order, blocking
//!            │   PIPE id …      admit (cap) + dispatch      │ ──► client
//!            │   LIST/STATS/…   answer inline (bare only —  │ (backpressure)
//!            │   `PIPE id LIST/STATS` goes via the outbox)  │
//!            └──────────────────────────┬───────────────────┘
//!                         tagged jobs   │
//!            ┌── per-model batchers ────▼──────────────────┐
//!            │ drain ≤ BATCH_WINDOW, answer the batch,     │
//!            │ enqueue `OK <id> …` into the conn outbox    │
//!            └──────────────────────────┬──────────────────┘
//!                     outbox (≤ in-flight cap entries)
//!            ┌─────── writer thread ────▼───────────────────┐
//! client ◄── │ drain the outbox, answer OUT OF ORDER as     │
//!            │ batches complete; expire overdue ids with    │
//!            │ `ERR timeout id=<n>`; drain-then-close on    │
//!            │ QUIT (socket shared via a write mutex)       │
//!            └──────────────────────────────────────────────┘
//! ```
//!
//! Pipelining (`PIPE <id> PREDICT …`) removes head-of-line blocking: one
//! connection can keep the batcher, spill, and pack tiers busy at once, and
//! a slow model (cold spill reload, first pack load) no longer stalls every
//! other request the client has in flight. Bare `PREDICT` keeps the
//! original in-order semantics — the reader waits for the reply before it
//! reads the next line. `PIPE <id> LIST` / `PIPE <id> STATS` ride the same
//! admission/outbox path: the reply (`OK <id> …`) is answered by the writer
//! thread like any other pipelined reply, counts against the in-flight cap,
//! and never jumps ahead of the socket's reply stream the way a
//! reader-inline answer would under writer backpressure. A bounded
//! in-flight cap per connection
//! ([`ServerConfig::inflight_cap`]) answers `ERR busy id=<n>` past the cap;
//! overdue requests answer `ERR timeout id=<n>` after
//! [`ServerConfig::request_timeout`] and the connection stays open.
//!
//! Batching: every prediction goes into a per-model queue; a batcher thread
//! drains whatever accumulated within [`BATCH_WINDOW`] (up to
//! [`BATCH_MAX`]) and answers the whole batch against the store at once.
//! Batcher threads retire themselves — deregistering their queue — when the
//! server shuts down, when their channel is dropped, or when their model
//! leaves the store, so dead per-model queues are reaped.
//!
//! Lifecycle: the accept loop **blocks** on the listener (no nonblocking
//! busy-spin); [`Server::stop`] wakes it with a loopback connection. On
//! `QUIT` (or peer EOF) the reader stops and the writer drains every reply
//! still in flight — or times it out — before the socket closes.

use super::store::{ModelStore, ObsValue, StoreStats};
use crate::compress::predict::PredictOne;
use crate::obs::{BatchTrace, Phase, Span};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Max requests answered in one batch.
pub const BATCH_MAX: usize = 64;
/// How long the batcher waits to accumulate a batch.
pub const BATCH_WINDOW: Duration = Duration::from_millis(2);
/// Idle tick on which a batcher re-checks shutdown and model residency.
const IDLE_TICK: Duration = Duration::from_millis(100);
/// Default per-connection cap on in-flight pipelined requests.
pub const DEFAULT_INFLIGHT_CAP: usize = 256;
/// Default request timeout (serial rendezvous and pipelined deadline alike).
pub const DEFAULT_REQUEST_TIMEOUT: Duration = Duration::from_secs(30);

/// Per-connection serving knobs ([`Server::start_with`]).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Max pipelined requests a single connection may have in flight;
    /// admission past it answers `ERR busy id=<n>` and bumps the store's
    /// `rejected_busy` counter.
    pub inflight_cap: usize,
    /// How long a request may remain unanswered. A serial `PREDICT` past it
    /// answers `ERR timeout`; a pipelined request answers
    /// `ERR timeout id=<n>`. The connection stays open either way.
    pub request_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            inflight_cap: DEFAULT_INFLIGHT_CAP,
            request_timeout: DEFAULT_REQUEST_TIMEOUT,
        }
    }
}

/// Where a finished prediction's answer goes.
enum JobReply {
    /// Bare `PREDICT`: rendezvous channel the reader thread blocks on
    /// (serial, in-order semantics).
    Sync(Sender<Result<PredictOne, String>>),
    /// `PIPE <id> PREDICT`: the formatted reply line goes straight into the
    /// connection's outbox; the writer thread answers out of order.
    Pipe(PipeTicket),
}

/// The answering handle of one admitted pipelined request. Dropping it
/// unanswered — e.g. the job died in a retiring batcher's queue — fails the
/// request immediately instead of leaving the client to wait out the full
/// request timeout (an already-answered admission makes the drop a no-op,
/// so a normal delivery never double-answers).
struct PipeTicket {
    id: u64,
    /// The admission's generation stamp: completion matches `(id,
    /// generation)`, so a stale ticket can never answer a reused id.
    generation: u64,
    outbox: Sender<String>,
    tracker: Arc<PipeTracker>,
}

impl Drop for PipeTicket {
    fn drop(&mut self) {
        let _ = self.tracker.finish_and_send(
            self.id,
            self.generation,
            &self.outbox,
            format!("ERR request dropped before prediction id={}", self.id),
        );
    }
}

struct Job {
    values: Vec<ObsValue>,
    reply: JobReply,
    /// The request's trace span: parse (and pipelined admission) already
    /// charged; the batcher charges batch wait, absorbs the store call's
    /// phase trace, and observes the span after delivery.
    span: Span,
}

/// Per-connection registry of in-flight pipelined requests: admission
/// (in-flight cap, duplicate ids), completion (late replies of timed-out
/// ids are dropped), and deadline expiry. Shared by the reader (admission),
/// the batchers (completion), and the writer (expiry).
struct PipeTracker {
    store: Arc<ModelStore>,
    cap: usize,
    timeout: Duration,
    /// Every admitted, not-yet-answered pipelined request, by client id.
    inflight: Mutex<Inflight>,
    /// Set when the reader stops (QUIT / EOF / shutdown): the writer may
    /// exit once the in-flight map drains.
    closing: AtomicBool,
}

/// The in-flight map plus the generation counter that disambiguates
/// **reused** ids: the protocol lets a client reuse an id once its reply
/// (or timeout) arrived, so a timed-out request's job may still be alive
/// in a batcher when the same id is admitted again. Completion matches on
/// `(id, generation)`, never the bare id — the stale job's late reply can
/// only miss, it can never be delivered as the new request's answer.
#[derive(Default)]
struct Inflight {
    map: HashMap<u64, InflightEntry>,
    next_generation: u64,
}

struct InflightEntry {
    generation: u64,
    deadline: Instant,
}

/// Admission verdict for a pipelined request.
enum Admit {
    /// Admitted; the generation stamp must accompany the reply.
    Ok(u64),
    /// The connection is at its in-flight cap.
    Busy,
    /// The id is already in flight on this connection.
    Duplicate,
}

impl PipeTracker {
    fn new(store: Arc<ModelStore>, cfg: &ServerConfig) -> Self {
        PipeTracker {
            store,
            cap: cfg.inflight_cap.max(1),
            // clamp to a year: `Instant + Duration` (admission deadlines,
            // `recv_timeout`) panics on overflow, so an absurd
            // --request-timeout-ms must not let a client kill the reader
            timeout: cfg.request_timeout.min(Duration::from_secs(365 * 24 * 3600)),
            inflight: Mutex::new(Inflight::default()),
            closing: AtomicBool::new(false),
        }
    }

    /// Try to register a pipelined request. On success the store's
    /// `inflight` gauge grows; `Busy` bumps `rejected_busy`.
    fn admit(&self, id: u64) -> Admit {
        let mut g = self.inflight.lock().unwrap();
        if g.map.contains_key(&id) {
            return Admit::Duplicate;
        }
        if g.map.len() >= self.cap {
            drop(g);
            self.store.note_rejected_busy();
            return Admit::Busy;
        }
        let generation = g.next_generation;
        g.next_generation += 1;
        g.map.insert(id, InflightEntry { generation, deadline: Instant::now() + self.timeout });
        self.store.note_pipe_dispatched();
        Admit::Ok(generation)
    }

    /// Mark a request answered and enqueue its reply line, atomically with
    /// respect to [`Self::drained`]: the outbox send happens under the
    /// in-flight lock, so a closing writer can never observe the map empty
    /// before this reply is in the channel (it would exit and drop a reply
    /// QUIT is documented to drain). `mpsc` sends never block, so holding
    /// the lock across the send is safe. Returns `false` when this exact
    /// admission already left the map — timed out, never admitted, or the
    /// id was reused by a newer request (generation mismatch) — and the
    /// reply is then dropped instead of answering an id twice or handing a
    /// stale payload to a reused id.
    fn finish_and_send(
        &self,
        id: u64,
        generation: u64,
        outbox: &Sender<String>,
        line: String,
    ) -> bool {
        let mut g = self.inflight.lock().unwrap();
        match g.map.get(&id) {
            Some(e) if e.generation == generation => {
                g.map.remove(&id);
            }
            _ => return false,
        }
        let _ = outbox.send(line);
        drop(g);
        self.store.note_pipe_retired();
        true
    }

    /// Remove and return every id whose deadline has passed (each counts a
    /// store `timeouts` and shrinks the `inflight` gauge).
    fn expire(&self) -> Vec<u64> {
        let now = Instant::now();
        let mut g = self.inflight.lock().unwrap();
        let expired: Vec<u64> = g
            .map
            .iter()
            .filter(|(_, e)| e.deadline <= now)
            .map(|(id, _)| *id)
            .collect();
        for id in &expired {
            g.map.remove(id);
        }
        drop(g);
        for _ in &expired {
            self.store.note_pipe_retired();
            self.store.note_request_timeout();
        }
        expired
    }

    /// Release pairs with the Acquire in [`Self::drained`]: everything the
    /// reader enqueued before closing (serial replies included) is visible
    /// to the writer's final drain sweep once it observes `closing`.
    fn close(&self) {
        self.closing.store(true, Ordering::Release);
    }

    fn drained(&self) -> bool {
        self.closing.load(Ordering::Acquire) && self.inflight.lock().unwrap().map.is_empty()
    }
}

/// Per-model batcher registry. Each entry carries a generation stamp so a
/// retiring batcher only deregisters *itself*, never a successor that took
/// the name over after a model was re-inserted.
struct Batchers {
    map: Mutex<HashMap<String, (u64, Sender<Job>)>>,
    next_gen: AtomicU64,
}

impl Batchers {
    fn new() -> Self {
        Batchers { map: Mutex::new(HashMap::new()), next_gen: AtomicU64::new(0) }
    }
}

/// The running server: blocking listener thread + per-model batcher threads
/// + a reader/writer thread pair per connection.
pub struct Server {
    store: Arc<ModelStore>,
    addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    batchers: Arc<Batchers>,
}

impl Server {
    /// Bind and start serving on `127.0.0.1:port` (0 = ephemeral) with the
    /// default [`ServerConfig`].
    pub fn start(store: Arc<ModelStore>, port: u16) -> Result<Server> {
        Self::start_with(store, port, ServerConfig::default())
    }

    /// Bind and start serving with explicit pipelining knobs.
    pub fn start_with(store: Arc<ModelStore>, port: u16, cfg: ServerConfig) -> Result<Server> {
        let listener =
            TcpListener::bind(("127.0.0.1", port)).context("binding server socket")?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let batchers = Arc::new(Batchers::new());

        {
            let store = store.clone();
            let shutdown = shutdown.clone();
            let batchers = batchers.clone();
            std::thread::spawn(move || {
                // blocking accept: zero CPU while idle; stop() wakes us with
                // a loopback connection
                loop {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if shutdown.load(Ordering::Relaxed) {
                                break;
                            }
                            let store = store.clone();
                            let batchers = batchers.clone();
                            let shutdown = shutdown.clone();
                            let cfg = cfg.clone();
                            std::thread::spawn(move || {
                                let _ =
                                    handle_conn(stream, &store, &batchers, &shutdown, &cfg);
                            });
                        }
                        Err(_) => {
                            if shutdown.load(Ordering::Relaxed) {
                                break;
                            }
                            // transient accept error (e.g. EMFILE): back off
                            // briefly instead of spinning on the error
                            std::thread::sleep(Duration::from_millis(10));
                        }
                    }
                }
            });
        }
        Ok(Server { store, addr, shutdown, batchers })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// The store this server answers from.
    pub fn store(&self) -> &Arc<ModelStore> {
        &self.store
    }

    /// Signal shutdown, wake the blocked accept loop, and drop every
    /// batcher queue (their threads drain and retire).
    pub fn stop(&self) {
        if self.shutdown.swap(true, Ordering::Relaxed) {
            return; // already stopped
        }
        // dropping the senders makes each batcher's recv disconnect promptly
        self.batchers.map.lock().unwrap().clear();
        wake_accept_loop(self.addr);
    }

    /// Number of live per-model batcher queues.
    pub fn active_batchers(&self) -> usize {
        self.batchers.map.lock().unwrap().len()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Nudge a blocking `accept()` loop awake with a loopback connection, with a
/// connect **timeout** and bounded retries — never an unbounded
/// `TcpStream::connect`. If the connect is refused the listener is already
/// gone (its accept loop has exited or is exiting), so failing after the
/// retries is fine; what matters is that `stop()` cannot hang on a stalled
/// loopback handshake.
pub(crate) fn wake_accept_loop(addr: std::net::SocketAddr) {
    for attempt in 0..3 {
        if attempt > 0 {
            std::thread::sleep(Duration::from_millis(20));
        }
        if TcpStream::connect_timeout(&addr, Duration::from_millis(200)).is_ok() {
            return;
        }
    }
}

/// Get (or start) the batcher queue for a model.
fn batcher_for(
    model: &str,
    store: &Arc<ModelStore>,
    batchers: &Arc<Batchers>,
    shutdown: &Arc<AtomicBool>,
) -> Sender<Job> {
    let mut map = batchers.map.lock().unwrap();
    if let Some((_, tx)) = map.get(model) {
        return tx.clone();
    }
    let generation = batchers.next_gen.fetch_add(1, Ordering::Relaxed);
    let (tx, rx): (Sender<Job>, Receiver<Job>) = channel();
    {
        let store = store.clone();
        let batchers = batchers.clone();
        let shutdown = shutdown.clone();
        let name = model.to_string();
        std::thread::spawn(move || {
            run_batcher(&name, generation, rx, &store, &batchers, &shutdown);
        });
    }
    map.insert(model.to_string(), (generation, tx.clone()));
    tx
}

/// Charge the interval from span start to batch drain — minus the
/// already-attributed parse/admit phases — as batch wait, so phases stay
/// non-overlapping.
fn charge_batch_wait(span: &mut Span, drained: Instant) {
    let waited = drained.duration_since(span.started()).as_micros() as u64;
    let pre = span.phase_us(Phase::Parse) + span.phase_us(Phase::Admit);
    span.add(Phase::BatchWait, waited.saturating_sub(pre));
}

/// Route a finished prediction to wherever its request came from: the
/// serial rendezvous channel, or (pipelined) the connection outbox — unless
/// the id already timed out, in which case the late reply is dropped so one
/// id is never answered twice.
fn deliver(reply: JobReply, out: Result<PredictOne, String>) {
    match reply {
        JobReply::Sync(tx) => {
            let _ = tx.send(out);
        }
        JobReply::Pipe(ticket) => {
            // answer through the tracker; the ticket's Drop then sees the
            // admission already retired and does nothing
            ticket.tracker.finish_and_send(
                ticket.id,
                ticket.generation,
                &ticket.outbox,
                render_pipe_reply(ticket.id, &out),
            );
        }
    }
}

/// Wire shape of a pipelined reply: `OK <id> <value>` on success,
/// `ERR <message> id=<id>` on failure (the id token is last so the message
/// may contain spaces).
fn render_pipe_reply(id: u64, out: &Result<PredictOne, String>) -> String {
    match out {
        Ok(PredictOne::Class(c)) => format!("OK {id} {c}"),
        Ok(PredictOne::Value(v)) => format!("OK {id} {v}"),
        Err(e) => format!("ERR {e} id={id}"),
    }
}

fn run_batcher(
    name: &str,
    generation: u64,
    rx: Receiver<Job>,
    store: &Arc<ModelStore>,
    batchers: &Arc<Batchers>,
    shutdown: &Arc<AtomicBool>,
) {
    while !shutdown.load(Ordering::Relaxed) {
        // block for the first job, then drain the window
        let first = match rx.recv_timeout(IDLE_TICK) {
            Ok(j) => j,
            Err(RecvTimeoutError::Timeout) => {
                if !store.contains(name) {
                    break; // model removed or evicted: retire this queue
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => break,
        };
        let mut jobs = vec![first];
        let deadline = std::time::Instant::now() + BATCH_WINDOW;
        while jobs.len() < BATCH_MAX {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(j) => jobs.push(j),
                Err(_) => break,
            }
        }
        let rows: Vec<Vec<ObsValue>> = jobs.iter().map(|j| j.values.clone()).collect();
        let drained = std::time::Instant::now();
        let obs = store.obs().clone();
        let mut trace = BatchTrace::default();
        let result = if obs.enabled() {
            store.predict_batch_traced(name, &rows, &mut trace)
        } else {
            store.predict_batch(name, &rows)
        };
        match result {
            Ok(outs) => {
                for (job, out) in jobs.into_iter().zip(outs) {
                    let Job { reply, mut span, .. } = job;
                    charge_batch_wait(&mut span, drained);
                    span.absorb(&trace);
                    let t_w = std::time::Instant::now();
                    deliver(reply, Ok(out));
                    span.add(Phase::Write, t_w.elapsed().as_micros() as u64);
                    span.finish();
                    obs.observe(&span);
                }
            }
            Err(e) => {
                // batch-level failure (e.g. one bad row): answer each
                // individually so good rows still succeed
                for job in jobs {
                    let Job { values, reply, mut span } = job;
                    charge_batch_wait(&mut span, drained);
                    let mut solo = BatchTrace::default();
                    let out = store
                        .predict_traced(name, &values, &mut solo)
                        .map_err(|e| e.to_string());
                    span.absorb(&solo);
                    let t_w = std::time::Instant::now();
                    deliver(reply, out);
                    span.add(Phase::Write, t_w.elapsed().as_micros() as u64);
                    span.finish();
                    obs.observe(&span);
                }
                let _ = e; // recorded via per-row errors
            }
        }
    }
    // retire: deregister our own generation (a re-inserted model may have
    // spawned a successor under the same name — leave that one alone)...
    {
        let mut map = batchers.map.lock().unwrap();
        if map.get(name).is_some_and(|(g, _)| *g == generation) {
            map.remove(name);
        }
    }
    // ...and fail any stragglers that raced into the queue while retiring,
    // instead of leaving them to time out against a dead queue
    while let Ok(job) = rx.try_recv() {
        deliver(job.reply, Err(format!("model {name:?} is no longer resident")));
    }
}

/// Write one protocol line under the connection's socket-write mutex (the
/// mutex keeps reader-written serial replies and writer-thread pipelined
/// replies from interleaving mid-line). Blocks when the peer stops
/// reading — that block **is** the backpressure: a reader stuck here stops
/// parsing further requests, exactly like the pre-pipelining server.
fn write_line(stream: &Mutex<TcpStream>, line: &str) -> std::io::Result<()> {
    let mut s = stream.lock().unwrap();
    s.write_all(line.as_bytes())?;
    s.write_all(b"\n")
}

/// The writer half of a connection: drains the outbox of **pipelined**
/// replies (enqueued by batchers as batches complete — out of order; the
/// in-flight cap bounds how many can ever be queued), expires overdue
/// pipelined ids with `ERR timeout id=<n>`, and exits when the channel
/// disconnects (reader gone and every in-flight job answered), when a
/// close was requested and the in-flight map has drained, or when the peer
/// stops accepting writes. Serial replies never pass through here — the
/// reader writes them directly.
fn writer_loop(stream: Arc<Mutex<TcpStream>>, rx: Receiver<String>, tracker: Arc<PipeTracker>) {
    // tick often enough to notice deadlines without spinning: the writer
    // wakes at most once per second on an idle connection (an expiry may
    // run up to one tick late — proportionate, since the tick never
    // exceeds the timeout itself), and the lower clamp keeps a zero/tiny
    // timeout (used by tests) from busy-looping
    let tick = tracker
        .timeout
        .min(Duration::from_secs(1))
        .max(Duration::from_millis(1));
    loop {
        let msg = rx.recv_timeout(tick);
        // overdue ids answer a typed error; the connection stays open
        for id in tracker.expire() {
            if write_line(&stream, &format!("ERR timeout id={id}")).is_err() {
                return; // peer dropped: late replies have nowhere to go
            }
        }
        match msg {
            Ok(line) => {
                if write_line(&stream, &line).is_err() {
                    return;
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                // close requested and nothing left in flight. drained()
                // can only turn true after every reply was enqueued
                // (finish_and_send sends under the in-flight lock), so a
                // final non-blocking sweep flushes any reply that raced
                // this tick into the channel
                if tracker.drained() {
                    while let Ok(line) = rx.try_recv() {
                        if write_line(&stream, &line).is_err() {
                            return;
                        }
                    }
                    return;
                }
            }
            Err(RecvTimeoutError::Disconnected) => return, // all senders gone
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    store: &Arc<ModelStore>,
    batchers: &Arc<Batchers>,
    shutdown: &Arc<AtomicBool>,
    cfg: &ServerConfig,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let wire = Arc::new(Mutex::new(stream.try_clone()?));
    let (out_tx, out_rx) = channel::<String>();
    let tracker = Arc::new(PipeTracker::new(store.clone(), cfg));
    let writer = {
        let tracker = tracker.clone();
        let wire = wire.clone();
        std::thread::spawn(move || writer_loop(wire, out_rx, tracker))
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if shutdown.load(Ordering::Relaxed) {
            break;
        }
        let reply = match handle_line(&line, store, batchers, shutdown, &tracker, &out_tx) {
            Ok(Handled::Reply(r)) => Some(r),
            Ok(Handled::Dispatched) => None,
            Ok(Handled::Quit) => break,
            Err(e) => Some(format!("ERR {e}")),
        };
        if let Some(r) = reply {
            // direct blocking write: serial replies (and admission errors)
            // never queue — a peer that stops reading stalls this reader,
            // and a write error tears the connection down
            if write_line(&wire, &r).is_err() {
                break;
            }
        }
    }
    // drain-then-close: the reader stops accepting input; dropping our
    // outbox sender lets the writer exit once every in-flight job (each
    // holds its own sender clone) has answered or timed out
    tracker.close();
    drop(out_tx);
    let _ = writer.join();
    Ok(())
}

/// What the reader does after parsing one request line.
enum Handled {
    /// Write this reply now, directly (serial verbs, admission errors).
    Reply(String),
    /// A pipelined job is in flight; its reply reaches the writer thread
    /// through the outbox when the batch completes.
    Dispatched,
    /// `QUIT`: stop reading and wind the connection down.
    Quit,
}

/// Parse and act on one request line. `Handled::Reply` lines are written
/// directly by the reader; `Err` is a protocol-level error the caller
/// answers with a bare `ERR <message>` line.
fn handle_line(
    line: &str,
    store: &Arc<ModelStore>,
    batchers: &Arc<Batchers>,
    shutdown: &Arc<AtomicBool>,
    tracker: &Arc<PipeTracker>,
    out_tx: &Sender<String>,
) -> Result<Handled> {
    let t0 = Instant::now();
    let mut parts = line.trim().splitn(3, ' ');
    match parts.next().unwrap_or("") {
        "PREDICT" => {
            let model = parts.next().context("PREDICT needs a model name")?;
            let values = parse_values(parts.next().context("PREDICT needs values")?)?;
            let mut span = Span::begin_at(t0, model);
            span.add(Phase::Parse, t0.elapsed().as_micros() as u64);
            let reply = serial_predict(model, values, span, store, batchers, shutdown, tracker);
            Ok(Handled::Reply(reply))
        }
        "PIPE" => {
            let id: u64 = parts
                .next()
                .context("PIPE needs a request id")?
                .parse()
                .ok()
                .context("PIPE id must be an unsigned integer")?;
            // once the id parsed, every error line must carry it (the
            // protocol's attribution contract) — including a missing body
            let Some(rest) = parts.next() else {
                return Ok(Handled::Reply(format!("ERR PIPE needs a request body id={id}")));
            };
            // an admission error answers now, directly; a dispatched job
            // answers later through the outbox
            match pipe_dispatch(id, rest, t0, store, batchers, shutdown, tracker, out_tx) {
                Some(err) => Ok(Handled::Reply(err)),
                None => Ok(Handled::Dispatched),
            }
        }
        "LIST" => Ok(Handled::Reply(format!("OK {}", store.names().join(" ")))),
        "STATS" => Ok(Handled::Reply(stats_line(&store.stats()))),
        "METRICS" => Ok(Handled::Reply(block_reply(None, &metrics_lines(store)))),
        "SLOW" => {
            let n = match parts.next() {
                None => usize::MAX,
                Some(tok) => tok
                    .trim()
                    .parse()
                    .ok()
                    .context("SLOW count must be an unsigned integer")?,
            };
            Ok(Handled::Reply(block_reply(None, &store.obs().ring().dump(n))))
        }
        "PREFETCH" => {
            let model = parts.next().context("PREFETCH needs a model name")?;
            Ok(Handled::Reply(match prefetch_line(model, store) {
                Ok(payload) => format!("OK {payload}"),
                Err(e) => format!("ERR {e}"),
            }))
        }
        "BYTES" => Ok(Handled::Reply(format!(
            "OK resident={} plans={} spilled={} packed={}",
            store.resident_bytes(),
            store.plan_bytes(),
            store.spilled_bytes(),
            store.packed_bytes()
        ))),
        "QUIT" => Ok(Handled::Quit),
        other => bail!("unknown verb {other:?}"),
    }
}

/// The in-order `PREDICT` path: dispatch to the batcher and block until the
/// reply arrives (or the request timeout passes — `ERR timeout`, the
/// connection stays open). Returns the formatted reply line.
fn serial_predict(
    model: &str,
    values: Vec<ObsValue>,
    span: Span,
    store: &Arc<ModelStore>,
    batchers: &Arc<Batchers>,
    shutdown: &Arc<AtomicBool>,
    tracker: &Arc<PipeTracker>,
) -> String {
    // answer unknown models inline: no batcher is spawned for a name that
    // is not resident (bad requests must not grow the queue registry)
    if !store.contains(model) {
        return format!("ERR unknown model {model:?}");
    }
    let (rtx, rrx) = channel();
    let q = batcher_for(model, store, batchers, shutdown);
    let out = match q.send(Job { values: values.clone(), reply: JobReply::Sync(rtx), span }) {
        // batcher already retired (model evicted or re-inserted in the
        // same instant): answer directly from the store — the failed send
        // hands the job (and its span) back for direct observation
        Err(std::sync::mpsc::SendError(job)) => {
            let mut span = job.span;
            let mut trace = BatchTrace::default();
            let out = store.predict_traced(model, &values, &mut trace).map_err(|e| e.to_string());
            span.absorb(&trace);
            span.finish();
            store.obs().observe(&span);
            out
        }
        Ok(()) => match rrx.recv_timeout(tracker.timeout) {
            Ok(out) => out,
            // the batcher retired with our job still queued; its queue (and
            // our reply sender) died with it — answer directly instead of
            // surfacing a channel error
            Err(RecvTimeoutError::Disconnected) => {
                store.predict(model, &values).map_err(|e| e.to_string())
            }
            Err(RecvTimeoutError::Timeout) => {
                store.note_request_timeout();
                return "ERR timeout".to_string();
            }
        },
    };
    match out {
        Ok(PredictOne::Class(c)) => format!("OK {c}"),
        Ok(PredictOne::Value(v)) => format!("OK {v}"),
        Err(e) => format!("ERR {e}"),
    }
}

/// Admit and dispatch one pipelined request (`rest` is everything after
/// `PIPE <id> `, i.e. `PREDICT <model> <vals>`). Returns `Some(reply)` for
/// admission errors the caller answers **now**; `None` means the job was
/// handed to a batcher (or answered inline on a retire race) and its reply
/// reaches the outbox when the batch completes.
fn pipe_dispatch(
    id: u64,
    rest: &str,
    t0: Instant,
    store: &Arc<ModelStore>,
    batchers: &Arc<Batchers>,
    shutdown: &Arc<AtomicBool>,
    tracker: &Arc<PipeTracker>,
    out_tx: &Sender<String>,
) -> Option<String> {
    let mut parts = rest.trim().splitn(3, ' ');
    let verb = parts.next().unwrap_or("");
    match verb {
        "PREDICT" => {}
        // LIST/STATS/METRICS/SLOW are store reads with no batcher leg:
        // admit them like any pipelined request (cap, duplicate ids, the
        // `inflight` gauge), answer immediately, and route the reply
        // through the outbox so it joins the writer thread's reply stream
        // instead of the reader jumping the queue with a direct socket
        // write. Multi-line replies (METRICS/SLOW) travel as one outbox
        // string, so the block stays contiguous in the stream.
        "LIST" | "STATS" | "METRICS" | "SLOW" => {
            // argument errors are checked before admission, like PREDICT's
            // unknown-model check
            let slow_n = match (verb, parts.next()) {
                ("SLOW", Some(tok)) => match tok.trim().parse::<usize>() {
                    Ok(n) => n,
                    Err(_) => {
                        return Some(format!("ERR SLOW count must be an unsigned integer id={id}"))
                    }
                },
                _ => usize::MAX,
            };
            let generation = match tracker.admit(id) {
                Admit::Busy => return Some(format!("ERR busy id={id}")),
                Admit::Duplicate => return Some(format!("ERR duplicate id id={id}")),
                Admit::Ok(generation) => generation,
            };
            let line = match verb {
                "LIST" => format!("OK {id} {}", store.names().join(" ")),
                "STATS" => format!("OK {id} {}", stats_payload(&store.stats())),
                "METRICS" => block_reply(Some(id), &metrics_lines(store)),
                _ => block_reply(Some(id), &store.obs().ring().dump(slow_n)),
            };
            tracker.finish_and_send(id, generation, out_tx, line);
            return None;
        }
        // PREFETCH is a fast acknowledgment (the warm-up itself runs on a
        // background thread), so like LIST/STATS it admits, answers, and
        // retires through the outbox in one step. Argument errors are
        // checked before admission, like PREDICT's unknown-model check.
        "PREFETCH" => {
            let Some(model) = parts.next() else {
                return Some(format!("ERR PREFETCH needs a model name id={id}"));
            };
            let generation = match tracker.admit(id) {
                Admit::Busy => return Some(format!("ERR busy id={id}")),
                Admit::Duplicate => return Some(format!("ERR duplicate id id={id}")),
                Admit::Ok(generation) => generation,
            };
            let line = match prefetch_line(model, store) {
                Ok(payload) => format!("OK {id} {payload}"),
                Err(e) => format!("ERR {e} id={id}"),
            };
            tracker.finish_and_send(id, generation, out_tx, line);
            return None;
        }
        other => {
            return Some(format!(
                "ERR PIPE supports only PREDICT, LIST, STATS, PREFETCH, METRICS, and SLOW, \
                 got {other:?} id={id}"
            ))
        }
    }
    let Some(model) = parts.next() else {
        return Some(format!("ERR PREDICT needs a model name id={id}"));
    };
    let values = match parts.next().map(parse_values) {
        Some(Ok(v)) => v,
        Some(Err(e)) => return Some(format!("ERR {e} id={id}")),
        None => return Some(format!("ERR PREDICT needs values id={id}")),
    };
    if !store.contains(model) {
        return Some(format!("ERR unknown model {model:?} id={id}"));
    }
    let mut span = Span::begin_at(t0, model);
    span.add(Phase::Parse, t0.elapsed().as_micros() as u64);
    let t_admit = Instant::now();
    let generation = match tracker.admit(id) {
        Admit::Busy => return Some(format!("ERR busy id={id}")),
        Admit::Duplicate => return Some(format!("ERR duplicate id id={id}")),
        Admit::Ok(generation) => generation,
    };
    span.add(Phase::Admit, t_admit.elapsed().as_micros() as u64);
    let reply = JobReply::Pipe(PipeTicket {
        id,
        generation,
        outbox: out_tx.clone(),
        tracker: tracker.clone(),
    });
    let q = batcher_for(model, store, batchers, shutdown);
    match q.send(Job { values, reply, span }) {
        Ok(()) => {}
        // batcher already retired (model evicted or re-inserted in the same
        // instant): answer directly from the store — the failed send hands
        // the job back, so no up-front clone is needed — through the
        // tracker so the in-flight accounting stays balanced
        Err(std::sync::mpsc::SendError(job)) => {
            let Job { values, reply, mut span } = job;
            let mut trace = BatchTrace::default();
            let out = store.predict_traced(model, &values, &mut trace).map_err(|e| e.to_string());
            span.absorb(&trace);
            let t_w = Instant::now();
            deliver(reply, out);
            span.add(Phase::Write, t_w.elapsed().as_micros() as u64);
            span.finish();
            store.obs().observe(&span);
        }
    }
    None
}

/// Act on one `PREFETCH <model>`: a Spilled/Packed target starts a
/// background warm-up ([`ModelStore::warm`] on a spawned thread — the reply
/// acknowledges *initiation*, not completion); an already-Resident target
/// is a cheap no-op that just stamps its LRU clock. Returns the reply
/// payload (without the `OK ` prefix) or the error message, shared by the
/// serial and pipelined arms.
fn prefetch_line(model: &str, store: &Arc<ModelStore>) -> Result<String, String> {
    match store.prefetch_needed(model) {
        Ok(true) => {
            let store = store.clone();
            let name = model.to_string();
            std::thread::spawn(move || {
                // best-effort: a failed warm-up (e.g. a corrupt spill file)
                // surfaces on the next PREDICT, which takes the same path
                let _ = store.warm(&name);
            });
            Ok(format!("warming {model}"))
        }
        Ok(false) => Ok(format!("resident {model}")),
        Err(e) => Err(e.to_string()),
    }
}

/// Render the serial `STATS` reply (`OK ` + [`stats_payload`]).
fn stats_line(s: &StoreStats) -> String {
    format!("OK {}", stats_payload(s))
}

/// Frame a multi-line reply (`METRICS`, `SLOW`) as one wire string:
/// `OK lines=<n>` (or `OK <id> lines=<n>` pipelined) followed by the
/// payload lines. Sending the whole block as a single write keeps it
/// contiguous in the reply stream — serial writes hold the socket mutex,
/// pipelined blocks travel as one outbox message.
pub(crate) fn block_reply(id: Option<u64>, lines: &[String]) -> String {
    let header = match id {
        Some(id) => format!("OK {id} lines={}", lines.len()),
        None => format!("OK lines={}", lines.len()),
    };
    if lines.is_empty() {
        header
    } else {
        format!("{header}\n{}", lines.join("\n"))
    }
}

/// Render the `METRICS` exposition: mirror the point-in-time
/// [`StoreStats`] snapshot into the registry's named counters/gauges, then
/// expose everything (mirrors, phase totals, latency histogram) sorted by
/// metric name.
fn metrics_lines(store: &Arc<ModelStore>) -> Vec<String> {
    let s = store.stats();
    let obs = store.obs();
    let reg = obs.registry();
    reg.set("requests", s.requests);
    reg.set("batches", s.batches);
    reg.set("evictions", s.evictions);
    reg.set("spills", s.spills);
    reg.set("reloads", s.reloads);
    reg.set("spill_bytes", s.spill_bytes);
    reg.set("plan_hits", s.plan_hits);
    reg.set("plan_misses", s.plan_misses);
    reg.set("pack_loads", s.pack_loads);
    reg.set("pack_releases", s.pack_releases);
    reg.set("inflight", s.inflight);
    reg.set("rejected_busy", s.rejected_busy);
    reg.set("timeouts", s.timeouts);
    reg.set("prefetches", s.prefetches);
    reg.set("admission_rejects", s.admission_rejects);
    reg.set("pack_generations", s.pack_generations);
    reg.set("compactions", s.compactions);
    reg.set("tombstones", s.tombstones);
    obs.expose()
}

/// The `STATS` counter list — shared by the serial reply (`OK <counters>`)
/// and the pipelined one (`OK <id> <counters>`).
/// `StoreStats::mean_latency_us` guards the empty window (zero recorded
/// requests reports `mean_us=0`, no division). Every counter named here
/// must be documented in `rust/PROTOCOL.md` — the
/// `protocol_doc_covers_every_counter` test enforces it.
fn stats_payload(s: &StoreStats) -> String {
    format!(
        "requests={} batches={} mean_us={} p50_us={} p99_us={} max_us={} evictions={} \
         spills={} reloads={} spill_bytes={} plan_hits={} plan_misses={} \
         pack_loads={} pack_releases={} inflight={} rejected_busy={} timeouts={} \
         prefetches={} admission_rejects={} pack_generations={} compactions={} \
         tombstones={}",
        s.requests,
        s.batches,
        s.mean_latency_us(),
        s.p50_latency_us,
        s.p99_latency_us,
        s.max_latency_us,
        s.evictions,
        s.spills,
        s.reloads,
        s.spill_bytes,
        s.plan_hits,
        s.plan_misses,
        s.pack_loads,
        s.pack_releases,
        s.inflight,
        s.rejected_busy,
        s.timeouts,
        s.prefetches,
        s.admission_rejects,
        s.pack_generations,
        s.compactions,
        s.tombstones
    )
}

/// Encode values for a `PREDICT` line — the inverse of [`parse_values`]:
/// numerics as decimal literals, categorical levels as `c<idx>`,
/// comma-separated. The single authority on the wire value encoding,
/// shared by the client helper, the integration suites, and the benches.
pub fn values_to_wire(values: &[ObsValue]) -> String {
    values
        .iter()
        .map(|v| match v {
            ObsValue::Num(x) => format!("{x}"),
            ObsValue::Cat(c) => format!("c{c}"),
        })
        .collect::<Vec<_>>()
        .join(",")
}

/// Parse `1.5,c3,0.25` → [Num(1.5), Cat(3), Num(0.25)].
pub fn parse_values(s: &str) -> Result<Vec<ObsValue>> {
    s.split(',')
        .map(|tok| {
            let tok = tok.trim();
            if let Some(cat) = tok.strip_prefix('c') {
                Ok(ObsValue::Cat(cat.parse().with_context(|| format!("bad level {tok:?}"))?))
            } else {
                Ok(ObsValue::Num(tok.parse().with_context(|| format!("bad number {tok:?}"))?))
            }
        })
        .collect()
}

/// One pipelined reply, decoded off the wire by [`Client::recv_pipelined`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PipeReply {
    /// `OK <id> <value>` — a successful prediction for request `id`.
    Ok {
        /// The client-supplied request id this reply answers.
        id: u64,
        /// The prediction, formatted as on the wire (class or value).
        value: String,
    },
    /// `ERR <message> id=<id>` (or a bare `ERR <message>` with no id).
    Err {
        /// The request id, when the error is attributable to one.
        id: Option<u64>,
        /// The error message, without the `ERR ` prefix or `id=` suffix.
        message: String,
    },
}

impl PipeReply {
    /// The request id this reply answers, if it carries one.
    pub fn id(&self) -> Option<u64> {
        match self {
            PipeReply::Ok { id, .. } => Some(*id),
            PipeReply::Err { id, .. } => *id,
        }
    }
}

/// Blocking client helper (used by tests/examples/benches): serial
/// [`Client::request`], or pipelined mode — issue N requests with
/// [`Client::pipe_predict`], then collect N replies by id with
/// [`Client::collect_pipelined`].
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Connect to a [`Server`]'s address.
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting")?;
        Self::from_stream(stream)
    }

    /// Connect with a bounded connect timeout instead of the OS default
    /// (which can be minutes against a blackholed peer).
    pub fn connect_timeout(addr: std::net::SocketAddr, timeout: Duration) -> Result<Client> {
        let stream = TcpStream::connect_timeout(&addr, timeout)
            .with_context(|| format!("connecting to {addr} (timeout {timeout:?})"))?;
        Self::from_stream(stream)
    }

    /// Bounded-retry connect: up to `tries` attempts, each with `timeout`,
    /// sleeping `backoff` between attempts. Returns the last error if every
    /// attempt fails — never blocks longer than
    /// `tries × timeout + (tries − 1) × backoff`.
    pub fn connect_with_retry(
        addr: std::net::SocketAddr,
        timeout: Duration,
        tries: u32,
        backoff: Duration,
    ) -> Result<Client> {
        let mut last = None;
        for attempt in 0..tries.max(1) {
            if attempt > 0 {
                std::thread::sleep(backoff);
            }
            match Self::connect_timeout(addr, timeout) {
                Ok(client) => return Ok(client),
                Err(e) => last = Some(e),
            }
        }
        Err(last.expect("at least one connect attempt ran"))
    }

    /// Set socket read/write deadlines (`None` = block forever, the
    /// pre-hardening behavior). With a read deadline, a hung peer turns
    /// into a `WouldBlock`/`TimedOut` error from [`Client::recv`] instead
    /// of a forever-blocked thread.
    pub fn set_deadlines(
        &self,
        read: Option<Duration>,
        write: Option<Duration>,
    ) -> Result<()> {
        // reader and writer are clones of one socket, so one call covers
        // both directions; set both fds anyway in case that ever changes
        self.writer.set_read_timeout(read).context("setting read deadline")?;
        self.writer.set_write_timeout(write).context("setting write deadline")?;
        self.reader.get_ref().set_read_timeout(read).context("setting read deadline")?;
        self.reader.get_ref().set_write_timeout(write).context("setting write deadline")?;
        Ok(())
    }

    fn from_stream(stream: TcpStream) -> Result<Client> {
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Serial round trip: send one request line, block for its reply.
    pub fn request(&mut self, line: &str) -> Result<String> {
        self.send(line)?;
        self.recv()
    }

    /// Send one request line without waiting for a reply (pipelined mode).
    pub fn send(&mut self, line: &str) -> Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        Ok(())
    }

    /// Read one reply line (empty string on EOF).
    pub fn recv(&mut self) -> Result<String> {
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        Ok(reply.trim_end().to_string())
    }

    /// Issue `PIPE <id> PREDICT <model> <values>` without waiting.
    pub fn pipe_predict(&mut self, id: u64, model: &str, wire_values: &str) -> Result<()> {
        self.send(&format!("PIPE {id} PREDICT {model} {wire_values}"))
    }

    /// Read one pipelined reply and decode its id.
    pub fn recv_pipelined(&mut self) -> Result<PipeReply> {
        let line = self.recv()?;
        parse_pipe_reply(&line)
    }

    /// Collect `n` pipelined replies in arrival order (which is **not**
    /// issue order — that is the point of pipelining).
    pub fn collect_pipelined(&mut self, n: usize) -> Result<Vec<PipeReply>> {
        (0..n).map(|_| self.recv_pipelined()).collect()
    }

    /// Round trip for a multi-line verb (`METRICS`, `SLOW [n]`): send the
    /// request and read the framed block.
    pub fn request_block(&mut self, line: &str) -> Result<Vec<String>> {
        self.send(line)?;
        self.recv_block()
    }

    /// Read one `OK [id] lines=<n>` header plus its `n` payload lines.
    pub fn recv_block(&mut self) -> Result<Vec<String>> {
        let header = self.recv()?;
        if !header.starts_with("OK ") {
            bail!("expected a block header, got {header:?}");
        }
        let n: usize = header
            .split_whitespace()
            .find_map(|t| t.strip_prefix("lines="))
            .with_context(|| format!("block header carries no lines= token: {header:?}"))?
            .parse()
            .ok()
            .with_context(|| format!("unparseable lines= count in {header:?}"))?;
        (0..n).map(|_| self.recv()).collect()
    }
}

/// Decode one pipelined reply line (see [`PipeReply`] for the grammar).
pub fn parse_pipe_reply(line: &str) -> Result<PipeReply> {
    if let Some(rest) = line.strip_prefix("OK ") {
        let mut parts = rest.splitn(2, ' ');
        let id: u64 = parts
            .next()
            .unwrap_or("")
            .parse()
            .ok()
            .with_context(|| format!("pipelined OK reply carries no id: {line:?}"))?;
        let value = parts.next().unwrap_or("").to_string();
        return Ok(PipeReply::Ok { id, value });
    }
    if let Some(rest) = line.strip_prefix("ERR ") {
        // the id token, when present, is last: `ERR <message> id=<id>`
        if let Some((message, id_tok)) = rest.rsplit_once(" id=") {
            if let Ok(id) = id_tok.parse::<u64>() {
                return Ok(PipeReply::Err { id: Some(id), message: message.to_string() });
            }
        }
        return Ok(PipeReply::Err { id: None, message: rest.to_string() });
    }
    bail!("unparseable reply line {line:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_line_empty_window_reports_zero_mean() {
        // no requests yet: the mean must be 0, not a division by zero
        let line = stats_line(&StoreStats::default());
        assert!(line.starts_with("OK requests=0"), "{line}");
        assert!(line.contains("mean_us=0"), "{line}");
        assert!(line.contains("p50_us=0") && line.contains("p99_us=0"), "{line}");
        assert!(line.contains("plan_hits=0") && line.contains("plan_misses=0"), "{line}");
        assert!(
            line.contains("spills=0") && line.contains("reloads=0")
                && line.contains("spill_bytes=0"),
            "{line}"
        );
        assert!(
            line.contains("pack_loads=0") && line.contains("pack_releases=0"),
            "{line}"
        );
        assert!(
            line.contains("inflight=0") && line.contains("rejected_busy=0")
                && line.contains("timeouts=0"),
            "{line}"
        );
        assert!(
            line.contains("prefetches=0") && line.contains("admission_rejects=0"),
            "{line}"
        );
        assert!(
            line.contains("pack_generations=0") && line.contains("compactions=0")
                && line.contains("tombstones=0"),
            "{line}"
        );
        // and a populated window reports the true per-request mean
        let s = StoreStats {
            requests: 4,
            total_latency_us: 10,
            ..Default::default()
        };
        assert!(stats_line(&s).contains("mean_us=2"), "{}", stats_line(&s));
    }

    #[test]
    fn parse_values_mixed() {
        let v = parse_values("1.5,c3,0.25,c0").unwrap();
        assert_eq!(
            v,
            vec![ObsValue::Num(1.5), ObsValue::Cat(3), ObsValue::Num(0.25), ObsValue::Cat(0)]
        );
        // the encoder is the parser's inverse
        assert_eq!(values_to_wire(&v), "1.5,c3,0.25,c0");
        assert_eq!(parse_values(&values_to_wire(&v)).unwrap(), v);
        assert!(parse_values("x").is_err());
        assert!(parse_values("cX").is_err());
    }

    #[test]
    fn pipe_reply_wire_shapes_round_trip() {
        let ok = render_pipe_reply(7, &Ok(PredictOne::Class(2)));
        assert_eq!(ok, "OK 7 2");
        assert_eq!(
            parse_pipe_reply(&ok).unwrap(),
            PipeReply::Ok { id: 7, value: "2".into() }
        );
        let okv = render_pipe_reply(8, &Ok(PredictOne::Value(1.5)));
        assert_eq!(okv, "OK 8 1.5");
        // error messages may contain spaces; the id token stays parseable
        let err = render_pipe_reply(9, &Err("unknown model \"x\"".into()));
        assert_eq!(err, "ERR unknown model \"x\" id=9");
        assert_eq!(
            parse_pipe_reply(&err).unwrap(),
            PipeReply::Err { id: Some(9), message: "unknown model \"x\"".into() }
        );
        // a bare serial error line still parses (no id)
        assert_eq!(
            parse_pipe_reply("ERR timeout").unwrap(),
            PipeReply::Err { id: None, message: "timeout".into() }
        );
        assert_eq!(parse_pipe_reply("ERR timeout id=3").unwrap().id(), Some(3));
        assert!(parse_pipe_reply("GARBAGE").is_err());
    }

    #[test]
    fn tracker_admission_cap_duplicates_and_expiry() {
        let store = Arc::new(ModelStore::new());
        let cfg = ServerConfig { inflight_cap: 2, request_timeout: Duration::ZERO };
        let tracker = PipeTracker::new(store.clone(), &cfg);
        let g1 = match tracker.admit(1) {
            Admit::Ok(g) => g,
            _ => panic!("admit 1"),
        };
        assert!(matches!(tracker.admit(1), Admit::Duplicate));
        let g2 = match tracker.admit(2) {
            Admit::Ok(g) => g,
            _ => panic!("admit 2"),
        };
        assert!(matches!(tracker.admit(3), Admit::Busy), "past the cap");
        let s = store.stats();
        assert_eq!(s.inflight, 2, "gauge tracks admitted requests");
        assert_eq!(s.rejected_busy, 1);
        // finishing an admission enqueues its reply and frees a slot
        // exactly once
        let (tx, rx) = channel::<String>();
        assert!(tracker.finish_and_send(1, g1, &tx, "OK 1 0".into()));
        assert_eq!(rx.try_recv().as_deref(), Ok("OK 1 0"));
        assert!(
            !tracker.finish_and_send(1, g1, &tx, "OK 1 0".into()),
            "an admission is answered at most once"
        );
        assert!(rx.try_recv().is_err(), "the duplicate reply was dropped");
        assert_eq!(store.stats().inflight, 1);
        // a zero timeout expires the remaining id immediately
        let expired = tracker.expire();
        assert_eq!(expired, vec![2]);
        assert!(
            !tracker.finish_and_send(2, g2, &tx, "OK 2 0".into()),
            "late replies of expired ids are dropped"
        );
        let s = store.stats();
        assert_eq!(s.inflight, 0);
        assert_eq!(s.timeouts, 1);
        // id reuse after the timeout: the stale admission's late reply must
        // never be delivered as the NEW request's answer (generation match)
        let g2b = match tracker.admit(2) {
            Admit::Ok(g) => g,
            _ => panic!("re-admit 2"),
        };
        assert_ne!(g2, g2b);
        assert!(
            !tracker.finish_and_send(2, g2, &tx, "OK 2 stale".into()),
            "a stale generation can never answer a reused id"
        );
        assert!(rx.try_recv().is_err(), "the stale payload was dropped");
        assert!(tracker.finish_and_send(2, g2b, &tx, "OK 2 fresh".into()));
        assert_eq!(rx.try_recv().as_deref(), Ok("OK 2 fresh"));
        // drained only once closing AND empty
        assert!(!tracker.drained());
        tracker.close();
        assert!(tracker.drained());
    }

    #[test]
    fn pipelined_list_and_stats_answer_through_the_outbox() {
        let store = Arc::new(ModelStore::new());
        let batchers = Arc::new(Batchers::new());
        let shutdown = Arc::new(AtomicBool::new(false));
        let tracker = Arc::new(PipeTracker::new(store.clone(), &ServerConfig::default()));
        let (tx, rx) = channel::<String>();
        // PIPE LIST: admitted (None = no direct reply), answered via outbox
        assert!(pipe_dispatch(4, "LIST", Instant::now(), &store, &batchers, &shutdown, &tracker, &tx).is_none());
        let line = rx.try_recv().expect("LIST reply reaches the outbox");
        assert!(line.starts_with("OK 4"), "{line}");
        assert_eq!(parse_pipe_reply(&line).unwrap().id(), Some(4));
        // PIPE STATS: the counters follow the id, same keys as serial STATS
        assert!(pipe_dispatch(5, "STATS", Instant::now(), &store, &batchers, &shutdown, &tracker, &tx).is_none());
        let line = rx.try_recv().expect("STATS reply reaches the outbox");
        assert!(line.starts_with("OK 5 requests="), "{line}");
        // both retired on the spot: the in-flight gauge is balanced and the
        // ids are immediately reusable
        assert_eq!(store.stats().inflight, 0);
        assert!(pipe_dispatch(4, "STATS", Instant::now(), &store, &batchers, &shutdown, &tracker, &tx).is_none());
        assert!(rx.try_recv().is_ok());
        // a duplicate in-flight id is still refused before dispatch
        let g = match tracker.admit(9) {
            Admit::Ok(g) => g,
            _ => panic!("admit 9"),
        };
        assert_eq!(
            pipe_dispatch(9, "LIST", Instant::now(), &store, &batchers, &shutdown, &tracker, &tx).as_deref(),
            Some("ERR duplicate id id=9")
        );
        assert!(tracker.finish_and_send(9, g, &tx, "OK 9 done".into()));
        let _ = rx.try_recv();
        // BYTES (and anything else) stays serial-only
        let err =
            pipe_dispatch(6, "BYTES resident", Instant::now(), &store, &batchers, &shutdown, &tracker, &tx)
                .expect("BYTES is not pipelinable");
        assert!(err.contains("id=6"), "{err}");
        assert!(err.contains("LIST"), "the error names the supported verbs: {err}");
    }

    #[test]
    fn pipelined_prefetch_answers_through_the_outbox() {
        let store = Arc::new(ModelStore::new());
        let batchers = Arc::new(Batchers::new());
        let shutdown = Arc::new(AtomicBool::new(false));
        let tracker = Arc::new(PipeTracker::new(store.clone(), &ServerConfig::default()));
        let (tx, rx) = channel::<String>();
        // unknown model: admitted, answered with a typed error, retired
        assert!(
            pipe_dispatch(3, "PREFETCH ghost", Instant::now(), &store, &batchers, &shutdown, &tracker, &tx)
                .is_none()
        );
        let line = rx.try_recv().expect("PREFETCH reply reaches the outbox");
        assert!(line.starts_with("ERR "), "{line}");
        assert_eq!(parse_pipe_reply(&line).unwrap().id(), Some(3));
        assert_eq!(store.stats().inflight, 0, "retired on the spot");
        // a missing argument is refused before admission, id attributed
        assert_eq!(
            pipe_dispatch(4, "PREFETCH", Instant::now(), &store, &batchers, &shutdown, &tracker, &tx).as_deref(),
            Some("ERR PREFETCH needs a model name id=4")
        );
        // the serial arm shares the same helper and error surface
        assert!(prefetch_line("ghost", &store).is_err());
    }

    #[test]
    fn protocol_doc_covers_every_counter() {
        // drift guard: every counter the wire emits must appear in the
        // PROTOCOL.md glossary (STATS keys and BYTES keys alike)
        let doc = include_str!("../../PROTOCOL.md");
        let line = stats_line(&StoreStats::default());
        for tok in line.split_whitespace().skip(1) {
            let key = tok.split('=').next().unwrap();
            assert!(
                doc.contains(&format!("`{key}`")),
                "STATS counter `{key}` is missing from rust/PROTOCOL.md"
            );
        }
        for key in ["resident", "plans", "spilled", "packed"] {
            assert!(
                doc.contains(&format!("`{key}`")),
                "BYTES counter `{key}` is missing from rust/PROTOCOL.md"
            );
        }
        // the router's STATS counters are part of the same wire surface:
        // every key its payload emits must be in the Routing glossary
        let router_line = super::super::router::router_stats_payload(
            &super::super::router::RouterStats::default(),
        );
        for tok in router_line.split_whitespace() {
            let key = tok.split('=').next().unwrap();
            assert!(
                doc.contains(&format!("`{key}`")),
                "router STATS counter `{key}` is missing from rust/PROTOCOL.md"
            );
        }
        // every metric the METRICS exposition can emit must be in the
        // glossary too — both roles' registries (store and router)
        for name in crate::obs::Obs::for_store(1, 1)
            .registry()
            .names()
            .into_iter()
            .chain(crate::obs::Obs::for_router(1, 1).registry().names())
        {
            assert!(
                doc.contains(&format!("`{name}`")),
                "METRICS metric `{name}` is missing from rust/PROTOCOL.md"
            );
        }
        // and every verb is specified
        for verb in
            ["PREDICT", "PIPE", "LIST", "STATS", "BYTES", "PREFETCH", "METRICS", "SLOW", "QUIT"]
        {
            assert!(
                doc.contains(&format!("`{verb}`")),
                "verb `{verb}` is missing from rust/PROTOCOL.md"
            );
        }
    }

    #[test]
    fn block_reply_frames_header_and_lines() {
        assert_eq!(block_reply(None, &[]), "OK lines=0");
        assert_eq!(block_reply(Some(7), &[]), "OK 7 lines=0");
        let lines = vec!["a 1".to_string(), "b 2".to_string()];
        assert_eq!(block_reply(None, &lines), "OK lines=2\na 1\nb 2");
        assert_eq!(block_reply(Some(3), &lines), "OK 3 lines=2\na 1\nb 2");
        // the pipelined shape still parses as a pipe reply (id first)
        assert_eq!(
            parse_pipe_reply(block_reply(Some(3), &[]).as_str()).unwrap().id(),
            Some(3)
        );
    }

    #[test]
    fn stop_wake_is_bounded_when_the_listener_is_gone() {
        // reserve a port, then free it: connects to it are now refused.
        // wake_accept_loop must return promptly (bounded retries with a
        // connect timeout), not hang the way a bare connect against a
        // blackholed address can.
        let addr = {
            let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
            listener.local_addr().unwrap()
        };
        let started = Instant::now();
        wake_accept_loop(addr);
        // worst case is 3 × 200ms connect timeouts + 2 × 20ms backoffs;
        // refused connects fail immediately, so this is generous
        assert!(
            started.elapsed() < Duration::from_secs(2),
            "wake_accept_loop took {:?} against a refused port",
            started.elapsed()
        );
    }

    // live server tests are in rust/tests/coordinator_e2e.rs and
    // rust/tests/pipeline_e2e.rs
}
