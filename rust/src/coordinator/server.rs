//! TCP front-end over the [`ModelStore`] with per-model micro-batching —
//! the "subscriber" serving loop of the end-to-end example.
//!
//! Line protocol (UTF-8, one request per line):
//!
//! ```text
//! PREDICT <model> <v1>,<v2>,...     → OK <class|value>       (numeric vi;
//!                                      categorical levels as c<idx>, e.g. c3)
//! LIST                              → OK <model> <model> ...
//! STATS                             → OK requests=.. batches=.. mean_us=..
//!                                         max_us=.. evictions=..
//!                                         spills=.. reloads=..
//!                                         spill_bytes=..
//!                                         plan_hits=.. plan_misses=..
//!                                         pack_loads=.. pack_releases=..
//! BYTES                             → OK resident=<bytes> plans=<bytes>
//!                                         spilled=<bytes> packed=<bytes>
//! QUIT                              → connection closes
//! ```
//!
//! Batching: every `PREDICT` goes into a per-model queue; a batcher thread
//! drains whatever accumulated within [`BATCH_WINDOW`] (up to
//! [`BATCH_MAX`]) and answers the whole batch against the store at once.
//! With one queued request the store takes the cheap prefix-decode path;
//! bigger flash crowds amortize a full per-tree decode across the batch.
//!
//! Lifecycle: the accept loop **blocks** on the listener (no nonblocking
//! busy-spin); [`Server::stop`] wakes it with a loopback connection.
//! Batcher threads retire themselves — deregistering their queue — when the
//! server shuts down, when their channel is dropped, or when their model
//! leaves the store (removal or LRU eviction), so dead per-model queues are
//! reaped instead of accumulating.

use super::store::{ModelStore, ObsValue, StoreStats};
use crate::compress::predict::PredictOne;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Max requests answered in one batch.
pub const BATCH_MAX: usize = 64;
/// How long the batcher waits to accumulate a batch.
pub const BATCH_WINDOW: Duration = Duration::from_millis(2);
/// Idle tick on which a batcher re-checks shutdown and model residency.
const IDLE_TICK: Duration = Duration::from_millis(100);

struct Job {
    values: Vec<ObsValue>,
    reply: Sender<Result<PredictOne, String>>,
}

/// Per-model batcher registry. Each entry carries a generation stamp so a
/// retiring batcher only deregisters *itself*, never a successor that took
/// the name over after a model was re-inserted.
struct Batchers {
    map: Mutex<HashMap<String, (u64, Sender<Job>)>>,
    next_gen: AtomicU64,
}

impl Batchers {
    fn new() -> Self {
        Batchers { map: Mutex::new(HashMap::new()), next_gen: AtomicU64::new(0) }
    }
}

/// The running server: blocking listener thread + per-model batcher threads.
pub struct Server {
    store: Arc<ModelStore>,
    addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    batchers: Arc<Batchers>,
}

impl Server {
    /// Bind and start serving on `127.0.0.1:port` (0 = ephemeral).
    pub fn start(store: Arc<ModelStore>, port: u16) -> Result<Server> {
        let listener =
            TcpListener::bind(("127.0.0.1", port)).context("binding server socket")?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let batchers = Arc::new(Batchers::new());

        {
            let store = store.clone();
            let shutdown = shutdown.clone();
            let batchers = batchers.clone();
            std::thread::spawn(move || {
                // blocking accept: zero CPU while idle; stop() wakes us with
                // a loopback connection
                loop {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if shutdown.load(Ordering::Relaxed) {
                                break;
                            }
                            let store = store.clone();
                            let batchers = batchers.clone();
                            let shutdown = shutdown.clone();
                            std::thread::spawn(move || {
                                let _ = handle_conn(stream, &store, &batchers, &shutdown);
                            });
                        }
                        Err(_) => {
                            if shutdown.load(Ordering::Relaxed) {
                                break;
                            }
                            // transient accept error (e.g. EMFILE): back off
                            // briefly instead of spinning on the error
                            std::thread::sleep(Duration::from_millis(10));
                        }
                    }
                }
            });
        }
        Ok(Server { store, addr, shutdown, batchers })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn store(&self) -> &Arc<ModelStore> {
        &self.store
    }

    /// Signal shutdown, wake the blocked accept loop, and drop every
    /// batcher queue (their threads drain and retire).
    pub fn stop(&self) {
        if self.shutdown.swap(true, Ordering::Relaxed) {
            return; // already stopped
        }
        // dropping the senders makes each batcher's recv disconnect promptly
        self.batchers.map.lock().unwrap().clear();
        // unblock accept()
        let _ = TcpStream::connect(self.addr);
    }

    /// Number of live per-model batcher queues.
    pub fn active_batchers(&self) -> usize {
        self.batchers.map.lock().unwrap().len()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Get (or start) the batcher queue for a model.
fn batcher_for(
    model: &str,
    store: &Arc<ModelStore>,
    batchers: &Arc<Batchers>,
    shutdown: &Arc<AtomicBool>,
) -> Sender<Job> {
    let mut map = batchers.map.lock().unwrap();
    if let Some((_, tx)) = map.get(model) {
        return tx.clone();
    }
    let generation = batchers.next_gen.fetch_add(1, Ordering::Relaxed);
    let (tx, rx): (Sender<Job>, Receiver<Job>) = channel();
    {
        let store = store.clone();
        let batchers = batchers.clone();
        let shutdown = shutdown.clone();
        let name = model.to_string();
        std::thread::spawn(move || {
            run_batcher(&name, generation, rx, &store, &batchers, &shutdown);
        });
    }
    map.insert(model.to_string(), (generation, tx.clone()));
    tx
}

fn run_batcher(
    name: &str,
    generation: u64,
    rx: Receiver<Job>,
    store: &Arc<ModelStore>,
    batchers: &Arc<Batchers>,
    shutdown: &Arc<AtomicBool>,
) {
    while !shutdown.load(Ordering::Relaxed) {
        // block for the first job, then drain the window
        let first = match rx.recv_timeout(IDLE_TICK) {
            Ok(j) => j,
            Err(RecvTimeoutError::Timeout) => {
                if !store.contains(name) {
                    break; // model removed or evicted: retire this queue
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => break,
        };
        let mut jobs = vec![first];
        let deadline = std::time::Instant::now() + BATCH_WINDOW;
        while jobs.len() < BATCH_MAX {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(j) => jobs.push(j),
                Err(_) => break,
            }
        }
        let rows: Vec<Vec<ObsValue>> = jobs.iter().map(|j| j.values.clone()).collect();
        match store.predict_batch(name, &rows) {
            Ok(outs) => {
                for (job, out) in jobs.into_iter().zip(outs) {
                    let _ = job.reply.send(Ok(out));
                }
            }
            Err(e) => {
                // batch-level failure (e.g. one bad row): answer each
                // individually so good rows still succeed
                for job in jobs {
                    let out = store
                        .predict(name, &job.values)
                        .map_err(|e| e.to_string());
                    let _ = job.reply.send(out);
                }
                let _ = e; // recorded via per-row errors
            }
        }
    }
    // retire: deregister our own generation (a re-inserted model may have
    // spawned a successor under the same name — leave that one alone)...
    {
        let mut map = batchers.map.lock().unwrap();
        if map.get(name).is_some_and(|(g, _)| *g == generation) {
            map.remove(name);
        }
    }
    // ...and fail any stragglers that raced into the queue while retiring,
    // instead of leaving them to time out against a dead queue
    while let Ok(job) = rx.try_recv() {
        let _ = job
            .reply
            .send(Err(format!("model {name:?} is no longer resident")));
    }
}

fn handle_conn(
    stream: TcpStream,
    store: &Arc<ModelStore>,
    batchers: &Arc<Batchers>,
    shutdown: &Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if shutdown.load(Ordering::Relaxed) {
            break;
        }
        let reply = match handle_line(&line, store, batchers, shutdown) {
            Ok(Some(s)) => s,
            Ok(None) => break, // QUIT
            Err(e) => format!("ERR {e}"),
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

fn handle_line(
    line: &str,
    store: &Arc<ModelStore>,
    batchers: &Arc<Batchers>,
    shutdown: &Arc<AtomicBool>,
) -> Result<Option<String>> {
    let mut parts = line.trim().splitn(3, ' ');
    match parts.next().unwrap_or("") {
        "PREDICT" => {
            let model = parts.next().context("PREDICT needs a model name")?;
            let values = parse_values(parts.next().context("PREDICT needs values")?)?;
            // answer unknown models inline: no batcher is spawned for a
            // name that is not resident (bad requests must not grow the
            // queue registry)
            if !store.contains(model) {
                bail!("unknown model {model:?}");
            }
            let (rtx, rrx) = channel();
            let q = batcher_for(model, store, batchers, shutdown);
            let out = match q.send(Job { values: values.clone(), reply: rtx }) {
                // batcher already retired (model evicted or re-inserted in
                // the same instant): answer directly from the store
                Err(_) => store.predict(model, &values).map_err(|e| e.to_string()),
                Ok(()) => match rrx.recv_timeout(Duration::from_secs(30)) {
                    Ok(out) => out,
                    // the batcher retired with our job still queued; its
                    // queue (and our reply sender) died with it — answer
                    // directly instead of surfacing a channel error
                    Err(RecvTimeoutError::Disconnected) => {
                        store.predict(model, &values).map_err(|e| e.to_string())
                    }
                    Err(RecvTimeoutError::Timeout) => bail!("prediction timed out"),
                },
            };
            match out {
                Ok(PredictOne::Class(c)) => Ok(Some(format!("OK {c}"))),
                Ok(PredictOne::Value(v)) => Ok(Some(format!("OK {v}"))),
                Err(e) => Ok(Some(format!("ERR {e}"))),
            }
        }
        "LIST" => Ok(Some(format!("OK {}", store.names().join(" ")))),
        "STATS" => Ok(Some(stats_line(&store.stats()))),
        "BYTES" => Ok(Some(format!(
            "OK resident={} plans={} spilled={} packed={}",
            store.resident_bytes(),
            store.plan_bytes(),
            store.spilled_bytes(),
            store.packed_bytes()
        ))),
        "QUIT" => Ok(None),
        other => bail!("unknown verb {other:?}"),
    }
}

/// Render the `STATS` reply. `StoreStats::mean_latency_us` guards the
/// empty window (zero recorded requests reports `mean_us=0`, no division).
fn stats_line(s: &StoreStats) -> String {
    format!(
        "OK requests={} batches={} mean_us={} max_us={} evictions={} \
         spills={} reloads={} spill_bytes={} plan_hits={} plan_misses={} \
         pack_loads={} pack_releases={}",
        s.requests,
        s.batches,
        s.mean_latency_us(),
        s.max_latency_us,
        s.evictions,
        s.spills,
        s.reloads,
        s.spill_bytes,
        s.plan_hits,
        s.plan_misses,
        s.pack_loads,
        s.pack_releases
    )
}

/// Parse `1.5,c3,0.25` → [Num(1.5), Cat(3), Num(0.25)].
pub fn parse_values(s: &str) -> Result<Vec<ObsValue>> {
    s.split(',')
        .map(|tok| {
            let tok = tok.trim();
            if let Some(cat) = tok.strip_prefix('c') {
                Ok(ObsValue::Cat(cat.parse().with_context(|| format!("bad level {tok:?}"))?))
            } else {
                Ok(ObsValue::Num(tok.parse().with_context(|| format!("bad number {tok:?}"))?))
            }
        })
        .collect()
}

/// Blocking client helper (used by tests/examples/benches).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting")?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn request(&mut self, line: &str) -> Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        Ok(reply.trim_end().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_line_empty_window_reports_zero_mean() {
        // no requests yet: the mean must be 0, not a division by zero
        let line = stats_line(&StoreStats::default());
        assert!(line.starts_with("OK requests=0"), "{line}");
        assert!(line.contains("mean_us=0"), "{line}");
        assert!(line.contains("plan_hits=0") && line.contains("plan_misses=0"), "{line}");
        assert!(
            line.contains("spills=0") && line.contains("reloads=0")
                && line.contains("spill_bytes=0"),
            "{line}"
        );
        assert!(
            line.contains("pack_loads=0") && line.contains("pack_releases=0"),
            "{line}"
        );
        // and a populated window reports the true per-request mean
        let s = StoreStats {
            requests: 4,
            total_latency_us: 10,
            ..Default::default()
        };
        assert!(stats_line(&s).contains("mean_us=2"), "{}", stats_line(&s));
    }

    #[test]
    fn parse_values_mixed() {
        let v = parse_values("1.5,c3,0.25,c0").unwrap();
        assert_eq!(
            v,
            vec![ObsValue::Num(1.5), ObsValue::Cat(3), ObsValue::Num(0.25), ObsValue::Cat(0)]
        );
        assert!(parse_values("x").is_err());
        assert!(parse_values("cX").is_err());
    }

    // live server tests are in rust/tests/coordinator_e2e.rs
}
