//! The L3 coordinator — the system layer around the codec.
//!
//! * [`pipeline`] — the leader/worker compression orchestration: train or
//!   ingest a forest, run the two extraction/encoding passes on a worker
//!   pool, drive the clustering through the XLA runtime, emit the container
//!   plus a [`pipeline::CompressionReport`] (sizes, ratios, cluster counts,
//!   timings) that the benches and CLI print
//! * [`store`]   — the model store: many compressed forests resident in
//!   memory, answering predictions straight from the compressed bytes (the
//!   paper's subscriber-device scenario)
//! * [`server`]  — a TCP front-end over the store with per-model
//!   micro-batching and per-connection pipelining: a line protocol
//!   (`PREDICT`, `PIPE`, `LIST`, `STATS`, `BYTES`, `METRICS`, `SLOW`,
//!   `QUIT`; specified in `rust/PROTOCOL.md`) suitable for the
//!   end-to-end example and the latency benches
//! * [`router`]  — the fleet layer: a shard-routing coordinator speaking
//!   the same protocol downstream and pipelined `PIPE` upstream, with
//!   rendezvous hashing, hot-key replication, per-backend connection
//!   pools, and retry/backoff onto replicas
//! * [`health`]  — the per-backend `Up → Degraded → Ejected` state machine
//!   the router's probe loop and request path drive
//! * [`admission`] — the TinyLFU frequency sketch and the
//!   `lru`/`tinylfu` admission-policy knob the store's budget enforcement
//!   consults (see `rust/OPERATIONS.md` for operator guidance)

pub mod admission;
pub mod health;
pub mod pipeline;
pub mod router;
pub mod server;
pub mod store;

pub use pipeline::{CompressionReport, Coordinator};
pub use router::Router;
pub use store::ModelStore;
