//! Per-backend health state machine for the shard router.
//!
//! Each backend a [`router::Router`](super::router::Router) fans out to
//! carries a [`BackendHealth`]: a three-state machine
//! (`Up → Degraded → Ejected`) driven by the outcomes the router observes —
//! connect failures, request timeouts, and the periodic `STATS` probe loop.
//! Consecutive failures degrade and then eject a backend; any success while
//! `Up`/`Degraded` resets the streak; an `Ejected` backend is only
//! re-admitted by a success observed **after** its cooldown elapsed, so a
//! stale in-flight reply that raced the ejection cannot flap it back in.
//!
//! Every transition method takes an explicit `now: Instant` instead of
//! reading the clock, so the unit tests drive the machine through
//! eject/cooldown/re-admit cycles deterministically, without sleeping.

use std::time::{Duration, Instant};

/// Where a backend sits in the `Up → Degraded → Ejected` lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    /// Serving normally; failure streak below the degrade threshold.
    Up,
    /// Still routable, but its failure streak crossed
    /// [`HealthPolicy::degrade_after`] — one eviction candidate away from
    /// ejection. The router prefers other replicas when it can.
    Degraded,
    /// Out of rotation: no requests are routed here. Re-admitted by a probe
    /// (or request) success observed after [`HealthPolicy::eject_cooldown`].
    Ejected,
}

/// Thresholds and timers governing the state machine.
#[derive(Debug, Clone)]
pub struct HealthPolicy {
    /// Consecutive failures before `Up` becomes `Degraded`.
    pub degrade_after: u32,
    /// Consecutive failures before the backend is `Ejected`.
    pub eject_after: u32,
    /// Minimum time a backend stays `Ejected` before a success may
    /// re-admit it.
    pub eject_cooldown: Duration,
    /// Period of the router's `STATS` probe loop (not used by the machine
    /// itself, but carried here so the router and its tests share one knob).
    pub probe_interval: Duration,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        HealthPolicy {
            degrade_after: 1,
            eject_after: 3,
            eject_cooldown: Duration::from_millis(500),
            probe_interval: Duration::from_millis(250),
        }
    }
}

/// One backend's health: current state, failure streak, and the lifetime
/// ejection/re-admission counters the router's `STATS` verb aggregates.
#[derive(Debug)]
pub struct BackendHealth {
    policy: HealthPolicy,
    state: HealthState,
    consecutive_failures: u32,
    ejected_at: Option<Instant>,
    /// Lifetime `* → Ejected` transitions.
    pub ejections: u64,
    /// Lifetime `Ejected → Up` re-admissions.
    pub readmissions: u64,
}

impl BackendHealth {
    /// A fresh backend starts `Up` with no failure history.
    pub fn new(policy: HealthPolicy) -> Self {
        BackendHealth {
            policy,
            state: HealthState::Up,
            consecutive_failures: 0,
            ejected_at: None,
            ejections: 0,
            readmissions: 0,
        }
    }

    /// Current state.
    pub fn state(&self) -> HealthState {
        self.state
    }

    /// Whether the router may send requests here (`Up` or `Degraded`).
    pub fn is_available(&self) -> bool {
        self.state != HealthState::Ejected
    }

    /// Whether an `Ejected` backend has served its cooldown and is due a
    /// re-admission probe. Always `false` while available.
    pub fn probe_due_at(&self, now: Instant) -> bool {
        match (self.state, self.ejected_at) {
            (HealthState::Ejected, Some(at)) => {
                now.saturating_duration_since(at) >= self.policy.eject_cooldown
            }
            _ => false,
        }
    }

    /// Record a successful exchange observed at `now`.
    ///
    /// While available this clears the failure streak (and any `Degraded`
    /// state). While `Ejected` it re-admits the backend **only** if the
    /// cooldown has elapsed — a success that raced the ejection (a late
    /// reply from before the partition) leaves it ejected.
    pub fn note_success_at(&mut self, now: Instant) {
        match self.state {
            HealthState::Ejected => {
                if self.probe_due_at(now) {
                    self.state = HealthState::Up;
                    self.consecutive_failures = 0;
                    self.ejected_at = None;
                    self.readmissions += 1;
                }
            }
            _ => {
                self.state = HealthState::Up;
                self.consecutive_failures = 0;
            }
        }
    }

    /// Record a failed exchange (connect failure, request timeout, probe
    /// failure) observed at `now`. Crossing `degrade_after` degrades;
    /// crossing `eject_after` ejects and starts the cooldown clock. A
    /// failure against an already-`Ejected` backend restarts its cooldown
    /// (the probe just confirmed it is still down).
    pub fn note_failure_at(&mut self, now: Instant) {
        if self.state == HealthState::Ejected {
            self.ejected_at = Some(now);
            return;
        }
        self.consecutive_failures = self.consecutive_failures.saturating_add(1);
        if self.consecutive_failures >= self.policy.eject_after {
            self.state = HealthState::Ejected;
            self.ejected_at = Some(now);
            self.ejections += 1;
        } else if self.consecutive_failures >= self.policy.degrade_after {
            self.state = HealthState::Degraded;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> HealthPolicy {
        HealthPolicy {
            degrade_after: 1,
            eject_after: 3,
            eject_cooldown: Duration::from_millis(500),
            probe_interval: Duration::from_millis(100),
        }
    }

    #[test]
    fn failures_degrade_then_eject() {
        let t0 = Instant::now();
        let mut h = BackendHealth::new(policy());
        assert_eq!(h.state(), HealthState::Up);

        h.note_failure_at(t0);
        assert_eq!(h.state(), HealthState::Degraded);
        assert!(h.is_available());

        h.note_failure_at(t0);
        assert_eq!(h.state(), HealthState::Degraded);

        h.note_failure_at(t0);
        assert_eq!(h.state(), HealthState::Ejected);
        assert!(!h.is_available());
        assert_eq!(h.ejections, 1);
    }

    #[test]
    fn success_resets_the_streak() {
        let t0 = Instant::now();
        let mut h = BackendHealth::new(policy());
        h.note_failure_at(t0);
        h.note_failure_at(t0);
        assert_eq!(h.state(), HealthState::Degraded);

        h.note_success_at(t0);
        assert_eq!(h.state(), HealthState::Up);

        // the streak restarted: two more failures only degrade again
        h.note_failure_at(t0);
        h.note_failure_at(t0);
        assert_eq!(h.state(), HealthState::Degraded);
    }

    #[test]
    fn readmission_waits_for_the_cooldown() {
        let t0 = Instant::now();
        let mut h = BackendHealth::new(policy());
        for _ in 0..3 {
            h.note_failure_at(t0);
        }
        assert_eq!(h.state(), HealthState::Ejected);

        // a success inside the cooldown window (a stale reply) is ignored
        let early = t0 + Duration::from_millis(100);
        assert!(!h.probe_due_at(early));
        h.note_success_at(early);
        assert_eq!(h.state(), HealthState::Ejected);

        // past the cooldown the probe is due and a success re-admits
        let late = t0 + Duration::from_millis(600);
        assert!(h.probe_due_at(late));
        h.note_success_at(late);
        assert_eq!(h.state(), HealthState::Up);
        assert_eq!(h.readmissions, 1);
        assert_eq!(h.ejections, 1);
    }

    #[test]
    fn probe_failure_restarts_the_cooldown() {
        let t0 = Instant::now();
        let mut h = BackendHealth::new(policy());
        for _ in 0..3 {
            h.note_failure_at(t0);
        }

        // still down at t0+600ms: the probe failure re-arms the clock, so
        // at t0+700ms (100ms after the failed probe) no probe is due yet
        let t1 = t0 + Duration::from_millis(600);
        h.note_failure_at(t1);
        assert_eq!(h.ejections, 1, "re-ejecting an ejected backend double-counts");
        assert!(!h.probe_due_at(t1 + Duration::from_millis(100)));
        assert!(h.probe_due_at(t1 + Duration::from_millis(500)));
    }
}
