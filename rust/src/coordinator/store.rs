//! The model store: many compressed forests resident in memory, answering
//! predictions **from the compressed bytes** — the paper's motivating
//! deployment ("a user-specific ensemble … stored on a personal device with
//! strict storage limitations", §1).

use crate::compress::predict::PredictOne;
use crate::compress::{CompressedForest, CompressedPredictor};
use crate::data::{Column, Dataset, Feature, Target};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::{Mutex, RwLock};

/// One observation value, matching the model's feature schema.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ObsValue {
    Num(f64),
    Cat(u32),
}

/// Store statistics (served by the `STATS` protocol verb).
#[derive(Debug, Clone, Copy, Default)]
pub struct StoreStats {
    pub requests: u64,
    pub batches: u64,
    pub total_latency_us: u64,
    pub max_latency_us: u64,
}

struct StoredModel {
    predictor: CompressedPredictor,
    compressed_bytes: u64,
}

/// A thread-safe registry of compressed models.
pub struct ModelStore {
    models: RwLock<BTreeMap<String, StoredModel>>,
    stats: Mutex<StoreStats>,
}

impl ModelStore {
    pub fn new() -> Self {
        ModelStore { models: RwLock::new(BTreeMap::new()), stats: Mutex::new(StoreStats::default()) }
    }

    /// Register a compressed forest under a name.
    pub fn insert(&self, name: &str, cf: &CompressedForest) -> Result<()> {
        let pc = cf.parse()?;
        let predictor = CompressedPredictor::new(pc)?;
        self.models.write().unwrap().insert(
            name.to_string(),
            StoredModel { predictor, compressed_bytes: cf.total_bytes() },
        );
        Ok(())
    }

    /// Load a container file from disk.
    pub fn insert_from_file(&self, name: &str, path: &std::path::Path) -> Result<()> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        let cf = CompressedForest::from_bytes(bytes)?;
        self.insert(name, &cf)
    }

    pub fn remove(&self, name: &str) -> bool {
        self.models.write().unwrap().remove(name).is_some()
    }

    pub fn names(&self) -> Vec<String> {
        self.models.read().unwrap().keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.models.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total compressed bytes resident (the "storage budget" figure).
    pub fn resident_bytes(&self) -> u64 {
        self.models.read().unwrap().values().map(|m| m.compressed_bytes).sum()
    }

    pub fn stats(&self) -> StoreStats {
        *self.stats.lock().unwrap()
    }

    /// Predict a single observation against a named model.
    pub fn predict(&self, model: &str, values: &[ObsValue]) -> Result<PredictOne> {
        let start = std::time::Instant::now();
        let models = self.models.read().unwrap();
        let stored = models.get(model).with_context(|| format!("unknown model {model:?}"))?;
        let ds = row_dataset(&stored.predictor, values, 1)?;
        let out = stored.predictor.predict_row(&ds, 0)?;
        drop(models);
        self.record(start.elapsed().as_micros() as u64, 1, 1);
        Ok(out)
    }

    /// Predict a batch of observations (the micro-batcher's path: one
    /// schema check + shared decode state amortized over the batch).
    pub fn predict_batch(&self, model: &str, rows: &[Vec<ObsValue>]) -> Result<Vec<PredictOne>> {
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        let start = std::time::Instant::now();
        let models = self.models.read().unwrap();
        let stored = models.get(model).with_context(|| format!("unknown model {model:?}"))?;
        let flat: Vec<ObsValue> = rows.iter().flatten().copied().collect();
        let ds = row_dataset(&stored.predictor, &flat, rows.len())?;
        // batched path decodes each tree once when the batch is large enough
        // to amortize it; small batches use the per-row prefix decode
        let out = if rows.len() >= 8 {
            match stored.predictor.predict_all(&ds)? {
                crate::forest::forest::Predictions::Classes(cs) => {
                    cs.into_iter().map(PredictOne::Class).collect()
                }
                crate::forest::forest::Predictions::Values(vs) => {
                    vs.into_iter().map(PredictOne::Value).collect()
                }
            }
        } else {
            (0..rows.len())
                .map(|r| stored.predictor.predict_row(&ds, r))
                .collect::<Result<Vec<_>>>()?
        };
        drop(models);
        self.record(start.elapsed().as_micros() as u64, rows.len() as u64, 1);
        Ok(out)
    }

    fn record(&self, us: u64, requests: u64, batches: u64) {
        let mut s = self.stats.lock().unwrap();
        s.requests += requests;
        s.batches += batches;
        s.total_latency_us += us;
        s.max_latency_us = s.max_latency_us.max(us);
    }
}

impl Default for ModelStore {
    fn default() -> Self {
        Self::new()
    }
}

/// Build an n-row dataset from flat observation values using the model's
/// stored feature schema (kinds + level counts from the container header).
fn row_dataset(
    predictor: &CompressedPredictor,
    flat: &[ObsValue],
    n_rows: usize,
) -> Result<Dataset> {
    let metas = &predictor.container().features;
    let d = metas.len();
    if flat.len() != d * n_rows {
        bail!("expected {} values ({} rows × {d} features), got {}", d * n_rows, n_rows, flat.len());
    }
    let mut features = Vec::with_capacity(d);
    for (j, meta) in metas.iter().enumerate() {
        let column = match meta.levels {
            None => {
                let mut v = Vec::with_capacity(n_rows);
                for r in 0..n_rows {
                    match flat[r * d + j] {
                        ObsValue::Num(x) => v.push(x),
                        ObsValue::Cat(_) => {
                            bail!("feature {:?} expects a numeric value", meta.name)
                        }
                    }
                }
                Column::Numeric(v)
            }
            Some(levels) => {
                let mut v = Vec::with_capacity(n_rows);
                for r in 0..n_rows {
                    match flat[r * d + j] {
                        ObsValue::Cat(c) if c < levels => v.push(c),
                        ObsValue::Cat(c) => {
                            bail!("feature {:?}: level {c} out of range (<{levels})", meta.name)
                        }
                        ObsValue::Num(_) => {
                            bail!("feature {:?} expects a categorical level", meta.name)
                        }
                    }
                }
                Column::Categorical { values: v, levels }
            }
        };
        features.push(Feature { name: meta.name.clone(), column });
    }
    // dummy target (prediction never reads it)
    let target = if predictor.container().classification {
        Target::Classification { labels: vec![0; n_rows], classes: predictor.container().classes.max(1) }
    } else {
        Target::Regression(vec![0.0; n_rows])
    };
    Ok(Dataset { name: "query".into(), features, target })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CompressOptions;
    use crate::data::synthetic;
    use crate::forest::{Forest, ForestParams};

    fn store_with_iris() -> (ModelStore, Forest, Dataset) {
        let ds = synthetic::iris(81);
        let f = Forest::train(&ds, &ForestParams::classification(5), 3);
        let cf = CompressedForest::compress(&f, &ds, &CompressOptions::default()).unwrap();
        let store = ModelStore::new();
        store.insert("iris", &cf).unwrap();
        (store, f, ds)
    }

    fn row_values(ds: &Dataset, row: usize) -> Vec<ObsValue> {
        ds.features
            .iter()
            .map(|f| match &f.column {
                Column::Numeric(v) => ObsValue::Num(v[row]),
                Column::Categorical { values, .. } => ObsValue::Cat(values[row]),
            })
            .collect()
    }

    #[test]
    fn store_predicts_like_original_forest() {
        let (store, f, ds) = store_with_iris();
        for row in (0..ds.num_rows()).step_by(17) {
            let vals = row_values(&ds, row);
            let got = store.predict("iris", &vals).unwrap();
            assert_eq!(got, PredictOne::Class(f.predict_class(&ds, row)));
        }
        assert!(store.stats().requests > 0);
    }

    #[test]
    fn batch_matches_single() {
        let (store, _, ds) = store_with_iris();
        let rows: Vec<Vec<ObsValue>> = (0..20).map(|r| row_values(&ds, r * 3)).collect();
        let batch = store.predict_batch("iris", &rows).unwrap();
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(batch[i], store.predict("iris", r).unwrap());
        }
    }

    #[test]
    fn unknown_model_and_bad_schema_rejected() {
        let (store, _, ds) = store_with_iris();
        let vals = row_values(&ds, 0);
        assert!(store.predict("nope", &vals).is_err());
        assert!(store.predict("iris", &vals[..2]).is_err());
        let mut bad = vals.clone();
        bad[0] = ObsValue::Cat(1);
        assert!(store.predict("iris", &bad).is_err());
    }

    #[test]
    fn multiple_models_and_removal() {
        let (store, _, ds) = store_with_iris();
        let ds2 = synthetic::wages(82);
        let f2 = Forest::train(&ds2, &ForestParams::classification(3), 4);
        let cf2 =
            CompressedForest::compress(&f2, &ds2, &CompressOptions::default()).unwrap();
        store.insert("wages", &cf2).unwrap();
        assert_eq!(store.names(), vec!["iris".to_string(), "wages".to_string()]);
        assert!(store.resident_bytes() > 0);
        let vals = row_values(&ds, 0);
        store.predict("iris", &vals).unwrap();
        assert!(store.remove("iris"));
        assert!(store.predict("iris", &vals).is_err());
        assert_eq!(store.len(), 1);
    }
}
