//! The model store: many compressed forests resident in memory, answering
//! predictions **from the compressed bytes** — the paper's motivating
//! deployment ("a user-specific ensemble … stored on a personal device with
//! strict storage limitations", §1).
//!
//! Scale shape:
//!
//! * **Sharded registry** — model names hash onto [`DEFAULT_SHARDS`] lock
//!   shards, so concurrent requests for different models never contend on
//!   one store-wide lock; a request clones the model's `Arc` out of its
//!   shard and predicts entirely outside any lock.
//! * **Storage budget** — [`ModelStore::with_budget`] caps resident
//!   compressed bytes (the paper's strict-storage device simulator). When
//!   an insert pushes past the budget, least-recently-used models are
//!   evicted until the store fits again; every prediction touches an atomic
//!   LRU clock, no lock required.
//! * **Zero-copy residency** — a stored model holds one `Arc<[u8]>`
//!   container buffer; its predictor's sections are views into it, so
//!   `resident_bytes` is an honest measure of what the model costs.

use crate::compress::flat::{PlanCache, DEFAULT_PLAN_CACHE_BYTES};
use crate::compress::predict::PredictOne;
use crate::compress::{CompressedForest, CompressedPredictor};
use crate::data::{Column, Dataset, Feature, Target};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Default number of lock shards (power of two; names spread via FNV-1a).
pub const DEFAULT_SHARDS: usize = 16;

/// One observation value, matching the model's feature schema.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ObsValue {
    Num(f64),
    Cat(u32),
}

/// Store statistics (served by the `STATS` protocol verb).
///
/// Latency accounting is **per request**: a batch of `n` answered in `t` µs
/// adds `n·t` to `total_latency_us` (each of those requests waited `t`), so
/// `total_latency_us / requests` is a true mean request latency and batches
/// no longer skew it.
#[derive(Debug, Clone, Copy, Default)]
pub struct StoreStats {
    pub requests: u64,
    pub batches: u64,
    pub total_latency_us: u64,
    pub max_latency_us: u64,
    pub evictions: u64,
    /// Flat-plan cache hits/misses across every resident model (a hit means
    /// a batch routed rows without touching the Huffman streams).
    pub plan_hits: u64,
    pub plan_misses: u64,
    /// Decoded plan bytes currently resident (charged against the store's
    /// `max_resident_bytes` budget).
    pub plan_bytes: u64,
}

impl StoreStats {
    /// Mean per-request latency in µs.
    pub fn mean_latency_us(&self) -> u64 {
        if self.requests > 0 {
            self.total_latency_us / self.requests
        } else {
            0
        }
    }
}

struct StoredModel {
    predictor: CompressedPredictor,
    compressed_bytes: u64,
    /// LRU stamp: the store clock value of the last touch.
    last_used: AtomicU64,
}

struct Shard {
    models: RwLock<BTreeMap<String, Arc<StoredModel>>>,
}

/// A thread-safe, sharded registry of compressed models with an optional
/// resident-bytes budget.
pub struct ModelStore {
    shards: Vec<Shard>,
    stats: Mutex<StoreStats>,
    /// Monotone access clock driving LRU eviction.
    clock: AtomicU64,
    /// Sum of `compressed_bytes` over resident models.
    resident: AtomicU64,
    max_resident_bytes: Option<u64>,
    predict_workers: usize,
    /// Decoded flat-tree plans, shared by every resident model's predictor.
    /// Plan bytes count against `max_resident_bytes`: budget enforcement
    /// shrinks this cache *before* evicting any model (a dropped plan
    /// rebuilds on the next batch; a dropped model needs a re-insert).
    plans: Arc<PlanCache>,
}

fn shard_index(name: &str, n: usize) -> usize {
    // FNV-1a over the model name; any stable spreading hash works
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in name.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % n as u64) as usize
}

impl ModelStore {
    /// Unbounded store with the default shard count.
    pub fn new() -> Self {
        Self::with_config(DEFAULT_SHARDS, None)
    }

    /// Store with a resident-bytes budget: inserting past it evicts
    /// least-recently-used models until the store fits again.
    pub fn with_budget(max_resident_bytes: u64) -> Self {
        Self::with_config(DEFAULT_SHARDS, Some(max_resident_bytes))
    }

    /// Fully explicit construction (shard count + optional budget).
    pub fn with_config(shards: usize, max_resident_bytes: Option<u64>) -> Self {
        // budgeted stores start the plan cap at the whole budget (it shrinks
        // as compressed bytes move in); unbounded stores get a fixed default
        let plan_cap = max_resident_bytes.unwrap_or(DEFAULT_PLAN_CACHE_BYTES);
        ModelStore {
            shards: (0..shards.max(1))
                .map(|_| Shard { models: RwLock::new(BTreeMap::new()) })
                .collect(),
            stats: Mutex::new(StoreStats::default()),
            clock: AtomicU64::new(0),
            resident: AtomicU64::new(0),
            max_resident_bytes,
            predict_workers: 1,
            plans: Arc::new(PlanCache::new(plan_cap)),
        }
    }

    /// Builder: worker threads handed to each model's batch predictor.
    pub fn predict_workers(mut self, workers: usize) -> Self {
        self.predict_workers = workers.max(1);
        self
    }

    /// Builder: byte cap of the flat-plan cache. Only meaningful for stores
    /// **without** a `max_resident_bytes` budget — budgeted stores size the
    /// cache to whatever the budget leaves after compressed bytes.
    pub fn plan_cache_bytes(self, bytes: u64) -> Self {
        if self.max_resident_bytes.is_none() {
            self.plans.set_max_bytes(bytes);
        }
        self
    }

    pub fn max_resident_bytes(&self) -> Option<u64> {
        self.max_resident_bytes
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    fn shard(&self, name: &str) -> &Shard {
        &self.shards[shard_index(name, self.shards.len())]
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    /// Register a compressed forest under a name (replacing any previous
    /// model of that name), then enforce the storage budget. The new model
    /// itself is never the eviction victim of its own insert.
    pub fn insert(&self, name: &str, cf: &CompressedForest) -> Result<()> {
        let bytes = cf.total_bytes();
        if let Some(budget) = self.max_resident_bytes {
            if bytes > budget {
                bail!(
                    "model {name:?} ({bytes} compressed bytes) exceeds the store \
                     budget ({budget} bytes) on its own"
                );
            }
        }
        let pc = cf.parse()?; // zero-copy: shares cf's Arc<[u8]>
        let predictor = CompressedPredictor::new(pc)?
            .with_workers(self.predict_workers)
            .with_plan_cache(self.plans.clone());
        let model = Arc::new(StoredModel {
            predictor,
            compressed_bytes: bytes,
            last_used: AtomicU64::new(self.tick()),
        });
        // account the bytes BEFORE the model becomes visible in its shard:
        // a concurrent enforce_budget may evict it the moment it appears,
        // and its fetch_sub must never run ahead of our fetch_add (a u64
        // underflow here would read as an enormous resident total and
        // mass-evict the store)
        self.resident.fetch_add(bytes, Ordering::Relaxed);
        let old = self
            .shard(name)
            .models
            .write()
            .unwrap()
            .insert(name.to_string(), model);
        if let Some(old) = old {
            self.resident.fetch_sub(old.compressed_bytes, Ordering::Relaxed);
            // the replaced parse's plans can never be served again
            self.plans.purge_model(old.predictor.model_id());
        }
        self.enforce_budget(name);
        Ok(())
    }

    /// Load a container file from disk.
    pub fn insert_from_file(&self, name: &str, path: &std::path::Path) -> Result<()> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        let cf = CompressedForest::from_bytes(bytes)?;
        self.insert(name, &cf)
    }

    /// Enforce `max_resident_bytes` over compressed bytes **plus** decoded
    /// plan bytes. Plans are dropped first (they rebuild on demand); only
    /// when the compressed bytes alone still exceed the budget are
    /// least-recently-used models (never `keep`) evicted.
    fn enforce_budget(&self, keep: &str) {
        let Some(budget) = self.max_resident_bytes else { return };
        // cap the plan cache to whatever the budget leaves after the
        // compressed residents; this also evicts plans already past the cap
        self.plans
            .set_max_bytes(budget.saturating_sub(self.resident.load(Ordering::Relaxed)));
        while self.resident.load(Ordering::Relaxed) > budget {
            let mut victim: Option<(String, u64)> = None;
            for shard in &self.shards {
                let models = shard.models.read().unwrap();
                for (name, model) in models.iter() {
                    if name == keep {
                        continue;
                    }
                    let used = model.last_used.load(Ordering::Relaxed);
                    if victim.as_ref().map_or(true, |(_, best)| used < *best) {
                        victim = Some((name.clone(), used));
                    }
                }
            }
            let Some((name, _)) = victim else { break };
            if self.remove(&name) {
                self.stats.lock().unwrap().evictions += 1;
            }
        }
        // model evictions freed compressed bytes: let plans grow back into
        // the slack
        self.plans
            .set_max_bytes(budget.saturating_sub(self.resident.load(Ordering::Relaxed)));
    }

    pub fn remove(&self, name: &str) -> bool {
        let removed = self.shard(name).models.write().unwrap().remove(name);
        match removed {
            Some(m) => {
                self.resident.fetch_sub(m.compressed_bytes, Ordering::Relaxed);
                self.plans.purge_model(m.predictor.model_id());
                true
            }
            None => false,
        }
    }

    pub fn contains(&self, name: &str) -> bool {
        self.shard(name).models.read().unwrap().contains_key(name)
    }

    /// Resident model names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut out: Vec<String> = self
            .shards
            .iter()
            .flat_map(|s| s.models.read().unwrap().keys().cloned().collect::<Vec<_>>())
            .collect();
        out.sort();
        out
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.models.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total compressed bytes resident (the "storage budget" figure;
    /// decoded plan bytes are reported separately by [`Self::plan_bytes`]).
    pub fn resident_bytes(&self) -> u64 {
        self.resident.load(Ordering::Relaxed)
    }

    /// Decoded flat-plan bytes currently resident.
    pub fn plan_bytes(&self) -> u64 {
        self.plans.resident_bytes()
    }

    /// The shared flat-plan cache (counters, budget introspection).
    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.plans
    }

    pub fn stats(&self) -> StoreStats {
        let mut s = *self.stats.lock().unwrap();
        let p = self.plans.stats();
        s.plan_hits = p.hits;
        s.plan_misses = p.misses;
        s.plan_bytes = p.resident_bytes;
        s
    }

    /// Look a model up (read lock held only for the map probe) and stamp
    /// its LRU clock.
    fn get(&self, name: &str) -> Result<Arc<StoredModel>> {
        let model = self
            .shard(name)
            .models
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .with_context(|| format!("unknown model {name:?}"))?;
        model.last_used.store(self.tick(), Ordering::Relaxed);
        Ok(model)
    }

    /// Predict a single observation against a named model. The shard lock
    /// covers only the name lookup; decoding runs lock-free on the shared
    /// buffer.
    pub fn predict(&self, model: &str, values: &[ObsValue]) -> Result<PredictOne> {
        let start = std::time::Instant::now();
        let stored = self.get(model)?;
        let ds = row_dataset(&stored.predictor, values, 1)?;
        let out = stored.predictor.predict_row(&ds, 0)?;
        self.record(start.elapsed().as_micros() as u64, 1, 1);
        Ok(out)
    }

    /// Predict a batch of observations (the micro-batcher's path: one
    /// schema check + per-tree decode amortized over the batch, sharded
    /// across the predictor's worker threads).
    pub fn predict_batch(&self, model: &str, rows: &[Vec<ObsValue>]) -> Result<Vec<PredictOne>> {
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        let start = std::time::Instant::now();
        let stored = self.get(model)?;
        let flat: Vec<ObsValue> = rows.iter().flatten().copied().collect();
        let ds = row_dataset(&stored.predictor, &flat, rows.len())?;
        // batched path decodes each tree once when the batch is large enough
        // to amortize it; small batches use the per-row prefix decode
        let out = if rows.len() >= 8 {
            match stored.predictor.predict_all(&ds)? {
                crate::forest::forest::Predictions::Classes(cs) => {
                    cs.into_iter().map(PredictOne::Class).collect()
                }
                crate::forest::forest::Predictions::Values(vs) => {
                    vs.into_iter().map(PredictOne::Value).collect()
                }
            }
        } else {
            (0..rows.len())
                .map(|r| stored.predictor.predict_row(&ds, r))
                .collect::<Result<Vec<_>>>()?
        };
        self.record(start.elapsed().as_micros() as u64, rows.len() as u64, 1);
        Ok(out)
    }

    /// Per-request latency accounting: `us` is the wall time every one of
    /// the `requests` in this batch waited, so it is charged once per
    /// request (see [`StoreStats`]).
    fn record(&self, us: u64, requests: u64, batches: u64) {
        let mut s = self.stats.lock().unwrap();
        s.requests += requests;
        s.batches += batches;
        s.total_latency_us += us * requests;
        s.max_latency_us = s.max_latency_us.max(us);
    }
}

impl Default for ModelStore {
    fn default() -> Self {
        Self::new()
    }
}

/// Build an n-row dataset from flat observation values using the model's
/// stored feature schema (kinds + level counts from the container header).
fn row_dataset(
    predictor: &CompressedPredictor,
    flat: &[ObsValue],
    n_rows: usize,
) -> Result<Dataset> {
    let metas = &predictor.container().features;
    let d = metas.len();
    if flat.len() != d * n_rows {
        bail!("expected {} values ({} rows × {d} features), got {}", d * n_rows, n_rows, flat.len());
    }
    let mut features = Vec::with_capacity(d);
    for (j, meta) in metas.iter().enumerate() {
        let column = match meta.levels {
            None => {
                let mut v = Vec::with_capacity(n_rows);
                for r in 0..n_rows {
                    match flat[r * d + j] {
                        ObsValue::Num(x) => v.push(x),
                        ObsValue::Cat(_) => {
                            bail!("feature {:?} expects a numeric value", meta.name)
                        }
                    }
                }
                Column::Numeric(v)
            }
            Some(levels) => {
                let mut v = Vec::with_capacity(n_rows);
                for r in 0..n_rows {
                    match flat[r * d + j] {
                        ObsValue::Cat(c) if c < levels => v.push(c),
                        ObsValue::Cat(c) => {
                            bail!("feature {:?}: level {c} out of range (<{levels})", meta.name)
                        }
                        ObsValue::Num(_) => {
                            bail!("feature {:?} expects a categorical level", meta.name)
                        }
                    }
                }
                Column::Categorical { values: v, levels }
            }
        };
        features.push(Feature { name: meta.name.clone(), column });
    }
    // dummy target (prediction never reads it)
    let target = if predictor.container().classification {
        Target::Classification { labels: vec![0; n_rows], classes: predictor.container().classes.max(1) }
    } else {
        Target::Regression(vec![0.0; n_rows])
    };
    Ok(Dataset { name: "query".into(), features, target })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CompressOptions;
    use crate::data::synthetic;
    use crate::forest::{Forest, ForestParams};

    fn iris_model(seed: u64) -> (CompressedForest, Forest, Dataset) {
        let ds = synthetic::iris(81);
        let f = Forest::train(&ds, &ForestParams::classification(5), seed);
        let cf = CompressedForest::compress(&f, &ds, &CompressOptions::default()).unwrap();
        (cf, f, ds)
    }

    fn store_with_iris() -> (ModelStore, Forest, Dataset) {
        let (cf, f, ds) = iris_model(3);
        let store = ModelStore::new();
        store.insert("iris", &cf).unwrap();
        (store, f, ds)
    }

    fn row_values(ds: &Dataset, row: usize) -> Vec<ObsValue> {
        ds.features
            .iter()
            .map(|f| match &f.column {
                Column::Numeric(v) => ObsValue::Num(v[row]),
                Column::Categorical { values, .. } => ObsValue::Cat(values[row]),
            })
            .collect()
    }

    #[test]
    fn store_predicts_like_original_forest() {
        let (store, f, ds) = store_with_iris();
        for row in (0..ds.num_rows()).step_by(17) {
            let vals = row_values(&ds, row);
            let got = store.predict("iris", &vals).unwrap();
            assert_eq!(got, PredictOne::Class(f.predict_class(&ds, row)));
        }
        assert!(store.stats().requests > 0);
    }

    #[test]
    fn batch_matches_single() {
        let (store, _, ds) = store_with_iris();
        let rows: Vec<Vec<ObsValue>> = (0..20).map(|r| row_values(&ds, r * 3)).collect();
        let batch = store.predict_batch("iris", &rows).unwrap();
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(batch[i], store.predict("iris", r).unwrap());
        }
        // per-request accounting: a 20-row batch counts 20 requests and the
        // mean stays a per-request figure
        let s = store.stats();
        assert!(s.requests >= 20 + rows.len() as u64);
        assert!(s.mean_latency_us() <= s.max_latency_us);
    }

    #[test]
    fn unknown_model_and_bad_schema_rejected() {
        let (store, _, ds) = store_with_iris();
        let vals = row_values(&ds, 0);
        assert!(store.predict("nope", &vals).is_err());
        assert!(store.predict("iris", &vals[..2]).is_err());
        let mut bad = vals.clone();
        bad[0] = ObsValue::Cat(1);
        assert!(store.predict("iris", &bad).is_err());
    }

    #[test]
    fn multiple_models_and_removal() {
        let (store, _, ds) = store_with_iris();
        let ds2 = synthetic::wages(82);
        let f2 = Forest::train(&ds2, &ForestParams::classification(3), 4);
        let cf2 =
            CompressedForest::compress(&f2, &ds2, &CompressOptions::default()).unwrap();
        store.insert("wages", &cf2).unwrap();
        assert_eq!(store.names(), vec!["iris".to_string(), "wages".to_string()]);
        assert!(store.resident_bytes() > 0);
        let vals = row_values(&ds, 0);
        store.predict("iris", &vals).unwrap();
        assert!(store.remove("iris"));
        assert!(store.predict("iris", &vals).is_err());
        assert_eq!(store.len(), 1);
        assert!(store.contains("wages") && !store.contains("iris"));
    }

    #[test]
    fn shards_spread_names_and_agree_with_flat_view() {
        let (cf, _, _) = iris_model(5);
        let store = ModelStore::with_config(4, None);
        assert_eq!(store.num_shards(), 4);
        for i in 0..12 {
            store.insert(&format!("model-{i}"), &cf).unwrap();
        }
        assert_eq!(store.len(), 12);
        let names = store.names();
        assert_eq!(names.len(), 12);
        assert!(names.windows(2).all(|w| w[0] < w[1]), "names sorted");
        assert_eq!(store.resident_bytes(), 12 * cf.total_bytes());
    }

    #[test]
    fn lru_eviction_respects_budget_and_recency() {
        let (cf, _, ds) = iris_model(6);
        let one = cf.total_bytes();
        // room for exactly three models
        let store = ModelStore::with_budget(3 * one + one / 2);
        store.insert("a", &cf).unwrap();
        store.insert("b", &cf).unwrap();
        store.insert("c", &cf).unwrap();
        assert_eq!(store.len(), 3);
        // touch "a" so "b" is now the LRU
        store.predict("a", &row_values(&ds, 0)).unwrap();
        store.insert("d", &cf).unwrap();
        assert_eq!(store.len(), 3, "budget holds three models");
        assert!(store.resident_bytes() <= store.max_resident_bytes().unwrap());
        assert_eq!(store.names(), vec!["a".to_string(), "c".to_string(), "d".to_string()]);
        assert_eq!(store.stats().evictions, 1);
        // an over-budget single model is refused outright
        let tiny = ModelStore::with_budget(one / 2);
        assert!(tiny.insert("too-big", &cf).is_err());
    }

    #[test]
    fn reinsert_same_name_replaces_without_double_counting() {
        let (cf, _, _) = iris_model(7);
        let store = ModelStore::new();
        store.insert("m", &cf).unwrap();
        store.insert("m", &cf).unwrap();
        assert_eq!(store.len(), 1);
        assert_eq!(store.resident_bytes(), cf.total_bytes());
    }

    #[test]
    fn warm_batches_hit_the_plan_cache() {
        let (store, f, ds) = store_with_iris();
        let rows: Vec<Vec<ObsValue>> = (0..20).map(|r| row_values(&ds, r * 3)).collect();
        let cold = store.predict_batch("iris", &rows).unwrap();
        let s = store.stats();
        assert_eq!(s.plan_misses, 5, "first batch decodes each of the 5 trees once");
        assert_eq!(s.plan_hits, 0);
        assert!(s.plan_bytes > 0, "plans stay resident for the next batch");
        let warm = store.predict_batch("iris", &rows).unwrap();
        assert_eq!(warm, cold);
        let s = store.stats();
        assert_eq!(s.plan_misses, 5, "warm batch decodes nothing");
        assert_eq!(s.plan_hits, 5);
        for (i, out) in cold.iter().enumerate() {
            assert_eq!(*out, PredictOne::Class(f.predict_class(&ds, i * 3)));
        }
    }

    #[test]
    fn removal_and_replacement_purge_plans() {
        let (store, _, ds) = store_with_iris();
        let rows: Vec<Vec<ObsValue>> = (0..16).map(|r| row_values(&ds, r)).collect();
        store.predict_batch("iris", &rows).unwrap();
        assert!(store.plan_bytes() > 0);
        // replacing the model orphans the old parse's plans: they are purged
        let (cf, _, _) = iris_model(12);
        store.insert("iris", &cf).unwrap();
        assert_eq!(store.plan_bytes(), 0, "replaced model's plans purged");
        store.predict_batch("iris", &rows).unwrap();
        assert!(store.plan_bytes() > 0);
        assert!(store.remove("iris"));
        assert_eq!(store.plan_bytes(), 0, "removed model's plans purged");
    }

    #[test]
    fn budget_drops_plans_before_models() {
        let (cf, f, ds) = iris_model(6);
        let one = cf.total_bytes();
        let store = ModelStore::with_budget(2 * one + one / 2);
        store.insert("a", &cf).unwrap();
        store.insert("b", &cf).unwrap();
        // plans may only use the budget slack left by the compressed bytes
        assert_eq!(store.plan_cache().max_bytes(), one / 2);
        let rows: Vec<Vec<ObsValue>> = (0..16).map(|r| row_values(&ds, r)).collect();
        store.predict_batch("a", &rows).unwrap();
        assert!(store.plan_bytes() <= one / 2);
        // a third insert exceeds the budget: every plan goes first, then
        // exactly one model
        store.insert("c", &cf).unwrap();
        assert_eq!(store.plan_bytes(), 0, "plans are the first eviction victims");
        assert_eq!(store.len(), 2);
        assert_eq!(store.stats().evictions, 1);
        assert!(store.resident_bytes() <= store.max_resident_bytes().unwrap());
        // serving still works (plans rebuild on demand)
        let out = store.predict_batch("c", &rows).unwrap();
        assert_eq!(out[0], PredictOne::Class(f.predict_class(&ds, 0)));
    }
}
