//! The model store: many compressed forests resident in memory, answering
//! predictions **from the compressed bytes** — the paper's motivating
//! deployment ("a user-specific ensemble … stored on a personal device with
//! strict storage limitations", §1).
//!
//! Scale shape:
//!
//! * **Sharded registry** — model names hash onto [`DEFAULT_SHARDS`] lock
//!   shards, so concurrent requests for different models never contend on
//!   one store-wide lock; a request clones the model's `Arc` out of its
//!   shard and predicts entirely outside any lock.
//! * **Storage budget** — [`ModelStore::with_budget`] caps resident
//!   compressed bytes (the paper's strict-storage device simulator). When
//!   an insert pushes past the budget, least-recently-used models are
//!   evicted until the store fits again; every prediction touches an atomic
//!   LRU clock, no lock required.
//! * **Three tiers (RAM → disk spill → pack)** — with a spill directory
//!   configured ([`ModelStore::spill_dir`]), a budget eviction *spills* the
//!   model's container bytes to disk instead of dropping it. The next
//!   request for a spilled model reloads it through an `mmap`-backed buffer
//!   ([`crate::util::mmap::Mmap`]): because the zero-copy parse only records
//!   spans, the reload is a map + header parse — no read, no payload
//!   memcpy. The disk tier has its own byte budget
//!   ([`ModelStore::spill_bytes`]) with its own LRU; a model evicted from
//!   *that* is gone. Tier lifecycle: `Resident → Spilled → (reload →
//!   Resident | LRU → gone)`; spill files are deleted on reload, removal,
//!   replacement, and store shutdown — they are cache, never durable state.
//!   Separately, [`ModelStore::attach_pack`] mounts every member of an
//!   `RFPK` archive ([`crate::pack::PackArchive`]) as a **Packed**-tier
//!   model: nothing is parsed until the first request
//!   (`Packed → Resident`), and a budget eviction of a pack member
//!   *releases* it back to its archive (`Resident → Packed`) — no spill
//!   file, no disk write, the pack keeps the bytes. Removing a member (or
//!   the whole store) never deletes the pack: archives are durable
//!   artifacts, unlike spill files.
//! * **Zero-copy residency** — a stored model holds one shared container
//!   buffer; its predictor's sections are views into it (for a pack member:
//!   into the pack's single mapping), so `resident_bytes` is an honest
//!   measure of what the model costs.
//!
//! Budget accounting order under pressure: decoded **plans** are dropped
//! first (they rebuild on demand), then pack members **release** to their
//! archive (free) and directly-inserted models **spill** to disk (a reload
//! is an mmap away), and only past the spill budget is a model **evicted**
//! outright.

use crate::compress::container::parse_arc;
use crate::coordinator::admission::{sketch_hash, AdmissionPolicy, FrequencySketch};
use crate::compress::flat::{PlanCache, DEFAULT_PLAN_CACHE_BYTES};
use crate::compress::predict::PredictOne;
use crate::compress::{CompressedForest, CompressedPredictor};
use crate::data::{Column, Dataset, Feature, Target};
use crate::obs::{BatchTrace, Obs, Phase, Span};
use crate::pack::{compact_chain, CompactMode, PackArchive, PackChain};
use crate::util::mmap::Mmap;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Default number of lock shards (power of two; names spread via FNV-1a).
pub const DEFAULT_SHARDS: usize = 16;

/// One observation value, matching the model's feature schema.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ObsValue {
    /// A numeric feature value.
    Num(f64),
    /// A categorical level index (written `c<idx>` on the wire).
    Cat(u32),
}

/// Store statistics (served by the `STATS` protocol verb).
///
/// Latency accounting is **per request**: a batch of `n` answered in `t` µs
/// adds `n·t` to `total_latency_us` (each of those requests waited `t`), so
/// `total_latency_us / requests` is a true mean request latency and batches
/// no longer skew it.
#[derive(Debug, Clone, Copy, Default)]
pub struct StoreStats {
    /// Prediction requests answered (each batch member counts once).
    pub requests: u64,
    /// Prediction calls into the store (a whole batch counts once).
    pub batches: u64,
    /// Sum of per-request latencies in µs (see the accounting note above).
    pub total_latency_us: u64,
    /// Slowest single store call observed, in µs.
    pub max_latency_us: u64,
    /// Models dropped from the store entirely (RAM eviction with no spill
    /// tier, or LRU eviction from the spill tier itself).
    pub evictions: u64,
    /// Resident → Spilled transitions (container bytes written to disk).
    pub spills: u64,
    /// Spilled → Resident transitions (mmap-backed reloads).
    pub reloads: u64,
    /// Container bytes currently parked in the spill directory.
    pub spill_bytes: u64,
    /// Flat-plan cache hits/misses across every resident model (a hit means
    /// a batch routed rows without touching the Huffman streams).
    pub plan_hits: u64,
    /// Flat-plan cache misses (each miss decoded a tree into a plan).
    pub plan_misses: u64,
    /// Decoded plan bytes currently resident (charged against the store's
    /// `max_resident_bytes` budget).
    pub plan_bytes: u64,
    /// Packed → Resident transitions (a member parsed out of its archive).
    pub pack_loads: u64,
    /// Resident → Packed transitions (a member released back to its archive
    /// under budget pressure — free, no disk write).
    pub pack_releases: u64,
    /// Logical container bytes currently parked in the Packed tier
    /// (unloaded pack members).
    pub packed_bytes: u64,
    /// Pipelined requests currently in flight across every connection (a
    /// gauge, not a counter: admitted via `PIPE` but not yet answered).
    pub inflight: u64,
    /// Pipelined requests refused with `ERR busy` because their connection
    /// was at its in-flight cap.
    pub rejected_busy: u64,
    /// Requests that outlived the configured request timeout and were
    /// answered with a typed `ERR timeout` line (serial and pipelined).
    pub timeouts: u64,
    /// `PREFETCH` requests that initiated a background warm-up of a
    /// Spilled/Packed model (an already-Resident target is not counted).
    pub prefetches: u64,
    /// Get-path loads the TinyLFU gate demoted right back out of the
    /// resident tier because the LRU victim they would have displaced was
    /// estimated hotter (always 0 under the `lru` policy).
    pub admission_rejects: u64,
    /// Generations across every mounted pack chain (a gauge: a lone
    /// immutable base reads 1; compaction collapses a chain back to 1).
    pub pack_generations: u64,
    /// Chain compactions this store ran (threshold-triggered or forced).
    pub compactions: u64,
    /// Tombstone entries across every mounted chain (a gauge; compaction
    /// clears a chain's tombstones to 0).
    pub tombstones: u64,
    /// Median per-request latency in µs, read from the store's live
    /// request histogram at snapshot time (bucket upper edge, ≤ 12.5%
    /// relative error; 0 until the first request).
    pub p50_latency_us: u64,
    /// 99th-percentile per-request latency in µs (same source and
    /// precision as [`StoreStats::p50_latency_us`]).
    pub p99_latency_us: u64,
}

impl StoreStats {
    /// Mean per-request latency in µs.
    pub fn mean_latency_us(&self) -> u64 {
        if self.requests > 0 {
            self.total_latency_us / self.requests
        } else {
            0
        }
    }
}

/// Where a resident model's bytes came from — decides what a budget
/// eviction does with it (spill/drop vs release to its pack).
enum ModelOrigin {
    /// Directly inserted ([`ModelStore::insert`]).
    Direct,
    /// Loaded out of a model pack; eviction releases back to the archive.
    Packed { pack: Arc<PackArchive>, member: usize },
}

struct StoredModel {
    predictor: CompressedPredictor,
    compressed_bytes: u64,
    origin: ModelOrigin,
    /// LRU stamp: the store clock value of the last touch.
    last_used: AtomicU64,
}

/// A model parked on disk: its container bytes, verbatim, in one spill file.
struct SpillEntry {
    path: PathBuf,
    bytes: u64,
    /// LRU stamp frozen at spill time (only the shard write lock mutates a
    /// spilled entry, so no atomic needed).
    last_used: u64,
}

/// An unloaded pack member: the archive holds the bytes; nothing is parsed
/// or resident until the first request.
struct PackedEntry {
    pack: Arc<PackArchive>,
    member: usize,
    /// Logical container bytes (what the member costs once Resident).
    bytes: u64,
    /// LRU stamp frozen at attach/release time. No eviction scans the
    /// Packed tier today (its members cost nothing until loaded); the
    /// stamp is kept for symmetry with [`SpillEntry`] and as the input a
    /// future pack-prefetch heuristic would rank members by.
    last_used: u64,
}

/// The tier a named model currently occupies.
enum Tier {
    Resident(Arc<StoredModel>),
    Spilled(SpillEntry),
    Packed(PackedEntry),
}

struct Shard {
    models: RwLock<BTreeMap<String, Tier>>,
}

/// A thread-safe, sharded registry of compressed models with an optional
/// resident-bytes budget and an optional disk spill tier.
pub struct ModelStore {
    shards: Vec<Shard>,
    stats: Mutex<StoreStats>,
    /// Monotone access clock driving LRU eviction.
    clock: AtomicU64,
    /// Sum of `compressed_bytes` over RAM-resident models.
    resident: AtomicU64,
    max_resident_bytes: Option<u64>,
    /// Sum of spill-file bytes over disk-tier models.
    spilled: AtomicU64,
    /// Sum of logical bytes over unloaded Packed-tier members.
    packed: AtomicU64,
    /// Where evicted models spill to (None = evictions drop models).
    spill_dir: Option<PathBuf>,
    /// Byte cap of the spill tier (None = unbounded disk).
    max_spill_bytes: Option<u64>,
    /// Monotone spill-file sequence within this store.
    spill_seq: AtomicU64,
    /// Process-wide store token baked into spill filenames, so stores (or
    /// restarted processes) sharing one spill directory never overwrite
    /// each other's files.
    spill_token: u64,
    /// In-flight pipelined requests, summed over every live connection
    /// (see [`StoreStats::inflight`]; the server moves it).
    inflight: AtomicU64,
    predict_workers: usize,
    /// Decoded flat-tree plans, shared by every resident model's predictor.
    /// Plan bytes count against `max_resident_bytes`: budget enforcement
    /// shrinks this cache *before* spilling or evicting any model (a
    /// dropped plan rebuilds on the next batch).
    plans: Arc<PlanCache>,
    /// Admission policy under budget pressure (see
    /// [`crate::coordinator::admission`]).
    admission: AdmissionPolicy,
    /// TinyLFU frequency sketch, allocated only under
    /// [`AdmissionPolicy::TinyLfu`]. Request-path lookups touch it; budget
    /// enforcement compares candidate-vs-victim estimates through it.
    sketch: Option<Mutex<FrequencySketch>>,
    /// Observability hub: request-latency histogram, mirrored counters,
    /// and the slow-request ring. The server reads it for `METRICS`/`SLOW`.
    obs: Arc<Obs>,
    /// Mounted generation chains ([`Self::attach_chain`]). Each chain has
    /// its own mutex: mutations and compaction serialize per chain, while
    /// request-path loads never touch these locks at all (a Packed entry
    /// holds its generation's `Arc<PackArchive>` directly).
    chains: Mutex<Vec<Arc<Mutex<PackChain>>>>,
    /// Store-side compaction trigger: a mounted chain at or past this many
    /// generations is compacted ([`DEFAULT_COMPACT_GENERATIONS`]).
    compact_generations: usize,
    /// Store-side compaction trigger: compact when tombstones reach this
    /// fraction of a chain's entries (tombstones / (live + tombstones)).
    compact_tombstone_ratio: f64,
}

/// Default generation-count threshold for store-side chain compaction.
pub const DEFAULT_COMPACT_GENERATIONS: usize = 8;
/// Default tombstone-ratio threshold for store-side chain compaction.
pub const DEFAULT_COMPACT_TOMBSTONE_RATIO: f64 = 0.5;

/// Source of per-store [`ModelStore::spill_token`] values.
static NEXT_STORE_TOKEN: AtomicU64 = AtomicU64::new(0);

fn shard_index(name: &str, n: usize) -> usize {
    // FNV-1a over the model name; any stable spreading hash works
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in name.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % n as u64) as usize
}

impl ModelStore {
    /// Unbounded store with the default shard count.
    pub fn new() -> Self {
        Self::with_config(DEFAULT_SHARDS, None)
    }

    /// Store with a resident-bytes budget: inserting past it evicts
    /// least-recently-used models until the store fits again (or spills
    /// them, when a spill directory is configured).
    pub fn with_budget(max_resident_bytes: u64) -> Self {
        Self::with_config(DEFAULT_SHARDS, Some(max_resident_bytes))
    }

    /// Fully explicit construction (shard count + optional budget).
    pub fn with_config(shards: usize, max_resident_bytes: Option<u64>) -> Self {
        // budgeted stores start the plan cap at the whole budget (it shrinks
        // as compressed bytes move in); unbounded stores get a fixed default
        let plan_cap = max_resident_bytes.unwrap_or(DEFAULT_PLAN_CACHE_BYTES);
        ModelStore {
            shards: (0..shards.max(1))
                .map(|_| Shard { models: RwLock::new(BTreeMap::new()) })
                .collect(),
            stats: Mutex::new(StoreStats::default()),
            clock: AtomicU64::new(0),
            resident: AtomicU64::new(0),
            max_resident_bytes,
            spilled: AtomicU64::new(0),
            packed: AtomicU64::new(0),
            spill_dir: None,
            max_spill_bytes: None,
            spill_seq: AtomicU64::new(0),
            spill_token: NEXT_STORE_TOKEN.fetch_add(1, Ordering::Relaxed),
            inflight: AtomicU64::new(0),
            predict_workers: 1,
            plans: Arc::new(PlanCache::new(plan_cap)),
            admission: AdmissionPolicy::Lru,
            sketch: None,
            obs: Arc::new(Obs::for_store(
                crate::obs::DEFAULT_SLOW_THRESHOLD_US,
                crate::obs::DEFAULT_TRACE_RING,
            )),
            chains: Mutex::new(Vec::new()),
            compact_generations: DEFAULT_COMPACT_GENERATIONS,
            compact_tombstone_ratio: DEFAULT_COMPACT_TOMBSTONE_RATIO,
        }
    }

    /// Builder: wall-time threshold (µs) past which a finished request
    /// span is retained in the slow ring (`--slow-threshold-us`; 0 retains
    /// every traced request).
    pub fn slow_threshold_us(self, us: u64) -> Self {
        self.obs.set_slow_threshold_us(us);
        self
    }

    /// Builder: slow-ring capacity (`--trace-ring N`; 0 disables
    /// retention). Rebuilds the hub, so call before handing the store out.
    pub fn trace_ring(mut self, cap: usize) -> Self {
        self.obs = Arc::new(Obs::for_store(self.obs.slow_threshold_us(), cap));
        self
    }

    /// The store's observability hub (`METRICS`/`SLOW` read through this).
    pub fn obs(&self) -> &Arc<Obs> {
        &self.obs
    }

    /// Builder: worker threads handed to each model's batch predictor.
    pub fn predict_workers(mut self, workers: usize) -> Self {
        self.predict_workers = workers.max(1);
        self
    }

    /// Builder: byte cap of the flat-plan cache. Only meaningful for stores
    /// **without** a `max_resident_bytes` budget — budgeted stores size the
    /// cache to whatever the budget leaves after compressed bytes.
    pub fn plan_cache_bytes(self, bytes: u64) -> Self {
        if self.max_resident_bytes.is_none() {
            self.plans.set_max_bytes(bytes);
        }
        self
    }

    /// Builder: enable the disk tier. Budget evictions spill container
    /// bytes into `dir` (created on first spill) instead of dropping the
    /// model; the next request reloads it through an mmap-backed buffer.
    pub fn spill_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.spill_dir = Some(dir.into());
        self
    }

    /// Builder: byte cap of the spill tier. Past it, the least-recently-used
    /// *spilled* model's file is deleted and the model leaves the store for
    /// good. Only meaningful together with [`Self::spill_dir`].
    pub fn spill_bytes(mut self, bytes: u64) -> Self {
        self.max_spill_bytes = Some(bytes);
        self
    }

    /// Builder: select the admission policy budget enforcement runs under.
    /// [`AdmissionPolicy::TinyLfu`] allocates the frequency sketch; with an
    /// empty sketch the gate admits everything, so behavior starts exactly
    /// as LRU and diverges only once frequency history accumulates.
    pub fn admission(mut self, policy: AdmissionPolicy) -> Self {
        self.admission = policy;
        self.sketch = match policy {
            AdmissionPolicy::Lru => None,
            AdmissionPolicy::TinyLfu => Some(Mutex::new(FrequencySketch::default())),
        };
        self
    }

    /// The configured admission policy.
    pub fn admission_policy(&self) -> AdmissionPolicy {
        self.admission
    }

    /// The RAM budget, when one was configured.
    pub fn max_resident_bytes(&self) -> Option<u64> {
        self.max_resident_bytes
    }

    /// The disk-tier byte cap, when one was configured.
    pub fn max_spill_bytes(&self) -> Option<u64> {
        self.max_spill_bytes
    }

    /// The configured spill directory, if the disk tier is enabled.
    pub fn spill_path(&self) -> Option<&std::path::Path> {
        self.spill_dir.as_deref()
    }

    /// Number of lock shards the registry spreads names over.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    fn shard(&self, name: &str) -> &Shard {
        &self.shards[shard_index(name, self.shards.len())]
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    /// Register a compressed forest under a name (replacing any previous
    /// model of that name), then enforce the storage budget. The new model
    /// itself is never the eviction victim of its own insert.
    pub fn insert(&self, name: &str, cf: &CompressedForest) -> Result<()> {
        let bytes = cf.total_bytes();
        if let Some(budget) = self.max_resident_bytes {
            if bytes > budget {
                bail!(
                    "model {name:?} ({bytes} compressed bytes) exceeds the store \
                     budget ({budget} bytes) on its own"
                );
            }
        }
        let pc = cf.parse()?; // zero-copy: shares cf's Arc<[u8]>
        let predictor = CompressedPredictor::new(pc)?
            .with_workers(self.predict_workers)
            .with_plan_cache(self.plans.clone());
        let model = Arc::new(StoredModel {
            predictor,
            compressed_bytes: bytes,
            origin: ModelOrigin::Direct,
            last_used: AtomicU64::new(self.tick()),
        });
        // account the bytes BEFORE the model becomes visible in its shard:
        // a concurrent enforce_budget may evict it the moment it appears,
        // and its fetch_sub must never run ahead of our fetch_add (a u64
        // underflow here would read as an enormous resident total and
        // mass-evict the store)
        self.resident.fetch_add(bytes, Ordering::Relaxed);
        let old = self
            .shard(name)
            .models
            .write()
            .unwrap()
            .insert(name.to_string(), Tier::Resident(model));
        self.retire_replaced(old);
        self.enforce_budget(name);
        Ok(())
    }

    /// Release a replaced tier entry's resources: bytes accounting, decoded
    /// plans, spill file. A pack archive is never touched — it may back any
    /// number of other members (and is durable, unlike spill files).
    fn retire_replaced(&self, old: Option<Tier>) {
        match old {
            Some(Tier::Resident(old)) => {
                self.resident.fetch_sub(old.compressed_bytes, Ordering::Relaxed);
                // the replaced parse's plans can never be served again
                self.plans.purge_model(old.predictor.model_id());
            }
            Some(Tier::Spilled(e)) => {
                // replacing a spilled model retires its spill file (its
                // plans were already purged at spill time)
                self.spilled.fetch_sub(e.bytes, Ordering::Relaxed);
                let _ = std::fs::remove_file(&e.path);
            }
            Some(Tier::Packed(e)) => {
                self.packed.fetch_sub(e.bytes, Ordering::Relaxed);
            }
            None => {}
        }
    }

    /// Load a container file from disk.
    pub fn insert_from_file(&self, name: &str, path: &std::path::Path) -> Result<()> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        let cf = CompressedForest::from_bytes(bytes)?;
        self.insert(name, &cf)
    }

    /// Mount every member of a pack archive as a model of this store, named
    /// by its member key (replacing same-named models). Members start in
    /// the **Packed** tier — nothing is parsed and no RAM budget is spent
    /// until the first request loads a member ([`StoreStats::pack_loads`]);
    /// budget evictions of loaded members *release* them back here instead
    /// of spilling ([`StoreStats::pack_releases`]). Returns the number of
    /// members attached.
    pub fn attach_pack(&self, pack: &Arc<PackArchive>) -> Result<usize> {
        // refuse up front any member that could never be loaded, like
        // insert() does for oversized models — attach is the admin surface
        if let Some(budget) = self.max_resident_bytes {
            for i in 0..pack.member_count() {
                let bytes = pack.member_logical_bytes(i);
                if bytes > budget {
                    bail!(
                        "pack member {:?} ({bytes} container bytes) exceeds the store \
                         budget ({budget} bytes) on its own",
                        pack.key(i)
                    );
                }
            }
        }
        for i in 0..pack.member_count() {
            let name = pack.key(i).to_string();
            let bytes = pack.member_logical_bytes(i);
            let entry = Tier::Packed(PackedEntry {
                pack: pack.clone(),
                member: i,
                bytes,
                last_used: self.tick(),
            });
            self.packed.fetch_add(bytes, Ordering::Relaxed);
            let old = self.shard(&name).models.write().unwrap().insert(name, entry);
            self.retire_replaced(old);
        }
        Ok(pack.member_count())
    }

    /// Builder: generation-count threshold past which a mounted chain is
    /// compacted store-side (checked at attach and by
    /// [`Self::compact_chains`]).
    pub fn compact_generations(mut self, n: usize) -> Self {
        self.compact_generations = n.max(2);
        self
    }

    /// Builder: tombstone-ratio threshold for store-side compaction
    /// (tombstones as a fraction of live + tombstoned entries).
    pub fn compact_tombstone_ratio(mut self, r: f64) -> Self {
        self.compact_tombstone_ratio = r.clamp(0.0, 1.0);
        self
    }

    /// Mount a pack **generation chain** ([`crate::pack::PackChain`]): every
    /// live member (newest-first resolution — a delta entry shadows the
    /// base, a tombstone hides a key) becomes a Packed-tier model served
    /// zero-copy off whichever generation's mapping holds it. The chain is
    /// retained for store-side compaction; the returned handle lets an
    /// admin surface append/remove against the mounted chain (remount with
    /// another `attach_chain` after mutating). If the chain arrives at or
    /// past the compaction thresholds it is compacted immediately. Returns
    /// the chain handle and the number of members mounted.
    pub fn attach_chain(
        &self,
        chain: PackChain,
    ) -> Result<(Arc<Mutex<PackChain>>, usize)> {
        let mounted = self.mount_chain_members(&chain)?;
        let handle = Arc::new(Mutex::new(chain));
        self.chains.lock().unwrap().push(handle.clone());
        self.compact_chains(false)?;
        Ok((handle, mounted))
    }

    /// Insert a Packed-tier entry for every live chain member, pointing at
    /// the generation archive that currently serves it.
    fn mount_chain_members(&self, chain: &PackChain) -> Result<usize> {
        // same up-front refusal as attach_pack: no member may be
        // unloadable under the budget
        if let Some(budget) = self.max_resident_bytes {
            for key in chain.live_keys() {
                let (pack, m) = chain.resolve(key).expect("live key resolves");
                let bytes = pack.member_logical_bytes(m);
                if bytes > budget {
                    bail!(
                        "chain member {key:?} ({bytes} container bytes) exceeds the \
                         store budget ({budget} bytes) on its own"
                    );
                }
            }
        }
        let mut mounted = 0;
        for key in chain.live_keys() {
            let (pack, m) = chain.resolve(key).expect("live key resolves");
            let bytes = pack.member_logical_bytes(m);
            let entry = Tier::Packed(PackedEntry {
                pack: pack.clone(),
                member: m,
                bytes,
                last_used: self.tick(),
            });
            self.packed.fetch_add(bytes, Ordering::Relaxed);
            let old = self.shard(key).models.write().unwrap().insert(key.to_string(), entry);
            self.retire_replaced(old);
            mounted += 1;
        }
        Ok(mounted)
    }

    /// Whether a chain is past a store-side compaction trigger.
    fn chain_needs_compaction(&self, chain: &PackChain) -> bool {
        if chain.generation_count() >= self.compact_generations {
            return true;
        }
        let tombstones = chain.tombstone_count();
        if tombstones == 0 {
            return false;
        }
        let entries = chain.live_len() as f64 + tombstones as f64;
        tombstones as f64 / entries >= self.compact_tombstone_ratio
    }

    /// Compact mounted chains: every chain past a trigger (or every chain
    /// with anything to merge, when `force` is set) is merged into a single
    /// fresh base generation — byte-level, so each member's container stays
    /// **bit-identical** — and its manifest atomically swapped. The live
    /// members are remounted onto the new base; a request that raced the
    /// swap either keeps serving off the old generation's `Arc`-held
    /// mapping or retries its load against the new entry
    /// ([`Self::load_packed`]) — never an error, and never an eviction
    /// (replacement accounting, not [`StoreStats::evictions`]). The merge
    /// is span-timed under [`Phase::Compact`] (`phase_compact_us`).
    /// Returns the number of chains compacted.
    pub fn compact_chains(&self, force: bool) -> Result<usize> {
        let handles: Vec<Arc<Mutex<PackChain>>> =
            self.chains.lock().unwrap().iter().cloned().collect();
        let mut compacted = 0;
        for handle in handles {
            let mut chain = handle.lock().unwrap();
            let mergeable = chain.generation_count() > 1 || chain.tombstone_count() > 0;
            if !mergeable || !(force || self.chain_needs_compaction(&chain)) {
                continue;
            }
            let mut span = Span::begin("pack-chain");
            span.time(Phase::Compact, || compact_chain(&mut chain, CompactMode::Merge))?;
            // remount while still holding the chain lock: the manifest on
            // disk and the mounted tier entries move together
            self.mount_chain_members(&chain)?;
            drop(chain);
            span.finish();
            self.obs.observe(&span);
            self.stats.lock().unwrap().compactions += 1;
            compacted += 1;
        }
        Ok(compacted)
    }

    /// Sum of generation and tombstone counts across mounted chains (the
    /// `pack_generations`/`tombstones` gauges).
    fn chain_gauges(&self) -> (u64, u64) {
        let handles: Vec<Arc<Mutex<PackChain>>> =
            self.chains.lock().unwrap().iter().cloned().collect();
        let mut gens = 0u64;
        let mut tombs = 0u64;
        for handle in handles {
            let chain = handle.lock().unwrap();
            gens += chain.generation_count() as u64;
            tombs += chain.tombstone_count();
        }
        (gens, tombs)
    }

    /// Enforce `max_resident_bytes` over compressed bytes **plus** decoded
    /// plan bytes, in the documented order: plans are dropped first (they
    /// rebuild on demand); then least-recently-used RAM models (never
    /// `keep`) spill to disk when a spill directory is configured, or are
    /// evicted outright when not; spilling past the spill budget deletes
    /// the coldest spill files (those models are gone).
    fn enforce_budget(&self, keep: &str) {
        self.enforce_budget_gated(keep, false);
    }

    /// Budget enforcement with the admission gate optionally armed. Get-path
    /// loads (reload, pack load) pass `gated = true`: under
    /// [`AdmissionPolicy::TinyLfu`], before the LRU victim is demoted its
    /// estimated frequency is compared against `keep`'s — if the victim is
    /// strictly hotter, `keep` *itself* is demoted instead
    /// ([`StoreStats::admission_rejects`]), so one cold scan request cannot
    /// displace the hot working set. The comparison runs at most once per
    /// enforcement (the caller's `Arc` still answers the request that
    /// triggered the load — serve-then-demote, never a failed request).
    /// Admin inserts and explicit prefetch warm-ups pass `gated = false`.
    fn enforce_budget_gated(&self, keep: &str, gated: bool) {
        let Some(budget) = self.max_resident_bytes else { return };
        // cap the plan cache to whatever the budget leaves after the
        // compressed residents; this also evicts plans already past the cap
        self.plans
            .set_max_bytes(budget.saturating_sub(self.resident.load(Ordering::Relaxed)));
        let mut keep_judged = false;
        while self.resident.load(Ordering::Relaxed) > budget {
            let Some(name) = self.lru_resident_victim(keep) else { break };
            // snapshot the victim: every destructive action below verifies
            // it still acts on THIS model, so losing a race to a concurrent
            // release/spill/replace of the same name can only make us
            // rescan — never delete a successor entry (in particular, a
            // pack member another thread just released must not fall
            // through to an eviction)
            let victim = {
                let models = self.shard(&name).models.read().unwrap();
                match models.get(&name) {
                    Some(Tier::Resident(m)) => m.clone(),
                    // raced away already; that freed bytes — rescan
                    _ => continue,
                }
            };
            if gated && !keep_judged {
                keep_judged = true;
                if self.reject_candidate(keep, &name) {
                    self.stats.lock().unwrap().admission_rejects += 1;
                    let candidate = {
                        let models = self.shard(keep).models.read().unwrap();
                        match models.get(keep) {
                            Some(Tier::Resident(m)) => Some(m.clone()),
                            _ => None,
                        }
                    };
                    if let Some(c) = candidate {
                        self.demote(keep, &c);
                    }
                    continue;
                }
            }
            self.demote(&name, &victim);
        }
        // spills/evictions freed compressed bytes: let plans grow back into
        // the slack
        self.plans
            .set_max_bytes(budget.saturating_sub(self.resident.load(Ordering::Relaxed)));
    }

    /// The TinyLFU admission rule: reject `candidate` iff the chosen LRU
    /// `victim` has a **strictly** higher estimated frequency. Ties admit
    /// the candidate, so an empty sketch (or the `lru` policy, which has no
    /// sketch at all) degrades to plain LRU.
    fn reject_candidate(&self, candidate: &str, victim: &str) -> bool {
        let Some(sketch) = &self.sketch else { return false };
        let sk = sketch.lock().unwrap();
        sk.estimate(sketch_hash(victim)) > sk.estimate(sketch_hash(candidate))
    }

    /// Demote one RAM-resident model (`model` is the caller's Arc-identity
    /// snapshot) along the documented tier order: a pack member releases to
    /// its archive, a direct model spills when the disk tier is armed
    /// (falling back to eviction if the disk refuses), anything else is
    /// evicted outright. Losing a race at any step just means another
    /// thread already freed the bytes.
    fn demote(&self, name: &str, model: &Arc<StoredModel>) {
        if matches!(model.origin, ModelOrigin::Packed { .. }) {
            // pack members release back to their archive: free, no disk
            // write, the pack keeps the bytes. A false return means a
            // racing thread beat us to it — either way, the loop rescans.
            self.release(name);
            return;
        }
        if self.spill_dir.is_some() {
            match self.spill(name) {
                // spilled, or raced with a concurrent remove/replace/spill
                // of the same name — that race freed bytes either way
                Ok(_) => return,
                // the disk refused the spill (full, unwritable): fall
                // back to dropping so the RAM budget still holds
                Err(_) => {}
            }
        }
        if self.evict_exact(name, model) {
            self.stats.lock().unwrap().evictions += 1;
        }
    }

    /// Drop `name` only if it is still the exact Resident model chosen as
    /// the eviction victim (`Arc` identity). A concurrent release, spill,
    /// or replace between victim selection and here leaves the successor
    /// entry untouched and reports `false` (the racer already freed bytes).
    fn evict_exact(&self, name: &str, victim: &Arc<StoredModel>) -> bool {
        let removed = {
            let mut models = self.shard(name).models.write().unwrap();
            match models.get(name) {
                Some(Tier::Resident(m)) if Arc::ptr_eq(m, victim) => models.remove(name),
                _ => None,
            }
        };
        match removed {
            Some(Tier::Resident(m)) => {
                self.resident.fetch_sub(m.compressed_bytes, Ordering::Relaxed);
                self.plans.purge_model(m.predictor.model_id());
                true
            }
            _ => false,
        }
    }

    /// Least-recently-used RAM-resident model, excluding `keep`.
    fn lru_resident_victim(&self, keep: &str) -> Option<String> {
        let mut victim: Option<(String, u64)> = None;
        for shard in &self.shards {
            let models = shard.models.read().unwrap();
            for (name, tier) in models.iter() {
                let Tier::Resident(model) = tier else { continue };
                if name == keep {
                    continue;
                }
                let used = model.last_used.load(Ordering::Relaxed);
                if victim.as_ref().map_or(true, |(_, best)| used < *best) {
                    victim = Some((name.clone(), used));
                }
            }
        }
        victim.map(|(name, _)| name)
    }

    /// Least-recently-used model of the disk tier.
    fn lru_spilled_victim(&self) -> Option<String> {
        let mut victim: Option<(String, u64)> = None;
        for shard in &self.shards {
            let models = shard.models.read().unwrap();
            for (name, tier) in models.iter() {
                let Tier::Spilled(e) = tier else { continue };
                if victim.as_ref().map_or(true, |(_, best)| e.last_used < *best) {
                    victim = Some((name.clone(), e.last_used));
                }
            }
        }
        victim.map(|(name, _)| name)
    }

    /// Enforce the spill tier's byte cap: delete the coldest spill files
    /// (Resident → Spilled → **gone**) until the tier fits.
    fn enforce_spill_budget(&self) {
        let Some(cap) = self.max_spill_bytes else { return };
        while self.spilled.load(Ordering::Relaxed) > cap {
            let Some(name) = self.lru_spilled_victim() else { break };
            if self.remove(&name) {
                self.stats.lock().unwrap().evictions += 1;
            }
        }
    }

    /// Spill a RAM-resident model's container bytes to the spill directory
    /// (write-then-rename, so a crash mid-write can never leave a torn file
    /// under a name the reload path would trust) and transition it to the
    /// disk tier. Returns `Ok(false)` if the model is not RAM-resident (or
    /// was removed/replaced while the file was being written). The spilled
    /// parse's decoded plans are purged — they pin a dead `plan_id`; the
    /// reload stamps a fresh one.
    pub fn spill(&self, name: &str) -> Result<bool> {
        let Some(dir) = self.spill_dir.as_ref() else {
            bail!("store has no spill directory (configure ModelStore::spill_dir)");
        };
        // snapshot the model under the read lock; disk I/O runs outside it
        let model = {
            let models = self.shard(name).models.read().unwrap();
            match models.get(name) {
                Some(Tier::Resident(m)) => m.clone(),
                Some(Tier::Spilled(_) | Tier::Packed(_)) | None => return Ok(false),
            }
        };
        // a pack member never spills: its bytes already live in the archive
        // — a spill file would duplicate them. Release instead.
        if matches!(model.origin, ModelOrigin::Packed { .. }) {
            return Ok(self.release(name));
        }
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating spill dir {}", dir.display()))?;
        let seq = self.spill_seq.fetch_add(1, Ordering::Relaxed);
        // pid + store token + sequence: unique across store instances and
        // process restarts sharing one directory, never reused within one
        // store (a leftover file from a crashed run is inert and can never
        // be overwritten by — or mistaken for — a live spill)
        let stem = format!(
            "spill-{pid:x}-{token:x}-{seq:08}.rfcz",
            pid = std::process::id(),
            token = self.spill_token
        );
        let final_path = dir.join(&stem);
        let tmp_path = dir.join(format!("{stem}.tmp"));
        let bytes: &[u8] = model.predictor.container().buffer();
        let write = std::fs::write(&tmp_path, bytes)
            .and_then(|()| std::fs::rename(&tmp_path, &final_path));
        if let Err(e) = write {
            let _ = std::fs::remove_file(&tmp_path);
            return Err(e).with_context(|| format!("spilling {name:?} to {}", final_path.display()));
        }
        let swapped = {
            let mut models = self.shard(name).models.write().unwrap();
            // still the exact model we wrote out (not removed or replaced
            // while the file was in flight)?
            let unchanged = matches!(
                models.get(name),
                Some(Tier::Resident(m)) if Arc::ptr_eq(m, &model)
            );
            if unchanged {
                models.insert(
                    name.to_string(),
                    Tier::Spilled(SpillEntry {
                        path: final_path.clone(),
                        bytes: model.compressed_bytes,
                        last_used: model.last_used.load(Ordering::Relaxed),
                    }),
                );
                // counters move inside the lock: a concurrent reload of this
                // name must never observe the Spilled entry before our
                // fetch_add lands — its fetch_sub would wrap the u64 and
                // read as an enormous spill tier (mass-evicting the disk)
                self.resident.fetch_sub(model.compressed_bytes, Ordering::Relaxed);
                self.spilled.fetch_add(model.compressed_bytes, Ordering::Relaxed);
            }
            unchanged
        };
        if !swapped {
            let _ = std::fs::remove_file(&final_path);
            return Ok(false);
        }
        // a spilled model's plans pin the dead parse's plan_id — drop them
        // now; an in-flight batch still holding the old predictor can be
        // served but can never repopulate the cache under the retired id
        self.plans.purge_model(model.predictor.model_id());
        self.stats.lock().unwrap().spills += 1;
        self.enforce_spill_budget();
        Ok(true)
    }

    /// Release a RAM-resident pack member back to its archive's Packed tier
    /// (`Resident → Packed`). Free: the pack still holds the bytes, so
    /// nothing is written and nothing can fail — which is why the budget
    /// path tries release before spill. Returns `false` when the model is
    /// not resident or did not come from a pack. The released parse's plans
    /// are purged (they pin the dead `plan_id`); the next load stamps a
    /// fresh one, same discipline as spill/reload.
    pub fn release(&self, name: &str) -> bool {
        let model = {
            let models = self.shard(name).models.read().unwrap();
            match models.get(name) {
                Some(Tier::Resident(m)) => m.clone(),
                _ => return false,
            }
        };
        let ModelOrigin::Packed { pack, member } = &model.origin else {
            return false;
        };
        let released = {
            let mut models = self.shard(name).models.write().unwrap();
            // still the exact model we snapshotted (not removed/replaced)?
            let unchanged = matches!(
                models.get(name),
                Some(Tier::Resident(m)) if Arc::ptr_eq(m, &model)
            );
            if unchanged {
                models.insert(
                    name.to_string(),
                    Tier::Packed(PackedEntry {
                        pack: pack.clone(),
                        member: *member,
                        bytes: model.compressed_bytes,
                        last_used: model.last_used.load(Ordering::Relaxed),
                    }),
                );
                // counters move inside the lock (same ordering rule as
                // spill: a racing load must never observe Packed before
                // the fetch_add lands)
                self.resident.fetch_sub(model.compressed_bytes, Ordering::Relaxed);
                self.packed.fetch_add(model.compressed_bytes, Ordering::Relaxed);
            }
            unchanged
        };
        if released {
            self.plans.purge_model(model.predictor.model_id());
            self.stats.lock().unwrap().pack_releases += 1;
        }
        released
    }

    /// Parse a Packed-tier member out of its archive and make it Resident
    /// (`Packed → Resident`). The parse rides the pack's mapping — verbatim
    /// members are fully zero-copy; shared-codebook members decode their
    /// side information from the pack blob. Parse + decoder build run
    /// outside every lock; the winner of a load race installs its model,
    /// losers adopt it (the reload discipline). `gated` arms the TinyLFU
    /// admission comparison in the budget enforcement this load triggers.
    fn load_packed(&self, name: &str, gated: bool) -> Result<Arc<StoredModel>> {
        // a chain compaction can atomically re-point this name's Packed
        // entry at the merged base between snapshot and install; the
        // retry re-snapshots and loads the same key (bit-identical bytes)
        // off the new generation. A genuinely removed name fails in the
        // retry's snapshot with the usual typed error.
        for _ in 0..3 {
            if let Some(model) = self.load_packed_once(name, gated)? {
                return Ok(model);
            }
        }
        bail!("model {name:?} kept changing during pack load");
    }

    /// One attempt of [`Self::load_packed`]: `Ok(None)` means the entry
    /// was swapped (re-attach/compaction) between snapshot and install —
    /// retryable; terminal states error in the snapshot probe.
    fn load_packed_once(&self, name: &str, gated: bool) -> Result<Option<Arc<StoredModel>>> {
        let (pack, member, bytes) = {
            let models = self.shard(name).models.read().unwrap();
            match models.get(name) {
                Some(Tier::Resident(m)) => {
                    m.last_used.store(self.tick(), Ordering::Relaxed);
                    return Ok(Some(m.clone()));
                }
                Some(Tier::Packed(e)) => (e.pack.clone(), e.member, e.bytes),
                // the name was replaced by a different (spilled) model in
                // the instant between dispatch and here — rare admin race;
                // surface it rather than chase the new tier
                Some(Tier::Spilled(_)) => bail!("model {name:?} changed during pack load"),
                None => bail!("unknown model {name:?}"),
            }
        };
        let pc = pack
            .parse_member(member)
            .with_context(|| format!("loading pack member {name:?}"))?;
        let mut predictor = CompressedPredictor::new(pc)?.with_workers(self.predict_workers);
        if self.plan_admit(name) {
            predictor = predictor.with_plan_cache(self.plans.clone());
        }
        let model = Arc::new(StoredModel {
            predictor,
            compressed_bytes: bytes,
            origin: ModelOrigin::Packed { pack: pack.clone(), member },
            last_used: AtomicU64::new(self.tick()),
        });
        enum Outcome {
            Installed,
            LostRace(Arc<StoredModel>),
            Gone,
        }
        let outcome = {
            let mut models = self.shard(name).models.write().unwrap();
            let state = match models.get(name) {
                // still the exact entry we snapshotted — same archive, same
                // member. A same-named entry from a *re-attached* pack must
                // not be overwritten by our (now stale) parse, and its
                // byte count must not be mixed into our accounting.
                Some(Tier::Packed(e)) if Arc::ptr_eq(&e.pack, &pack) && e.member == member => {
                    Outcome::Installed
                }
                // lost a load race: adopt the winner's model
                Some(Tier::Resident(m)) => Outcome::LostRace(m.clone()),
                Some(Tier::Packed(_) | Tier::Spilled(_)) | None => Outcome::Gone,
            };
            if matches!(state, Outcome::Installed) {
                // same ordering rule as insert: account resident bytes
                // before the entry becomes visible as Resident
                self.resident.fetch_add(bytes, Ordering::Relaxed);
                self.packed.fetch_sub(bytes, Ordering::Relaxed);
                models.insert(name.to_string(), Tier::Resident(model.clone()));
            }
            state
        };
        match outcome {
            Outcome::LostRace(m) => return Ok(Some(m)),
            // removed, or replaced by a different entry (a re-attached
            // archive or a chain compaction) mid-load: hand the race back
            // to the caller's retry loop, which re-snapshots the entry
            Outcome::Gone => return Ok(None),
            Outcome::Installed => {}
        }
        self.stats.lock().unwrap().pack_loads += 1;
        // the load grew the RAM tier; it may need to release/spill another
        self.enforce_budget_gated(name, gated);
        Ok(Some(model))
    }

    /// Reload a spilled model through an mmap-backed buffer. The map + parse
    /// + decoder build runs outside every lock; the winner of a reload race
    /// installs its model, losers adopt it. On success the spill file is
    /// unlinked (on unix the mapping keeps its pages alive; the non-unix
    /// fallback copied them). `gated` arms the TinyLFU admission comparison
    /// in the budget enforcement this reload triggers.
    fn reload(&self, name: &str, gated: bool) -> Result<Arc<StoredModel>> {
        let (path, bytes) = {
            let models = self.shard(name).models.read().unwrap();
            match models.get(name) {
                Some(Tier::Resident(m)) => {
                    m.last_used.store(self.tick(), Ordering::Relaxed);
                    return Ok(m.clone());
                }
                Some(Tier::Spilled(e)) => (e.path.clone(), e.bytes),
                // replaced by a pack attach mid-request — rare admin race
                Some(Tier::Packed(_)) => bail!("model {name:?} changed during reload"),
                None => bail!("unknown model {name:?}"),
            }
        };
        let map = match Mmap::map_path(&path) {
            Ok(m) => m,
            Err(e) => {
                // a racing reload may have won and already unlinked the file
                if let Some(Tier::Resident(m)) =
                    self.shard(name).models.read().unwrap().get(name)
                {
                    return Ok(m.clone());
                }
                return Err(e.context(format!("reloading spilled model {name:?}")));
            }
        };
        if map.len() as u64 != bytes {
            bail!(
                "spill file {} is {} bytes, expected {bytes} (truncated or corrupted)",
                path.display(),
                map.len()
            );
        }
        let pc = parse_arc(map)
            .with_context(|| format!("parsing spill file {} of model {name:?}", path.display()))?;
        let mut predictor = CompressedPredictor::new(pc)?.with_workers(self.predict_workers);
        if self.plan_admit(name) {
            predictor = predictor.with_plan_cache(self.plans.clone());
        }
        let model = Arc::new(StoredModel {
            predictor,
            compressed_bytes: bytes,
            origin: ModelOrigin::Direct,
            last_used: AtomicU64::new(self.tick()),
        });
        enum Outcome {
            Installed,
            LostRace(Arc<StoredModel>),
            Removed,
        }
        let outcome = {
            let mut models = self.shard(name).models.write().unwrap();
            let state = match models.get(name) {
                Some(Tier::Spilled(_)) => Outcome::Installed,
                // lost a reload race: adopt the winner's model
                Some(Tier::Resident(m)) => Outcome::LostRace(m.clone()),
                Some(Tier::Packed(_)) | None => Outcome::Removed,
            };
            if matches!(state, Outcome::Installed) {
                // same ordering rule as insert: account resident bytes
                // before the entry becomes visible as Resident
                self.resident.fetch_add(bytes, Ordering::Relaxed);
                self.spilled.fetch_sub(bytes, Ordering::Relaxed);
                models.insert(name.to_string(), Tier::Resident(model.clone()));
            }
            state
        };
        match outcome {
            Outcome::LostRace(m) => return Ok(m),
            Outcome::Removed => bail!("model {name:?} was removed during reload"),
            Outcome::Installed => {}
        }
        {
            let _ = std::fs::remove_file(&path);
            self.stats.lock().unwrap().reloads += 1;
            // the reload grew the RAM tier; it may need to spill someone else
            self.enforce_budget_gated(name, gated);
        }
        Ok(model)
    }

    /// Remove a model from whichever tier holds it (deleting its spill
    /// file; a backing pack archive is never touched). Returns whether the
    /// name was present.
    pub fn remove(&self, name: &str) -> bool {
        let removed = self.shard(name).models.write().unwrap().remove(name);
        match removed {
            Some(Tier::Resident(m)) => {
                self.resident.fetch_sub(m.compressed_bytes, Ordering::Relaxed);
                self.plans.purge_model(m.predictor.model_id());
                true
            }
            Some(Tier::Spilled(e)) => {
                self.spilled.fetch_sub(e.bytes, Ordering::Relaxed);
                let _ = std::fs::remove_file(&e.path);
                true
            }
            // the member leaves the store; the archive (shared, durable)
            // stays on disk untouched
            Some(Tier::Packed(e)) => {
                self.packed.fetch_sub(e.bytes, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// Whether any tier currently owns a model of this name.
    pub fn contains(&self, name: &str) -> bool {
        self.shard(name).models.read().unwrap().contains_key(name)
    }

    /// Whether a model currently sits in the disk tier.
    pub fn is_spilled(&self, name: &str) -> bool {
        matches!(
            self.shard(name).models.read().unwrap().get(name),
            Some(Tier::Spilled(_))
        )
    }

    /// Whether a model currently sits unloaded in the Packed tier (a loaded
    /// pack member is Resident and reports `false` here, mirroring
    /// [`Self::is_spilled`]).
    pub fn is_packed(&self, name: &str) -> bool {
        matches!(
            self.shard(name).models.read().unwrap().get(name),
            Some(Tier::Packed(_))
        )
    }

    /// Model names across both tiers, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut out: Vec<String> = self
            .shards
            .iter()
            .flat_map(|s| s.models.read().unwrap().keys().cloned().collect::<Vec<_>>())
            .collect();
        out.sort();
        out
    }

    /// Number of models owned, across every tier.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.models.read().unwrap().len()).sum()
    }

    /// Whether the store owns no models at all.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of models currently in the disk tier.
    pub fn spilled_len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                s.models
                    .read()
                    .unwrap()
                    .values()
                    .filter(|t| matches!(t, Tier::Spilled(_)))
                    .count()
            })
            .sum()
    }

    /// Number of members currently unloaded in the Packed tier.
    pub fn packed_len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                s.models
                    .read()
                    .unwrap()
                    .values()
                    .filter(|t| matches!(t, Tier::Packed(_)))
                    .count()
            })
            .sum()
    }

    /// Total compressed bytes RAM-resident (the "storage budget" figure;
    /// decoded plan bytes are reported separately by [`Self::plan_bytes`],
    /// disk-tier bytes by [`Self::spilled_bytes`]).
    pub fn resident_bytes(&self) -> u64 {
        self.resident.load(Ordering::Relaxed)
    }

    /// Container bytes currently parked in the spill directory.
    pub fn spilled_bytes(&self) -> u64 {
        self.spilled.load(Ordering::Relaxed)
    }

    /// Logical container bytes of unloaded Packed-tier members (what they
    /// would cost Resident; the archive's bytes on disk are shared and
    /// counted once per pack, not per member).
    pub fn packed_bytes(&self) -> u64 {
        self.packed.load(Ordering::Relaxed)
    }

    /// Decoded flat-plan bytes currently resident.
    pub fn plan_bytes(&self) -> u64 {
        self.plans.resident_bytes()
    }

    /// The shared flat-plan cache (counters, budget introspection).
    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.plans
    }

    /// Snapshot of the serving counters (the `STATS` verb's source).
    pub fn stats(&self) -> StoreStats {
        let mut s = *self.stats.lock().unwrap();
        let p = self.plans.stats();
        s.plan_hits = p.hits;
        s.plan_misses = p.misses;
        s.plan_bytes = p.resident_bytes;
        s.spill_bytes = self.spilled.load(Ordering::Relaxed);
        s.packed_bytes = self.packed.load(Ordering::Relaxed);
        s.inflight = self.inflight.load(Ordering::Relaxed);
        let (gens, tombs) = self.chain_gauges();
        s.pack_generations = gens;
        s.tombstones = tombs;
        s.p50_latency_us = self.obs.request_us().quantile(0.50);
        s.p99_latency_us = self.obs.request_us().quantile(0.99);
        s
    }

    /// A pipelined request was admitted: grow the in-flight gauge.
    pub fn note_pipe_dispatched(&self) {
        self.inflight.fetch_add(1, Ordering::Relaxed);
    }

    /// A pipelined request left flight (answered or timed out): shrink the
    /// in-flight gauge. Callers pair this 1:1 with
    /// [`Self::note_pipe_dispatched`] — the saturating sub only guards a
    /// misuse from reading as an enormous gauge.
    pub fn note_pipe_retired(&self) {
        let _ = self.inflight.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_sub(1))
        });
    }

    /// A pipelined request was refused with `ERR busy` (connection at its
    /// in-flight cap).
    pub fn note_rejected_busy(&self) {
        self.stats.lock().unwrap().rejected_busy += 1;
    }

    /// A request outlived the configured timeout and was answered with a
    /// typed `ERR timeout` line.
    pub fn note_request_timeout(&self) {
        self.stats.lock().unwrap().timeouts += 1;
    }

    /// Look a model up and stamp its LRU clock. RAM-resident models come
    /// back from a read-locked map probe; spilled models are reloaded
    /// through the mmap path ([`Self::reload`]); unloaded pack members are
    /// parsed out of their archive ([`Self::load_packed`]).
    fn get(&self, name: &str) -> Result<Arc<StoredModel>> {
        self.get_gated(name, true)
    }

    /// [`Self::get`] with explicit gating: request-path lookups
    /// (`gated = true`) feed the frequency sketch and run TinyLFU-gated
    /// budget enforcement; warm-up lookups ([`Self::warm`]) bypass both —
    /// an operator prefetch is an explicit residency hint, not a data point
    /// to second-guess.
    fn get_gated(&self, name: &str, gated: bool) -> Result<Arc<StoredModel>> {
        if gated {
            self.touch_sketch(name);
        }
        let packed = {
            let models = self.shard(name).models.read().unwrap();
            match models.get(name) {
                Some(Tier::Resident(m)) => {
                    m.last_used.store(self.tick(), Ordering::Relaxed);
                    return Ok(m.clone());
                }
                Some(Tier::Spilled(_)) => false,
                Some(Tier::Packed(_)) => true,
                None => bail!("unknown model {name:?}"),
            }
        };
        if packed {
            self.load_packed(name, gated)
        } else {
            self.reload(name, gated)
        }
    }

    /// Record one request for `name` in the frequency sketch (no-op under
    /// the `lru` policy).
    fn touch_sketch(&self, name: &str) {
        if let Some(sketch) = &self.sketch {
            sketch.lock().unwrap().touch(sketch_hash(name));
        }
    }

    /// Plan-cache admission for a load of `name`: under TinyLFU, a cold
    /// model (estimated frequency < 2 — i.e. never seen before the touch
    /// that triggered this very load) builds its predictor **without** the
    /// shared [`PlanCache`] attached, so a one-pass scan cannot churn the
    /// hot set's decoded plans either. Its plans become cacheable on the
    /// next (re)load, by which point the sketch has history. Always true
    /// under `lru`.
    fn plan_admit(&self, name: &str) -> bool {
        match &self.sketch {
            None => true,
            Some(sketch) => sketch.lock().unwrap().estimate(sketch_hash(name)) >= 2,
        }
    }

    /// Note a `PREFETCH` request and report whether a background warm-up is
    /// worth spawning: `Ok(true)` for a Spilled/Packed model (counted in
    /// [`StoreStats::prefetches`]), `Ok(false)` for an already-Resident one
    /// (its LRU clock is stamped; nothing to do). The touch also feeds the
    /// frequency sketch — a prefetch is a statement of intent. Errors only
    /// for unknown names.
    pub fn prefetch_needed(&self, name: &str) -> Result<bool> {
        self.touch_sketch(name);
        let cold = {
            let models = self.shard(name).models.read().unwrap();
            match models.get(name) {
                Some(Tier::Resident(m)) => {
                    m.last_used.store(self.tick(), Ordering::Relaxed);
                    false
                }
                Some(Tier::Spilled(_) | Tier::Packed(_)) => true,
                None => bail!("unknown model {name:?}"),
            }
        };
        if cold {
            self.stats.lock().unwrap().prefetches += 1;
        }
        Ok(cold)
    }

    /// Synchronously warm a model into the resident tier, bypassing the
    /// admission gate (an explicit prefetch must not be second-guessed by
    /// the sketch it is trying to pre-seed). The server runs this on a
    /// background thread after acknowledging the `PREFETCH`.
    pub fn warm(&self, name: &str) -> Result<()> {
        self.get_gated(name, false).map(|_| ())
    }

    /// Predict a single observation against a named model. The shard lock
    /// covers only the name lookup; decoding runs lock-free on the shared
    /// buffer.
    pub fn predict(&self, model: &str, values: &[ObsValue]) -> Result<PredictOne> {
        let start = std::time::Instant::now();
        let stored = self.get(model)?;
        let ds = row_dataset(&stored.predictor, values, 1)?;
        let out = stored.predictor.predict_row(&ds, 0)?;
        self.record(start.elapsed().as_micros() as u64, 1, 1);
        Ok(out)
    }

    /// Predict a batch of observations (the micro-batcher's path: one
    /// schema check + per-tree decode amortized over the batch, sharded
    /// across the predictor's worker threads).
    pub fn predict_batch(&self, model: &str, rows: &[Vec<ObsValue>]) -> Result<Vec<PredictOne>> {
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        let start = std::time::Instant::now();
        let stored = self.get(model)?;
        let out = execute_rows(&stored, rows)?;
        self.record(start.elapsed().as_micros() as u64, rows.len() as u64, 1);
        Ok(out)
    }

    /// [`Self::predict_batch`] with phase attribution: the lookup's cost
    /// lands in `trace.reload_us` or `trace.pack_load_us` according to the
    /// tier the model occupied when the call started (a warm model charges
    /// neither), traversal in `trace.execute_us`, and plan-cache traffic
    /// as a before/after delta of the shared cache counters (approximate
    /// under concurrency — see [`BatchTrace`]). Same outputs and `STATS`
    /// accounting as the untraced path.
    pub fn predict_batch_traced(
        &self,
        model: &str,
        rows: &[Vec<ObsValue>],
        trace: &mut BatchTrace,
    ) -> Result<Vec<PredictOne>> {
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        let start = std::time::Instant::now();
        let spilled = self.is_spilled(model);
        let packed = !spilled && self.is_packed(model);
        let (h0, m0) = self.plans.counts();
        let b0 = self.plans.build_us();
        let stored = self.get(model)?;
        let get_us = start.elapsed().as_micros() as u64;
        if spilled {
            trace.reload_us += get_us;
        } else if packed {
            trace.pack_load_us += get_us;
        }
        let t_exec = std::time::Instant::now();
        let out = execute_rows(&stored, rows)?;
        trace.execute_us += t_exec.elapsed().as_micros() as u64;
        let (h1, m1) = self.plans.counts();
        trace.plan_hits += h1.saturating_sub(h0);
        trace.plan_misses += m1.saturating_sub(m0);
        trace.plan_us += self.plans.build_us().saturating_sub(b0);
        self.record(start.elapsed().as_micros() as u64, rows.len() as u64, 1);
        Ok(out)
    }

    /// [`Self::predict`] with phase attribution — one row through the
    /// traced batch path.
    pub fn predict_traced(
        &self,
        model: &str,
        values: &[ObsValue],
        trace: &mut BatchTrace,
    ) -> Result<PredictOne> {
        let rows = [values.to_vec()];
        let mut out = self.predict_batch_traced(model, &rows, trace)?;
        Ok(out.pop().expect("one row in, one prediction out"))
    }

    /// Per-request latency accounting: `us` is the wall time every one of
    /// the `requests` in this batch waited, so it is charged once per
    /// request (see [`StoreStats`]). The same per-request charge feeds the
    /// live `request_latency_us` histogram behind `p50_us`/`p99_us` and
    /// the `METRICS` exposition.
    fn record(&self, us: u64, requests: u64, batches: u64) {
        self.obs.record_latency(us, requests);
        let mut s = self.stats.lock().unwrap();
        s.requests += requests;
        s.batches += batches;
        s.total_latency_us += us * requests;
        s.max_latency_us = s.max_latency_us.max(us);
    }
}

impl Default for ModelStore {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for ModelStore {
    /// Shutdown purge: spill files are cache, never durable state — delete
    /// every disk-tier file this store still owns.
    fn drop(&mut self) {
        for shard in &mut self.shards {
            let models = match shard.models.get_mut() {
                Ok(m) => m,
                Err(poisoned) => poisoned.into_inner(),
            };
            for tier in models.values() {
                if let Tier::Spilled(e) = tier {
                    let _ = std::fs::remove_file(&e.path);
                }
            }
        }
    }
}

/// Shared execute step of the (traced and untraced) batch paths: one
/// schema check, then either the batched per-tree decode (large enough to
/// amortize it) or the per-row prefix decode.
fn execute_rows(stored: &StoredModel, rows: &[Vec<ObsValue>]) -> Result<Vec<PredictOne>> {
    let flat: Vec<ObsValue> = rows.iter().flatten().copied().collect();
    let ds = row_dataset(&stored.predictor, &flat, rows.len())?;
    if rows.len() >= 8 {
        Ok(match stored.predictor.predict_all(&ds)? {
            crate::forest::forest::Predictions::Classes(cs) => {
                cs.into_iter().map(PredictOne::Class).collect()
            }
            crate::forest::forest::Predictions::Values(vs) => {
                vs.into_iter().map(PredictOne::Value).collect()
            }
        })
    } else {
        (0..rows.len())
            .map(|r| stored.predictor.predict_row(&ds, r))
            .collect::<Result<Vec<_>>>()
    }
}

/// Build an n-row dataset from flat observation values using the model's
/// stored feature schema (kinds + level counts from the container header).
fn row_dataset(
    predictor: &CompressedPredictor,
    flat: &[ObsValue],
    n_rows: usize,
) -> Result<Dataset> {
    let metas = &predictor.container().features;
    let d = metas.len();
    if flat.len() != d * n_rows {
        bail!("expected {} values ({} rows × {d} features), got {}", d * n_rows, n_rows, flat.len());
    }
    let mut features = Vec::with_capacity(d);
    for (j, meta) in metas.iter().enumerate() {
        let column = match meta.levels {
            None => {
                let mut v = Vec::with_capacity(n_rows);
                for r in 0..n_rows {
                    match flat[r * d + j] {
                        ObsValue::Num(x) => v.push(x),
                        ObsValue::Cat(_) => {
                            bail!("feature {:?} expects a numeric value", meta.name)
                        }
                    }
                }
                Column::Numeric(v)
            }
            Some(levels) => {
                let mut v = Vec::with_capacity(n_rows);
                for r in 0..n_rows {
                    match flat[r * d + j] {
                        ObsValue::Cat(c) if c < levels => v.push(c),
                        ObsValue::Cat(c) => {
                            bail!("feature {:?}: level {c} out of range (<{levels})", meta.name)
                        }
                        ObsValue::Num(_) => {
                            bail!("feature {:?} expects a categorical level", meta.name)
                        }
                    }
                }
                Column::Categorical { values: v, levels }
            }
        };
        features.push(Feature { name: meta.name.clone(), column });
    }
    // dummy target (prediction never reads it)
    let target = if predictor.container().classification {
        Target::Classification { labels: vec![0; n_rows], classes: predictor.container().classes.max(1) }
    } else {
        Target::Regression(vec![0.0; n_rows])
    };
    Ok(Dataset { name: "query".into(), features, target })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CompressOptions;
    use crate::data::synthetic;
    use crate::forest::{Forest, ForestParams};

    fn iris_model(seed: u64) -> (CompressedForest, Forest, Dataset) {
        let ds = synthetic::iris(81);
        let f = Forest::train(&ds, &ForestParams::classification(5), seed);
        let cf = CompressedForest::compress(&f, &ds, &CompressOptions::default()).unwrap();
        (cf, f, ds)
    }

    fn store_with_iris() -> (ModelStore, Forest, Dataset) {
        let (cf, f, ds) = iris_model(3);
        let store = ModelStore::new();
        store.insert("iris", &cf).unwrap();
        (store, f, ds)
    }

    fn row_values(ds: &Dataset, row: usize) -> Vec<ObsValue> {
        ds.features
            .iter()
            .map(|f| match &f.column {
                Column::Numeric(v) => ObsValue::Num(v[row]),
                Column::Categorical { values, .. } => ObsValue::Cat(values[row]),
            })
            .collect()
    }

    /// Unique spill directory per test (tests run in parallel).
    fn temp_spill_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("rfc-store-spill-{tag}-{}", std::process::id()))
    }

    fn spill_files(dir: &std::path::Path) -> Vec<PathBuf> {
        match std::fs::read_dir(dir) {
            Ok(entries) => entries.map(|e| e.unwrap().path()).collect(),
            Err(_) => Vec::new(),
        }
    }

    #[test]
    fn store_predicts_like_original_forest() {
        let (store, f, ds) = store_with_iris();
        for row in (0..ds.num_rows()).step_by(17) {
            let vals = row_values(&ds, row);
            let got = store.predict("iris", &vals).unwrap();
            assert_eq!(got, PredictOne::Class(f.predict_class(&ds, row)));
        }
        assert!(store.stats().requests > 0);
    }

    #[test]
    fn batch_matches_single() {
        let (store, _, ds) = store_with_iris();
        let rows: Vec<Vec<ObsValue>> = (0..20).map(|r| row_values(&ds, r * 3)).collect();
        let batch = store.predict_batch("iris", &rows).unwrap();
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(batch[i], store.predict("iris", r).unwrap());
        }
        // per-request accounting: a 20-row batch counts 20 requests and the
        // mean stays a per-request figure
        let s = store.stats();
        assert!(s.requests >= 20 + rows.len() as u64);
        assert!(s.mean_latency_us() <= s.max_latency_us);
    }

    #[test]
    fn unknown_model_and_bad_schema_rejected() {
        let (store, _, ds) = store_with_iris();
        let vals = row_values(&ds, 0);
        assert!(store.predict("nope", &vals).is_err());
        assert!(store.predict("iris", &vals[..2]).is_err());
        let mut bad = vals.clone();
        bad[0] = ObsValue::Cat(1);
        assert!(store.predict("iris", &bad).is_err());
    }

    #[test]
    fn multiple_models_and_removal() {
        let (store, _, ds) = store_with_iris();
        let ds2 = synthetic::wages(82);
        let f2 = Forest::train(&ds2, &ForestParams::classification(3), 4);
        let cf2 =
            CompressedForest::compress(&f2, &ds2, &CompressOptions::default()).unwrap();
        store.insert("wages", &cf2).unwrap();
        assert_eq!(store.names(), vec!["iris".to_string(), "wages".to_string()]);
        assert!(store.resident_bytes() > 0);
        let vals = row_values(&ds, 0);
        store.predict("iris", &vals).unwrap();
        assert!(store.remove("iris"));
        assert!(store.predict("iris", &vals).is_err());
        assert_eq!(store.len(), 1);
        assert!(store.contains("wages") && !store.contains("iris"));
    }

    #[test]
    fn shards_spread_names_and_agree_with_flat_view() {
        let (cf, _, _) = iris_model(5);
        let store = ModelStore::with_config(4, None);
        assert_eq!(store.num_shards(), 4);
        for i in 0..12 {
            store.insert(&format!("model-{i}"), &cf).unwrap();
        }
        assert_eq!(store.len(), 12);
        let names = store.names();
        assert_eq!(names.len(), 12);
        assert!(names.windows(2).all(|w| w[0] < w[1]), "names sorted");
        assert_eq!(store.resident_bytes(), 12 * cf.total_bytes());
    }

    #[test]
    fn lru_eviction_respects_budget_and_recency() {
        let (cf, _, ds) = iris_model(6);
        let one = cf.total_bytes();
        // room for exactly three models
        let store = ModelStore::with_budget(3 * one + one / 2);
        store.insert("a", &cf).unwrap();
        store.insert("b", &cf).unwrap();
        store.insert("c", &cf).unwrap();
        assert_eq!(store.len(), 3);
        // touch "a" so "b" is now the LRU
        store.predict("a", &row_values(&ds, 0)).unwrap();
        store.insert("d", &cf).unwrap();
        assert_eq!(store.len(), 3, "budget holds three models");
        assert!(store.resident_bytes() <= store.max_resident_bytes().unwrap());
        assert_eq!(store.names(), vec!["a".to_string(), "c".to_string(), "d".to_string()]);
        assert_eq!(store.stats().evictions, 1);
        // an over-budget single model is refused outright
        let tiny = ModelStore::with_budget(one / 2);
        assert!(tiny.insert("too-big", &cf).is_err());
    }

    #[test]
    fn reinsert_same_name_replaces_without_double_counting() {
        let (cf, _, _) = iris_model(7);
        let store = ModelStore::new();
        store.insert("m", &cf).unwrap();
        store.insert("m", &cf).unwrap();
        assert_eq!(store.len(), 1);
        assert_eq!(store.resident_bytes(), cf.total_bytes());
    }

    #[test]
    fn warm_batches_hit_the_plan_cache() {
        let (store, f, ds) = store_with_iris();
        let rows: Vec<Vec<ObsValue>> = (0..20).map(|r| row_values(&ds, r * 3)).collect();
        let cold = store.predict_batch("iris", &rows).unwrap();
        let s = store.stats();
        assert_eq!(s.plan_misses, 5, "first batch decodes each of the 5 trees once");
        assert_eq!(s.plan_hits, 0);
        assert!(s.plan_bytes > 0, "plans stay resident for the next batch");
        let warm = store.predict_batch("iris", &rows).unwrap();
        assert_eq!(warm, cold);
        let s = store.stats();
        assert_eq!(s.plan_misses, 5, "warm batch decodes nothing");
        assert_eq!(s.plan_hits, 5);
        for (i, out) in cold.iter().enumerate() {
            assert_eq!(*out, PredictOne::Class(f.predict_class(&ds, i * 3)));
        }
    }

    #[test]
    fn removal_and_replacement_purge_plans() {
        let (store, _, ds) = store_with_iris();
        let rows: Vec<Vec<ObsValue>> = (0..16).map(|r| row_values(&ds, r)).collect();
        store.predict_batch("iris", &rows).unwrap();
        assert!(store.plan_bytes() > 0);
        // replacing the model orphans the old parse's plans: they are purged
        let (cf, _, _) = iris_model(12);
        store.insert("iris", &cf).unwrap();
        assert_eq!(store.plan_bytes(), 0, "replaced model's plans purged");
        store.predict_batch("iris", &rows).unwrap();
        assert!(store.plan_bytes() > 0);
        assert!(store.remove("iris"));
        assert_eq!(store.plan_bytes(), 0, "removed model's plans purged");
    }

    #[test]
    fn budget_drops_plans_before_models() {
        let (cf, f, ds) = iris_model(6);
        let one = cf.total_bytes();
        let store = ModelStore::with_budget(2 * one + one / 2);
        store.insert("a", &cf).unwrap();
        store.insert("b", &cf).unwrap();
        // plans may only use the budget slack left by the compressed bytes
        assert_eq!(store.plan_cache().max_bytes(), one / 2);
        let rows: Vec<Vec<ObsValue>> = (0..16).map(|r| row_values(&ds, r)).collect();
        store.predict_batch("a", &rows).unwrap();
        assert!(store.plan_bytes() <= one / 2);
        // a third insert exceeds the budget: every plan goes first, then
        // exactly one model
        store.insert("c", &cf).unwrap();
        assert_eq!(store.plan_bytes(), 0, "plans are the first eviction victims");
        assert_eq!(store.len(), 2);
        assert_eq!(store.stats().evictions, 1);
        assert!(store.resident_bytes() <= store.max_resident_bytes().unwrap());
        // serving still works (plans rebuild on demand)
        let out = store.predict_batch("c", &rows).unwrap();
        assert_eq!(out[0], PredictOne::Class(f.predict_class(&ds, 0)));
    }

    // ------------------------------------------------------ spill tier

    #[test]
    fn spill_and_reload_round_trip_is_lossless() {
        let dir = temp_spill_dir("roundtrip");
        let _ = std::fs::remove_dir_all(&dir);
        let (cf, f, ds) = iris_model(13);
        let one = cf.total_bytes();
        let store = ModelStore::with_budget(2 * one).spill_dir(&dir);
        store.insert("m", &cf).unwrap();
        let rows: Vec<Vec<ObsValue>> = (0..20).map(|r| row_values(&ds, r * 2)).collect();
        let before = store.predict_batch("m", &rows).unwrap();

        assert!(store.spill("m").unwrap());
        assert!(store.is_spilled("m"));
        assert!(store.contains("m"), "spilled models are still owned");
        assert_eq!(store.resident_bytes(), 0);
        assert_eq!(store.spilled_bytes(), one);
        assert_eq!(store.spilled_len(), 1);
        assert_eq!(spill_files(&dir).len(), 1, "one spill file on disk");
        assert!(!store.spill("m").unwrap(), "already spilled: no-op");

        // the next request reloads through the mmap path, bit-identical
        let after = store.predict_batch("m", &rows).unwrap();
        assert_eq!(after, before);
        assert!(!store.is_spilled("m"));
        assert_eq!(store.resident_bytes(), one);
        assert_eq!(store.spilled_bytes(), 0);
        assert_eq!(spill_files(&dir).len(), 0, "reload unlinks the spill file");
        let s = store.stats();
        assert_eq!((s.spills, s.reloads), (1, 1));
        // the reloaded predictor rides the mapping, not a heap copy
        #[cfg(all(unix, target_pointer_width = "64"))]
        assert!(store.get("m").unwrap().predictor.container().buffer().is_mapped());
        for (i, out) in after.iter().enumerate() {
            assert_eq!(*out, PredictOne::Class(f.predict_class(&ds, i * 2)));
        }
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn budget_eviction_spills_instead_of_dropping() {
        let dir = temp_spill_dir("evict");
        let _ = std::fs::remove_dir_all(&dir);
        let (cf, f, ds) = iris_model(14);
        let one = cf.total_bytes();
        let store = ModelStore::with_budget(2 * one + one / 2).spill_dir(&dir);
        store.insert("a", &cf).unwrap();
        store.insert("b", &cf).unwrap();
        store.predict("a", &row_values(&ds, 0)).unwrap(); // "b" is now LRU
        store.insert("c", &cf).unwrap();
        assert_eq!(store.len(), 3, "no model was lost");
        assert!(store.is_spilled("b"), "the LRU model moved to disk");
        assert!(!store.is_spilled("a") && !store.is_spilled("c"));
        let s = store.stats();
        assert_eq!(s.spills, 1);
        assert_eq!(s.evictions, 0, "a spill is not an eviction");
        assert!(store.resident_bytes() <= store.max_resident_bytes().unwrap());
        // serving the spilled model reloads it — and the RAM budget holds by
        // spilling the (then) coldest resident
        let out = store.predict("b", &row_values(&ds, 3)).unwrap();
        assert_eq!(out, PredictOne::Class(f.predict_class(&ds, 3)));
        assert!(!store.is_spilled("b"));
        assert!(store.resident_bytes() <= store.max_resident_bytes().unwrap());
        assert_eq!(store.stats().reloads, 1);
        assert_eq!(store.len(), 3);
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spill_budget_lru_deletes_the_coldest_for_good() {
        let dir = temp_spill_dir("spillbudget");
        let _ = std::fs::remove_dir_all(&dir);
        let (cf, _, _) = iris_model(15);
        let one = cf.total_bytes();
        // disk holds exactly one spilled model
        let store = ModelStore::new().spill_dir(&dir).spill_bytes(one + one / 2);
        store.insert("a", &cf).unwrap();
        store.insert("b", &cf).unwrap();
        assert!(store.spill("a").unwrap());
        assert!(store.spill("b").unwrap());
        // "a" (coldest spill) was deleted to fit "b": Resident → Spilled → gone
        assert!(!store.contains("a"), "spill-tier LRU victim leaves the store");
        assert!(store.is_spilled("b"));
        assert_eq!(store.spilled_bytes(), one);
        assert_eq!(spill_files(&dir).len(), 1);
        let s = store.stats();
        assert_eq!(s.spills, 2);
        assert_eq!(s.evictions, 1, "a spill-tier deletion is a true eviction");
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spill_files_purged_on_remove_replace_and_drop() {
        let dir = temp_spill_dir("purge");
        let _ = std::fs::remove_dir_all(&dir);
        let (cf, _, _) = iris_model(16);
        let store = ModelStore::new().spill_dir(&dir);
        store.insert("a", &cf).unwrap();
        store.insert("b", &cf).unwrap();
        store.insert("c", &cf).unwrap();
        assert!(store.spill("a").unwrap());
        assert!(store.spill("b").unwrap());
        assert!(store.spill("c").unwrap());
        assert_eq!(spill_files(&dir).len(), 3);
        // remove deletes the file
        assert!(store.remove("a"));
        assert_eq!(spill_files(&dir).len(), 2);
        // replacement (re-insert under the same name) deletes the file
        store.insert("b", &cf).unwrap();
        assert!(!store.is_spilled("b"));
        assert_eq!(spill_files(&dir).len(), 1);
        assert_eq!(store.spilled_bytes(), cf.total_bytes());
        // shutdown (drop) deletes whatever is left
        drop(store);
        assert_eq!(spill_files(&dir).len(), 0, "shutdown purges the spill dir");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_spill_file_surfaces_a_typed_error() {
        let dir = temp_spill_dir("corrupt");
        let _ = std::fs::remove_dir_all(&dir);
        let (cf, _, ds) = iris_model(17);
        let store = ModelStore::new().spill_dir(&dir);
        store.insert("m", &cf).unwrap();
        assert!(store.spill("m").unwrap());
        let file = spill_files(&dir).pop().unwrap();

        // truncation: the length check trips before the parse
        let full = std::fs::read(&file).unwrap();
        std::fs::write(&file, &full[..full.len() / 2]).unwrap();
        let err = store.predict("m", &row_values(&ds, 0)).unwrap_err().to_string();
        assert!(err.contains("truncated"), "typed error, not a panic: {err}");
        assert!(store.is_spilled("m"), "a failed reload leaves the entry spilled");

        // right length, garbage content: the parse itself errors
        std::fs::write(&file, vec![0x5a; full.len()]).unwrap();
        let err = format!("{:#}", store.predict("m", &row_values(&ds, 0)).unwrap_err());
        assert!(err.contains("parsing spill file"), "{err}");
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spill_purges_plans_and_reload_stamps_a_fresh_id() {
        let dir = temp_spill_dir("planid");
        let _ = std::fs::remove_dir_all(&dir);
        let (cf, _, ds) = iris_model(18);
        let store = ModelStore::new().spill_dir(&dir);
        store.insert("m", &cf).unwrap();
        let rows: Vec<Vec<ObsValue>> = (0..16).map(|r| row_values(&ds, r)).collect();
        let cold = store.predict_batch("m", &rows).unwrap();
        assert!(store.plan_bytes() > 0);
        // hold the pre-spill predictor like an in-flight batch would
        let old = store.get("m").unwrap();
        let old_id = old.predictor.model_id();

        assert!(store.spill("m").unwrap());
        assert_eq!(store.plan_bytes(), 0, "a spilled model's plans are dropped");
        // the in-flight predictor still serves, but the retired id can never
        // repopulate the cache (regression: spilled ids must stay dead)
        let inflight = old.predictor.predict_all_workers(&ds, 1).unwrap();
        assert_eq!(store.plan_bytes(), 0, "retired plan_id cannot re-enter the cache");

        // reload: fresh parse, fresh plan_id, cache fills under the new id
        let warm = store.predict_batch("m", &rows).unwrap();
        assert_eq!(warm, cold);
        let new_id = store.get("m").unwrap().predictor.model_id();
        assert_ne!(new_id, old_id, "reload must stamp a fresh plan id");
        assert!(store.plan_bytes() > 0, "plans rebuild under the reloaded id");
        // the in-flight predictor's answers (rows 0..16 of the training
        // data) agree with the pre-spill batch over those same rows
        match inflight {
            crate::forest::forest::Predictions::Classes(cs) => {
                for (i, out) in cold.iter().enumerate() {
                    assert_eq!(*out, PredictOne::Class(cs[i]), "row {i}");
                }
            }
            _ => panic!("classification expected"),
        }
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spill_without_a_dir_is_an_error() {
        let (cf, _, _) = iris_model(19);
        let store = ModelStore::new();
        store.insert("m", &cf).unwrap();
        assert!(store.spill("m").is_err());
        let with_dir = ModelStore::new().spill_dir(temp_spill_dir("nodir"));
        assert!(!with_dir.spill("ghost").unwrap(), "unknown models spill to nothing");
    }

    // ------------------------------------------------------ packed tier

    /// A cohort pack over n tiny iris forests, keys `user-<i>`.
    fn iris_pack(n: usize, seed: u64) -> (Arc<PackArchive>, Vec<crate::forest::Forest>, Dataset) {
        use crate::forest::{Forest, ForestParams};
        let ds = synthetic::iris(83);
        let forests: Vec<Forest> = (0..n)
            .map(|i| Forest::train(&ds, &ForestParams::classification(2), seed + i as u64))
            .collect();
        let cohort =
            crate::pack::compress_cohort(&forests, &ds, &CompressOptions::default()).unwrap();
        let mut b = crate::pack::PackBuilder::new();
        for (i, cf) in cohort.iter().enumerate() {
            b.add(&format!("user-{i}"), cf.bytes.clone()).unwrap();
        }
        let (bytes, _) = b.build().unwrap();
        (Arc::new(PackArchive::from_bytes(bytes).unwrap()), forests, ds)
    }

    #[test]
    fn attach_load_release_round_trip() {
        let (pack, forests, ds) = iris_pack(4, 21);
        let store = ModelStore::new();
        assert_eq!(store.attach_pack(&pack).unwrap(), 4);
        assert_eq!(store.len(), 4);
        assert_eq!(store.packed_len(), 4, "members start unloaded");
        assert_eq!(store.resident_bytes(), 0, "attach costs no RAM");
        assert!(store.packed_bytes() > 0);
        assert!(store.is_packed("user-0") && store.contains("user-0"));

        // first request loads the member out of the archive
        let vals = row_values(&ds, 0);
        let out = store.predict("user-0", &vals).unwrap();
        assert_eq!(out, PredictOne::Class(forests[0].predict_class(&ds, 0)));
        assert!(!store.is_packed("user-0"), "loaded member is Resident");
        assert_eq!(store.packed_len(), 3);
        assert!(store.resident_bytes() > 0);
        let s = store.stats();
        assert_eq!((s.pack_loads, s.pack_releases), (1, 0));

        // a batch decodes flat plans for the loaded member...
        let rows: Vec<Vec<ObsValue>> = (0..16).map(|r| row_values(&ds, r)).collect();
        store.predict_batch("user-0", &rows).unwrap();
        assert!(store.plan_bytes() > 0);

        // release parks it back in the archive — no disk write, no eviction
        assert!(store.release("user-0"));
        assert!(store.is_packed("user-0"));
        assert_eq!(store.resident_bytes(), 0);
        assert_eq!(store.stats().pack_releases, 1);
        assert!(!store.release("user-0"), "already released: no-op");
        assert_eq!(store.plan_bytes(), 0, "released member's plans are purged");

        // and it serves again, identically, through a fresh load
        let again = store.predict("user-0", &vals).unwrap();
        assert_eq!(again, out);
        assert_eq!(store.stats().pack_loads, 2);
    }

    #[test]
    fn budget_releases_pack_members_instead_of_spilling() {
        let (pack, forests, ds) = iris_pack(4, 22);
        let one = pack.member_logical_bytes(0);
        let dir = temp_spill_dir("packrelease");
        let _ = std::fs::remove_dir_all(&dir);
        // room for ~2 loaded members, spill dir armed — members must still
        // RELEASE (free) rather than spill (disk write)
        let store = ModelStore::with_budget(2 * one + one / 2).spill_dir(&dir);
        store.attach_pack(&pack).unwrap();
        for i in 0..4 {
            let name = format!("user-{i}");
            let out = store.predict(&name, &row_values(&ds, i)).unwrap();
            assert_eq!(out, PredictOne::Class(forests[i].predict_class(&ds, i)));
        }
        assert!(store.resident_bytes() <= store.max_resident_bytes().unwrap());
        let s = store.stats();
        assert_eq!(s.pack_loads, 4);
        assert!(s.pack_releases >= 1, "budget pressure must release members");
        assert_eq!(s.spills, 0, "pack members never spill");
        assert_eq!(s.evictions, 0, "pack members never drop");
        assert_eq!(spill_files(&dir).len(), 0, "no spill files for pack members");
        assert_eq!(store.len(), 4, "every member is still owned");
        // spill() on a loaded pack member delegates to release
        let loaded = store
            .names()
            .into_iter()
            .find(|n| !store.is_packed(n))
            .expect("some member is resident");
        assert!(store.spill(&loaded).unwrap());
        assert!(store.is_packed(&loaded), "spill of a pack member = release");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pack_member_removal_and_replacement_keep_the_archive_intact() {
        let (pack, _, _) = iris_pack(3, 23);
        let store = ModelStore::new();
        store.attach_pack(&pack).unwrap();
        // removing a member never touches the archive
        assert!(store.remove("user-0"));
        assert!(!store.contains("user-0"));
        assert_eq!(store.len(), 2);
        assert!(pack.parse_member(0).is_ok(), "the archive still serves member 0");
        // a direct insert replaces a packed member cleanly
        let (cf, _, _) = iris_model(24);
        store.insert("user-1", &cf).unwrap();
        assert!(!store.is_packed("user-1"));
        assert_eq!(store.resident_bytes(), cf.total_bytes());
        // re-attach restores every member (replacing the direct insert)
        store.attach_pack(&pack).unwrap();
        assert_eq!(store.len(), 3);
        assert!(store.is_packed("user-1"));
        assert_eq!(store.resident_bytes(), 0);
        assert_eq!(store.packed_bytes(), (0..3).map(|i| pack.member_logical_bytes(i)).sum());
    }

    #[test]
    fn attach_refuses_members_over_the_whole_budget() {
        let (pack, _, _) = iris_pack(2, 25);
        let tiny = ModelStore::with_budget(pack.member_logical_bytes(0) / 2);
        assert!(tiny.attach_pack(&pack).is_err());
        assert_eq!(tiny.len(), 0, "refusal leaves nothing half-attached");
    }

    /// Budget for exactly two models, spill tier armed, four models in:
    /// `hot` is requested heavily, `warm2` keeps a seat, then one cold scan
    /// request arrives. Under `tinylfu` the scan candidate is demoted right
    /// back (the hot set survives and `admission_rejects` ticks); under
    /// `lru` the exact same sequence spills the hot model.
    fn scan_round(policy: AdmissionPolicy) -> (ModelStore, PathBuf) {
        let (cf, _, ds) = iris_model(6);
        let one = cf.total_bytes();
        let dir = temp_spill_dir(&format!("adm-{policy}"));
        let store = ModelStore::with_budget(2 * one + one / 2)
            .spill_dir(dir.clone())
            .admission(policy);
        for name in ["hot", "cold", "warm1", "warm2"] {
            store.insert(name, &cf).unwrap();
        }
        // inserts ran ungated (admin path): the two oldest spilled
        assert_eq!(store.spilled_len(), 2);
        assert!(store.is_spilled("hot") && store.is_spilled("cold"));
        let vals = row_values(&ds, 0);
        // build the hot set: "hot" reloads and accumulates frequency,
        // then "warm2" is touched so "hot" becomes the LRU resident
        for _ in 0..5 {
            store.predict("hot", &vals).unwrap();
        }
        for _ in 0..3 {
            store.predict("warm2", &vals).unwrap();
        }
        assert!(!store.is_spilled("hot"), "the hot model reloaded");
        // the scan: one request for a model seen once ever
        store.predict("cold", &vals).unwrap();
        (store, dir)
    }

    #[test]
    fn tinylfu_gate_keeps_the_hot_model_under_a_scan() {
        let (store, dir) = scan_round(AdmissionPolicy::TinyLfu);
        assert!(
            !store.is_spilled("hot"),
            "the scan must not displace the hot model under tinylfu"
        );
        assert!(store.is_spilled("cold"), "the rejected candidate re-spilled");
        assert_eq!(store.stats().admission_rejects, 1);
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_control_loses_the_hot_model_to_the_same_scan() {
        let (store, dir) = scan_round(AdmissionPolicy::Lru);
        assert!(
            store.is_spilled("hot"),
            "under pure LRU the scan displaces the hot model (the contrast \
             the tinylfu test demonstrates)"
        );
        assert_eq!(store.stats().admission_rejects, 0, "lru never consults the gate");
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn admission_gate_with_empty_sketch_degrades_to_lru() {
        // no history at all: ties admit the candidate, so the very first
        // gated load behaves exactly like LRU (nothing is rejected)
        let store = ModelStore::with_budget(1).admission(AdmissionPolicy::TinyLfu);
        assert!(!store.reject_candidate("anything", "victim"));
        assert_eq!(store.admission_policy(), AdmissionPolicy::TinyLfu);
        assert_eq!(ModelStore::new().admission_policy(), AdmissionPolicy::Lru);
    }

    #[test]
    fn plan_cache_admission_needs_two_sightings() {
        let store = ModelStore::new().admission(AdmissionPolicy::TinyLfu);
        assert!(!store.plan_admit("m"), "a never-seen model gets no shared plans");
        store.touch_sketch("m");
        assert!(!store.plan_admit("m"), "first sighting is still cold");
        store.touch_sketch("m");
        assert!(store.plan_admit("m"), "second sighting clears the doorkeeper");
        // the lru policy has no sketch: plans always attach
        assert!(ModelStore::new().plan_admit("never-seen"));
    }

    #[test]
    fn prefetch_counts_cold_targets_and_warm_makes_them_resident() {
        let (cf, _, _) = iris_model(6);
        let one = cf.total_bytes();
        let dir = temp_spill_dir("prefetch");
        let store = ModelStore::with_budget(one + one / 2).spill_dir(dir.clone());
        store.insert("a", &cf).unwrap();
        store.insert("b", &cf).unwrap();
        assert!(store.is_spilled("a"), "budget for one: the older model spilled");
        assert!(store.prefetch_needed("a").unwrap(), "a spilled model wants warming");
        store.warm("a").unwrap();
        assert!(!store.is_spilled("a"), "warm promoted the spilled model");
        assert!(
            !store.prefetch_needed("a").unwrap(),
            "an already-resident model needs no warm-up"
        );
        let s = store.stats();
        assert_eq!(s.prefetches, 1, "only the cold prefetch counted");
        assert!(store.prefetch_needed("nope").is_err(), "unknown names error");
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
