//! The CI bench-regression gate.
//!
//! Compares the serve bench's machine-readable report (`BENCH_serve.json`,
//! written by `cargo bench --bench hotpath -- serve`) against a committed
//! baseline (`BENCH_baseline.json`) and fails on regression beyond a
//! relative tolerance. Wired as the `repro bench-gate` subcommand and run by
//! the `bench-gate` CI job, which uploads both JSONs as artifacts.
//!
//! Gated metrics (the serving SLO pair):
//!
//! * `rows_per_sec.flat_warm` — warm-flat batch throughput; **higher** is
//!   better, the gate fails when current < baseline · (1 − tolerance);
//! * `single_row_us.p99` — single-row tail latency; **lower** is better,
//!   the gate fails when current > baseline · (1 + tolerance).
//!
//! Refreshing the baseline after an intentional perf change (validates the
//! gated metrics exist before overwriting anything, unlike a blind `cp`):
//!
//! ```text
//! cargo bench --bench hotpath -- serve --quick --trees 16
//! repro bench-gate --current BENCH_serve.json --write-baseline   # commit it
//! ```

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::Path;

/// Whether a metric regresses by shrinking or by growing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Regression = current below baseline (throughput-like).
    HigherIsBetter,
    /// Regression = current above baseline (latency-like).
    LowerIsBetter,
}

/// One gated metric's verdict.
#[derive(Debug, Clone, PartialEq)]
pub struct GateResult {
    /// Human-readable metric label.
    pub metric: String,
    /// Which way this metric regresses.
    pub direction: Direction,
    /// The committed baseline value.
    pub baseline: f64,
    /// The freshly-measured value.
    pub current: f64,
    /// current / baseline.
    pub ratio: f64,
    /// Whether the metric stayed within tolerance.
    pub ok: bool,
}

/// The serve-bench metrics under the gate: (label, JSON path, direction).
const SERVE_GATES: &[(&str, &[&str], Direction)] = &[
    (
        "warm-flat throughput (rows/s)",
        &["rows_per_sec", "flat_warm"],
        Direction::HigherIsBetter,
    ),
    (
        "single-row p99 latency (µs)",
        &["single_row_us", "p99"],
        Direction::LowerIsBetter,
    ),
];

fn metric(doc: &Json, which: &str, path: &[&str]) -> Result<f64> {
    let v = doc
        .at(path)
        .and_then(Json::as_f64)
        .with_context(|| format!("{which} report is missing numeric {}", path.join(".")))?;
    if !v.is_finite() || v < 0.0 {
        anyhow::bail!("{which} report has implausible {} = {v}", path.join("."));
    }
    Ok(v)
}

/// Compare two parsed serve reports under a relative `tolerance`
/// (0.25 = ±25%). Errors when either report lacks a gated metric —
/// a silently-skipped gate is indistinguishable from a green one.
pub fn compare_serve(baseline: &Json, current: &Json, tolerance: f64) -> Result<Vec<GateResult>> {
    let mut out = Vec::with_capacity(SERVE_GATES.len());
    for &(label, path, direction) in SERVE_GATES {
        let base = metric(baseline, "baseline", path)?;
        let cur = metric(current, "current", path)?;
        let ratio = if base > 0.0 { cur / base } else { f64::INFINITY };
        let ok = match direction {
            Direction::HigherIsBetter => cur >= base * (1.0 - tolerance),
            Direction::LowerIsBetter => cur <= base * (1.0 + tolerance),
        };
        out.push(GateResult {
            metric: label.to_string(),
            direction,
            baseline: base,
            current: cur,
            ratio,
            ok,
        });
    }
    Ok(out)
}

/// Read both report files, print the verdict table, and return whether every
/// gate passed.
pub fn run_files(baseline: &Path, current: &Path, tolerance: f64) -> Result<bool> {
    let read = |p: &Path, which: &str| -> Result<Json> {
        let text = std::fs::read_to_string(p)
            .with_context(|| format!("reading {which} report {}", p.display()))?;
        Json::parse(&text).with_context(|| format!("parsing {which} report {}", p.display()))
    };
    let results = compare_serve(
        &read(baseline, "baseline")?,
        &read(current, "current")?,
        tolerance,
    )?;

    let mut table = super::bench::Table::new(&["metric", "baseline", "current", "ratio", "gate"]);
    let mut all_ok = true;
    for r in &results {
        all_ok &= r.ok;
        let bound = match r.direction {
            Direction::HigherIsBetter => format!("≥ {:.3}", 1.0 - tolerance),
            Direction::LowerIsBetter => format!("≤ {:.3}", 1.0 + tolerance),
        };
        table.row(&[
            r.metric.clone(),
            format!("{:.1}", r.baseline),
            format!("{:.1}", r.current),
            format!("{:.3} ({bound})", r.ratio),
            if r.ok { "PASS".into() } else { "FAIL".into() },
        ]);
    }
    table.print();
    if all_ok {
        println!("bench-gate: PASS (tolerance ±{:.0}%)", tolerance * 100.0);
    } else {
        println!(
            "bench-gate: FAIL — perf regressed past ±{:.0}% of {}; if intentional, \
             refresh the baseline (`cargo bench --bench hotpath -- serve --quick --trees 16 \
             && repro bench-gate --current BENCH_serve.json --write-baseline`)",
            tolerance * 100.0,
            baseline.display()
        );
    }
    Ok(all_ok)
}

/// Rewrite the committed baseline from a current run (`repro bench-gate
/// --write-baseline`). The current report must carry every gated metric —
/// a baseline missing one would hard-fail every future gate run — and is
/// then copied **verbatim**, so ungated context fields (trees, rows, worker
/// scaling) stay diffable across refreshes.
pub fn write_baseline(current: &Path, baseline: &Path) -> Result<()> {
    let text = std::fs::read_to_string(current)
        .with_context(|| format!("reading current report {}", current.display()))?;
    let doc = Json::parse(&text)
        .with_context(|| format!("parsing current report {}", current.display()))?;
    for &(label, path, _) in SERVE_GATES {
        metric(&doc, "current", path)
            .with_context(|| format!("refusing to write a baseline without {label}"))?;
    }
    std::fs::write(baseline, &text)
        .with_context(|| format!("writing baseline {}", baseline.display()))?;
    println!(
        "bench-gate: baseline {} refreshed from {} ({} gated metrics verified)",
        baseline.display(),
        current.display(),
        SERVE_GATES.len()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(flat_warm: f64, p99: f64) -> Json {
        Json::parse(&format!(
            r#"{{"rows_per_sec": {{"flat_warm": {flat_warm}, "baseline_redecode": 1.0}},
                 "single_row_us": {{"p50": 1.0, "p99": {p99}}}}}"#
        ))
        .unwrap()
    }

    #[test]
    fn unchanged_metrics_pass() {
        let r = compare_serve(&report(1000.0, 50.0), &report(1000.0, 50.0), 0.25).unwrap();
        assert_eq!(r.len(), 2);
        assert!(r.iter().all(|g| g.ok));
        assert!(r.iter().all(|g| (g.ratio - 1.0).abs() < 1e-12));
    }

    #[test]
    fn within_tolerance_passes_both_directions() {
        // throughput −20%, latency +20%: inside ±25%
        let r = compare_serve(&report(1000.0, 50.0), &report(800.0, 60.0), 0.25).unwrap();
        assert!(r.iter().all(|g| g.ok), "{r:?}");
        // improvements never fail, however large
        let r = compare_serve(&report(1000.0, 50.0), &report(9000.0, 1.0), 0.25).unwrap();
        assert!(r.iter().all(|g| g.ok), "{r:?}");
    }

    #[test]
    fn throughput_regression_fails() {
        let r = compare_serve(&report(1000.0, 50.0), &report(700.0, 50.0), 0.25).unwrap();
        assert!(!r[0].ok, "throughput −30% must trip the gate: {r:?}");
        assert!(r[1].ok);
    }

    #[test]
    fn latency_regression_fails() {
        let r = compare_serve(&report(1000.0, 50.0), &report(1000.0, 70.0), 0.25).unwrap();
        assert!(r[0].ok);
        assert!(!r[1].ok, "p99 +40% must trip the gate: {r:?}");
    }

    #[test]
    fn missing_metric_is_an_error_not_a_skip() {
        let empty = Json::parse("{}").unwrap();
        assert!(compare_serve(&empty, &report(1.0, 1.0), 0.25).is_err());
        assert!(compare_serve(&report(1.0, 1.0), &empty, 0.25).is_err());
        let non_numeric =
            Json::parse(r#"{"rows_per_sec": {"flat_warm": "fast"}, "single_row_us": {"p99": 1}}"#)
                .unwrap();
        assert!(compare_serve(&non_numeric, &report(1.0, 1.0), 0.25).is_err());
    }

    #[test]
    fn write_baseline_validates_then_copies_verbatim() {
        let dir = std::env::temp_dir().join(format!("rfc-gate-wb-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cur = dir.join("cur.json");
        let base = dir.join("base.json");
        // extra fields and formatting must survive the refresh byte-for-byte
        let body = "{\n  \"rows_per_sec\": {\"flat_warm\": 1234.5},\n  \
                    \"single_row_us\": {\"p99\": 9.5},\n  \"trees\": 16\n}\n";
        std::fs::write(&cur, body).unwrap();
        write_baseline(&cur, &base).unwrap();
        assert_eq!(std::fs::read_to_string(&base).unwrap(), body);
        // the refreshed baseline immediately passes the gate against itself
        assert!(run_files(&base, &cur, 0.25).unwrap());

        // a report missing a gated metric must NOT overwrite the baseline
        std::fs::write(&cur, r#"{"rows_per_sec": {"flat_warm": 1.0}}"#).unwrap();
        assert!(write_baseline(&cur, &base).is_err());
        assert_eq!(std::fs::read_to_string(&base).unwrap(), body, "baseline untouched");
        // unreadable / malformed current reports error out too
        assert!(write_baseline(&dir.join("missing.json"), &base).is_err());
        std::fs::write(&cur, "not json").unwrap();
        assert!(write_baseline(&cur, &base).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn run_files_end_to_end() {
        let dir = std::env::temp_dir().join(format!("rfc-gate-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("base.json");
        let cur = dir.join("cur.json");
        let body = |fw: f64, p99: f64| {
            format!(
                r#"{{"rows_per_sec": {{"flat_warm": {fw}}}, "single_row_us": {{"p99": {p99}}}}}"#
            )
        };
        std::fs::write(&base, body(1000.0, 50.0)).unwrap();
        std::fs::write(&cur, body(950.0, 55.0)).unwrap();
        assert!(run_files(&base, &cur, 0.25).unwrap());
        std::fs::write(&cur, body(100.0, 55.0)).unwrap();
        assert!(!run_files(&base, &cur, 0.25).unwrap());
        assert!(run_files(&dir.join("missing.json"), &cur, 0.25).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
