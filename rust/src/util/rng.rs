//! PCG64 (XSL-RR 128/64) pseudo-random number generator.
//!
//! A small, fast, statistically solid generator (O'Neill, 2014) used for all
//! stochastic components: bootstrap sampling, feature subsetting, synthetic
//! data generation, dithered quantization, and the property-testing
//! framework. Deterministic for a given seed, and *splittable* so that each
//! tree / worker / dataset gets an independent stream.

/// PCG64 XSL-RR generator state.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed. Two generators with distinct seeds
    /// produce independent-looking streams.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream selector; generators with
    /// the same seed but different streams are independent.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        // Diffuse the seed through a few rounds.
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    /// Derive an independent child generator (splittable-RNG style); used to
    /// give each tree its own stream so training is order-independent and
    /// parallelizable.
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let stream = self.next_u64() | 1;
        Pcg64::with_stream(seed, stream)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "gen_range bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let t = bound.wrapping_neg() % bound;
            while lo < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn gen_index(&mut self, bound: usize) -> usize {
        self.gen_range(bound as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box–Muller (cached second variate is deliberately
    /// not kept: simplicity beats the 2x speedup here).
    pub fn gen_normal(&mut self) -> f64 {
        loop {
            let u1 = self.gen_f64();
            if u1 > 0.0 {
                let u2 = self.gen_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates);
    /// returned in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        // For small k relative to n use a set-based approach; otherwise
        // shuffle a full index vector.
        if k * 4 <= n {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let idx = self.gen_index(n);
                if seen.insert(idx) {
                    out.push(idx);
                }
            }
            out
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        }
    }

    /// Bootstrap sample: `n` draws with replacement from `[0, n)`.
    pub fn bootstrap(&mut self, n: usize) -> Vec<usize> {
        (0..n).map(|_| self.gen_index(n)).collect()
    }

    /// Pick one element of a slice uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Pcg64::new(3);
        let mut c1 = root.split(0);
        let mut c2 = root.split(1);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut rng = Pcg64::new(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.gen_range(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_f64_unit_interval_mean() {
        let mut rng = Pcg64::new(5);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gen_normal_moments() {
        let mut rng = Pcg64::new(6);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::new(9);
        for &(n, k) in &[(10usize, 3usize), (100, 90), (50, 50), (1000, 10)] {
            let s = rng.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(10);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn bootstrap_len_and_range() {
        let mut rng = Pcg64::new(12);
        let b = rng.bootstrap(500);
        assert_eq!(b.len(), 500);
        assert!(b.iter().all(|&i| i < 500));
        // with replacement ⇒ expect duplicates
        let set: std::collections::HashSet<_> = b.iter().collect();
        assert!(set.len() < 500);
    }
}
