//! Minimal command-line argument parsing (no `clap` available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed accessors and a generated usage string. Used by the
//! `repro` binary, the examples, and every bench target (benches share the
//! same flags: `--trees`, `--seed`, `--paper-scale`, ...).

use std::collections::BTreeMap;

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
    program: String,
}

impl Args {
    /// Parse from `std::env::args()`.
    pub fn from_env() -> Self {
        let mut it = std::env::args();
        let program = it.next().unwrap_or_default();
        Self::parse_iter(program, it)
    }

    /// Parse from an explicit list (used in tests).
    pub fn parse(program: &str, args: &[&str]) -> Self {
        Self::parse_iter(program.to_string(), args.iter().map(|s| s.to_string()))
    }

    fn parse_iter(program: String, it: impl Iterator<Item = String>) -> Self {
        let mut out = Args {
            program,
            ..Default::default()
        };
        let mut pending: Option<String> = None;
        for arg in it {
            if let Some(key) = pending.take() {
                if arg.starts_with("--") {
                    // previous was a bare flag
                    out.flags.insert(key, "true".into());
                    pending = Some(arg.trim_start_matches("--").to_string());
                } else {
                    out.flags.insert(key, arg);
                }
                continue;
            }
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    pending = Some(rest.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        if let Some(key) = pending {
            out.flags.insert(key, "true".into());
        }
        out
    }

    /// The program name (`argv[0]`).
    pub fn program(&self) -> &str {
        &self.program
    }

    /// Positional argument by index.
    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    /// All positional arguments, in order.
    pub fn positionals(&self) -> &[String] {
        &self.positional
    }

    /// Raw string value of `--key`.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Boolean flag: present (as bare `--key` or `--key true/1/yes`).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Typed value with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            Some(s) => s.parse().unwrap_or_else(|_| {
                eprintln!("warning: could not parse --{key} {s:?}; using default");
                default
            }),
            None => default,
        }
    }

    /// Required typed value; exits with a message when missing/invalid.
    pub fn require<T: std::str::FromStr>(&self, key: &str) -> T {
        match self.get(key) {
            Some(s) => s.parse().unwrap_or_else(|_| {
                eprintln!("error: could not parse --{key} {s:?}");
                std::process::exit(2);
            }),
            None => {
                eprintln!("error: missing required flag --{key}");
                std::process::exit(2);
            }
        }
    }

    /// Comma-separated list of typed values.
    pub fn get_list<T: std::str::FromStr>(&self, key: &str) -> Option<Vec<T>> {
        self.get(key).map(|s| {
            s.split(',')
                .filter(|p| !p.is_empty())
                .filter_map(|p| p.trim().parse().ok())
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_key_value_and_equals() {
        let a = Args::parse("p", &["--trees", "100", "--seed=7", "pos1"]);
        assert_eq!(a.get_or("trees", 0u32), 100);
        assert_eq!(a.get_or("seed", 0u64), 7);
        assert_eq!(a.positional(0), Some("pos1"));
    }

    #[test]
    fn bare_flags() {
        let a = Args::parse("p", &["--verbose", "--paper-scale", "--k", "3"]);
        assert!(a.flag("verbose"));
        assert!(a.flag("paper-scale"));
        assert_eq!(a.get_or("k", 0u32), 3);
        assert!(!a.flag("absent"));
    }

    #[test]
    fn trailing_bare_flag() {
        let a = Args::parse("p", &["--x", "1", "--debug"]);
        assert!(a.flag("debug"));
        assert_eq!(a.get_or("x", 0u32), 1);
    }

    #[test]
    fn list_values() {
        let a = Args::parse("p", &["--bits", "4,8,12"]);
        assert_eq!(a.get_list::<u32>("bits"), Some(vec![4, 8, 12]));
    }

    #[test]
    fn default_on_missing() {
        let a = Args::parse("p", &[]);
        assert_eq!(a.get_or("trees", 25u32), 25);
    }
}
