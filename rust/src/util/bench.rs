//! Micro-benchmark harness shared by the `cargo bench` targets (criterion is
//! not available offline; this provides warmup + repeated timing with
//! median/p10/p90, and aligned table printing).

use std::time::Instant;

/// Timing summary in seconds.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    /// Median sample, seconds.
    pub median: f64,
    /// 10th-percentile sample, seconds.
    pub p10: f64,
    /// 90th-percentile sample, seconds.
    pub p90: f64,
    /// Tail latency (used by the machine-readable bench reports); with few
    /// iterations this degrades toward the max sample.
    pub p99: f64,
    /// Number of timed iterations.
    pub iters: usize,
}

impl Timing {
    /// Throughput at the median: `items` per second.
    pub fn per_sec(&self, items: f64) -> f64 {
        items / self.median.max(1e-12)
    }
}

impl std::fmt::Display for Timing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let scale = |s: f64| {
            if s >= 1.0 {
                format!("{s:.3} s")
            } else if s >= 1e-3 {
                format!("{:.3} ms", s * 1e3)
            } else {
                format!("{:.1} µs", s * 1e6)
            }
        };
        write!(
            f,
            "{} (p10 {}, p90 {}, n={})",
            scale(self.median),
            scale(self.p10),
            scale(self.p90),
            self.iters
        )
    }
}

/// Run `f` repeatedly: 1 warmup + enough iterations to fill ~`budget_s`
/// seconds (at least `min_iters`). Returns the timing summary.
pub fn time_it<F: FnMut()>(budget_s: f64, min_iters: usize, mut f: F) -> Timing {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let first = t0.elapsed().as_secs_f64();
    let iters = ((budget_s / first.max(1e-9)) as usize).clamp(min_iters, 10_000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    Timing { median: q(0.5), p10: q(0.1), p90: q(0.9), p99: q(0.99), iters }
}

/// Aligned table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append one row (must match the header count).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    /// Print the table with aligned columns.
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!("{c:>w$}  ", w = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", widths.iter().map(|w| "-".repeat(*w + 2)).collect::<String>());
        for row in &self.rows {
            line(row);
        }
    }
}

/// Common bench flags: `--trees`, `--seed`, `--paper-scale`; `default_trees`
/// is used when neither `--trees` nor `--paper-scale` is given.
pub struct BenchConfig {
    /// Forest size for the bench workloads.
    pub trees: usize,
    /// Training/workload seed.
    pub seed: u64,
    /// Whether `--paper-scale` was given.
    pub paper_scale: bool,
    /// The raw parsed arguments, for bench-specific flags.
    pub args: super::cli::Args,
}

/// Parse the common bench flags from the environment.
pub fn bench_config(default_trees: usize) -> BenchConfig {
    let args = super::cli::Args::from_env();
    let paper_scale = args.flag("paper-scale");
    let trees = args.get_or("trees", if paper_scale { 1000 } else { default_trees });
    BenchConfig { trees, seed: args.get_or("seed", 7), paper_scale, args }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_sane_numbers() {
        let t = time_it(0.01, 3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t.median >= 0.0);
        assert!(t.p10 <= t.p90 + 1e-12);
        assert!(t.p90 <= t.p99 + 1e-12);
        assert!(t.iters >= 3);
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "22".into()]);
        t.print();
    }
}
