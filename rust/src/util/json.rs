//! A minimal JSON reader (no `serde` in the offline image).
//!
//! Parses the machine-readable bench reports (`BENCH_serve.json`,
//! `BENCH_baseline.json`) for the CI regression gate. Full JSON value
//! grammar — objects, arrays, strings with escapes, numbers, booleans,
//! null — with a recursion-depth bound; no serialization (the benches write
//! their JSON by hand).

use anyhow::{bail, Context, Result};

/// Maximum nesting depth accepted (bench reports are ~3 levels deep;
/// anything past this is malformed or adversarial).
const MAX_DEPTH: usize = 64;

/// A parsed JSON value. Object keys keep insertion order (duplicate keys:
/// first match wins on lookup).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (all JSON numbers parse as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, keys in insertion order.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing non-whitespace is an error).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    /// Object member by key (None on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Walk a path of object keys: `at(&["rows_per_sec", "flat_warm"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for key in path {
            cur = cur.get(key)?;
        }
        Some(cur)
    }

    /// The number, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The string, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().context("unexpected end of JSON")
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i);
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            bail!("nesting deeper than {MAX_DEPTH}");
        }
        match self.peek()? {
            b'{' => self.object(depth),
            b'[' => self.array(depth),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected {:?} at byte {}", c as char, self.i),
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value(depth + 1)?;
            members.push((key, v));
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(members));
                }
                c => bail!("expected ',' or '}}', got {:?} at byte {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']', got {:?} at byte {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => out.push(self.unicode_escape()?),
                        _ => bail!("bad escape at byte {}", self.i - 1),
                    }
                }
                // multi-byte UTF-8 continuation: copy the raw bytes through
                _ => {
                    let start = self.i - 1;
                    while self.i < self.b.len()
                        && self.b[self.i] >= 0x80
                        && self.b[self.i] < 0xc0
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).context("bad utf8")?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            bail!("truncated \\u escape");
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4]).context("bad \\u escape")?;
        let v = u32::from_str_radix(s, 16).context("bad \\u escape")?;
        self.i += 4;
        Ok(v)
    }

    fn unicode_escape(&mut self) -> Result<char> {
        let hi = self.hex4()?;
        // surrogate pair: \uD800-\uDBFF must be followed by \uDC00-\uDFFF
        let cp = if (0xd800..0xdc00).contains(&hi) {
            if self.b.get(self.i) == Some(&b'\\') && self.b.get(self.i + 1) == Some(&b'u') {
                self.i += 2;
                let lo = self.hex4()?;
                if !(0xdc00..0xe000).contains(&lo) {
                    bail!("unpaired surrogate");
                }
                0x10000 + ((hi - 0xd800) << 10) + (lo - 0xdc00)
            } else {
                bail!("unpaired surrogate");
            }
        } else if (0xdc00..0xe000).contains(&hi) {
            bail!("unpaired surrogate");
        } else {
            hi
        };
        char::from_u32(cp).context("invalid code point")
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).context("bad number")?;
        let v: f64 = s.parse().with_context(|| format!("bad number {s:?} at byte {start}"))?;
        Ok(Json::Num(v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_bench_report_shape() {
        let doc = r#"{
            "bench": "hotpath serve",
            "trees": 16,
            "single_row_us": {"p50": 42.5, "p99": 120.0},
            "rows_per_sec": {"baseline_redecode": 1000.5, "flat_warm": 2.5e6},
            "worker_scaling": [{"workers": 1, "rows_per_sec": 100.0}],
            "ok": true, "missing": null
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str(), Some("hotpath serve"));
        assert_eq!(j.at(&["single_row_us", "p99"]).unwrap().as_f64(), Some(120.0));
        assert_eq!(j.at(&["rows_per_sec", "flat_warm"]).unwrap().as_f64(), Some(2.5e6));
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("missing"), Some(&Json::Null));
        assert_eq!(j.at(&["rows_per_sec", "nope"]), None);
        match j.get("worker_scaling").unwrap() {
            Json::Arr(items) => {
                assert_eq!(items.len(), 1);
                assert_eq!(items[0].at(&["workers"]).unwrap().as_f64(), Some(1.0));
            }
            _ => panic!("array expected"),
        }
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\"b\\c\n\u0041\u00e9\ud83d\ude00""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\nAé😀"));
    }

    #[test]
    fn negative_and_exponent_numbers() {
        assert_eq!(Json::parse("-12.5e-2").unwrap().as_f64(), Some(-0.125));
        assert_eq!(Json::parse("0").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn malformed_documents_error() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "{\"a\" 1}", "tru", "1 2", "\"unterminated",
            "\"\\uD800\"", "nul", "{,}",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn depth_bound_is_enforced() {
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(Json::parse(&deep).is_err());
        let ok = "[".repeat(10) + &"]".repeat(10);
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn utf8_passthrough() {
        let j = Json::parse("\"héllo → wörld\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo → wörld"));
    }

    #[test]
    fn escaped_quotes_and_backslashes_in_strings() {
        // every escape position: leading, trailing, adjacent, doubled
        for (doc, want) in [
            (r#""\"""#, "\""),
            (r#""\\""#, "\\"),
            (r#""\\\\""#, "\\\\"),
            (r#""\"\"""#, "\"\""),
            (r#""a\\\"b""#, "a\\\"b"),
            (r#""\\n""#, "\\n"),
            (r#""path\\to\\file""#, "path\\to\\file"),
            (r#""end with \\""#, "end with \\"),
        ] {
            assert_eq!(Json::parse(doc).unwrap().as_str(), Some(want), "{doc}");
        }
        // a lone backslash before the closing quote swallows it: the
        // document is unterminated and must error, not mis-parse
        assert!(Json::parse(r#""\""#).is_err());
        // escaped quotes inside object KEYS work too
        let j = Json::parse(r#"{"a\"b": 1}"#).unwrap();
        assert_eq!(j.get("a\"b").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn exponent_float_forms() {
        for (doc, want) in [
            ("1e10", 1e10),
            ("1E10", 1e10),
            ("1e+10", 1e10),
            ("1e-10", 1e-10),
            ("-2.5E-3", -2.5e-3),
            ("0.0e0", 0.0),
            ("123.456e2", 12345.6),
            ("5e0", 5.0),
        ] {
            let got = Json::parse(doc).unwrap().as_f64().unwrap();
            assert!((got - want).abs() <= want.abs() * 1e-12, "{doc}: {got} != {want}");
        }
        // malformed exponents must error, not round to something
        for bad in ["1e", "1e+", "e10", "1.2.3", "--1", "1e10e10"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} must not parse");
        }
        // exponent metrics survive a realistic report lookup
        let j = Json::parse(r#"{"rows_per_sec": {"flat_warm": 2.5e6}}"#).unwrap();
        assert_eq!(j.at(&["rows_per_sec", "flat_warm"]).unwrap().as_f64(), Some(2.5e6));
    }

    #[test]
    fn deeply_nested_arrays_to_the_bound() {
        // inside the bound parses; past it errors (no stack overflow). The
        // innermost of n nested arrays runs at depth n-1, so n = MAX_DEPTH
        // is safely inside and n = MAX_DEPTH + 2 is guaranteed past it.
        let at = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
        assert!(Json::parse(&at).is_ok(), "{MAX_DEPTH} nested arrays must parse");
        let past = "[".repeat(MAX_DEPTH + 2) + &"]".repeat(MAX_DEPTH + 2);
        assert!(Json::parse(&past).is_err(), "{} nested arrays must error", MAX_DEPTH + 2);
        // mixed nesting counts every level
        let mixed = r#"{"a": [{"b": [[{"c": [1, [2, [3]]]}]]}]}"#;
        let j = Json::parse(mixed).unwrap();
        assert!(j.at(&["a"]).is_some());
        // nesting with content at the leaves round-trips values
        let deep = format!("{}42{}", "[".repeat(20), "]".repeat(20));
        let mut cur = Json::parse(&deep).unwrap();
        for _ in 0..20 {
            cur = match cur {
                Json::Arr(mut items) => items.remove(0),
                other => panic!("array expected, got {other:?}"),
            };
        }
        assert_eq!(cur.as_f64(), Some(42.0));
    }

    #[test]
    fn trailing_garbage_rejected() {
        for bad in [
            "{} {}",
            "[1] [2]",
            "1 2",
            "null null",
            "{\"a\": 1} x",
            "\"s\"garbage",
            "[1],",
            "{}]",
            "true false",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} must reject trailing garbage");
        }
        // trailing WHITESPACE (including newlines) is fine
        for ok in ["{} ", "[1]\n", " 1 ", "null\r\n", "\t\"s\"\t"] {
            assert!(Json::parse(ok).is_ok(), "{ok:?} must parse");
        }
    }
}
