//! Shared infrastructure: RNG, statistics, CLI parsing, and a scoped thread
//! pool.
//!
//! The build environment is offline with no `rand`/`clap`/`tokio` crates, so
//! these substrates are implemented in-tree (see `DESIGN.md §3`).

pub mod bench;
pub mod benchgate;
pub mod cli;
pub mod json;
pub mod mmap;
pub mod rng;
pub mod stats;
pub mod threads;

pub use rng::Pcg64;
pub use stats::OnlineStats;
