//! A small scoped thread pool (no `tokio` offline).
//!
//! The coordinator's map-reduce passes (DESIGN.md §6) need "run these N
//! closures on W workers and collect results in order". `parallel_map` does
//! exactly that on `std::thread::scope`, so borrowed data needs no `Arc`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of workers to use: the `RF_THREADS` env var when set, otherwise
/// available parallelism (1 on this testbed).
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("RF_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Apply `f` to every item of `items` on up to `workers` threads; results are
/// returned in input order. Falls back to a plain sequential map when
/// `workers <= 1` or the input is tiny (avoids thread-spawn overhead on the
/// 1-vCPU benchmark box).
pub fn parallel_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if workers <= 1 || n <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let workers = workers.min(n);
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

/// Chunked parallel fold: split `items` into `workers` contiguous chunks, run
/// `fold` per chunk, then `reduce` pairwise. Used for count-table extraction
/// where merging per-worker tables once is far cheaper than locking a shared
/// table per item.
pub fn parallel_fold<T, A, FF, RF>(items: &[T], workers: usize, fold: FF, reduce: RF) -> Option<A>
where
    T: Sync,
    A: Send,
    FF: Fn(&[T]) -> A + Sync,
    RF: Fn(A, A) -> A,
{
    if items.is_empty() {
        return None;
    }
    if workers <= 1 || items.len() == 1 {
        return Some(fold(items));
    }
    let workers = workers.min(items.len());
    let chunk = items.len().div_ceil(workers);
    let chunks: Vec<&[T]> = items.chunks(chunk).collect();
    let parts = parallel_map(&chunks, workers, |_, c| fold(c));
    parts.into_iter().reduce(reduce)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = parallel_map(&items, 4, |_, &x| x * x);
        assert_eq!(out, items.iter().map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_sequential_fallback() {
        let items = vec![1, 2, 3];
        assert_eq!(parallel_map(&items, 1, |i, &x| x + i), vec![1, 3, 5]);
    }

    #[test]
    fn fold_matches_sequential() {
        let items: Vec<u64> = (1..=1000).collect();
        let total = parallel_fold(
            &items,
            8,
            |c| c.iter().sum::<u64>(),
            |a, b| a + b,
        )
        .unwrap();
        assert_eq!(total, 500_500);
    }

    #[test]
    fn fold_empty_is_none() {
        let items: Vec<u64> = vec![];
        assert!(parallel_fold(&items, 4, |c| c.len(), |a, b| a + b).is_none());
    }

    #[test]
    fn borrows_without_arc() {
        let data = vec![String::from("a"), String::from("bb")];
        let lens = parallel_map(&data, 2, |_, s| s.len());
        assert_eq!(lens, vec![1, 2]);
    }
}
