//! A vendored, dependency-free read-only memory map.
//!
//! The tiered model store spills cold containers to disk and reloads them on
//! demand; because [`crate::compress::container::ParsedContainer`] only
//! records `(offset, len)` spans into its buffer, an `mmap`-backed buffer
//! makes the reload **zero-copy**: the header parse touches a few pages, and
//! payload bytes are paged in by the kernel on first decode — no `read`, no
//! payload memcpy.
//!
//! No `libc` crate exists in the offline build image, so the wrapper
//! declares the two syscall shims (`mmap`/`munmap`) directly; `std` already
//! links the platform C library on every supported target. The FFI path is
//! gated to 64-bit unix (where `off_t` is `i64`); everywhere else
//! [`Mmap::map_path`] degrades to reading the file into an owned buffer —
//! same API, same semantics, one copy.

use anyhow::{bail, Context, Result};
use std::path::Path;

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use std::ffi::c_void;

    pub const PROT_READ: i32 = 0x1;
    pub const MAP_PRIVATE: i32 = 0x2;

    pub fn map_failed() -> *mut c_void {
        usize::MAX as *mut c_void
    }

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

enum Inner {
    /// A live kernel mapping (read-only, private). Unmapped on drop.
    #[cfg(all(unix, target_pointer_width = "64"))]
    Mapped { ptr: *const u8, len: usize },
    /// Fallback for targets without the FFI path, and for empty files
    /// (`mmap` of zero bytes is an error by spec).
    Owned(Box<[u8]>),
}

/// A read-only view of a file's bytes, memory-mapped where the platform
/// allows it.
///
/// The mapping is private and immutable, so sharing it across threads is
/// sound; on unix the bytes stay valid even if the file is unlinked while
/// mapped (the store unlinks spill files the moment they reload).
pub struct Mmap {
    inner: Inner,
}

// SAFETY: the mapping is PROT_READ/MAP_PRIVATE — no &self method can
// mutate the bytes, and the kernel keeps the pages alive until munmap.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map `path` read-only. Empty files yield an empty (unmapped) buffer.
    pub fn map_path(path: &Path) -> Result<Mmap> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let len = file
            .metadata()
            .with_context(|| format!("stat {}", path.display()))?
            .len();
        if len == 0 {
            return Ok(Mmap { inner: Inner::Owned(Vec::new().into_boxed_slice()) });
        }
        let Ok(len) = usize::try_from(len) else {
            bail!("{} is too large to map ({len} bytes)", path.display());
        };
        Self::map_file(&file, len, path)
    }

    #[cfg(all(unix, target_pointer_width = "64"))]
    fn map_file(file: &std::fs::File, len: usize, path: &Path) -> Result<Mmap> {
        use std::os::unix::io::AsRawFd;
        // SAFETY: fd is open for reading, len matches the file size read
        // above, and we never hand out the pointer beyond `len`; the fd may
        // close after mmap returns — the mapping holds its own reference.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == sys::map_failed() {
            bail!(
                "mmap of {} ({len} bytes) failed: {}",
                path.display(),
                std::io::Error::last_os_error()
            );
        }
        Ok(Mmap { inner: Inner::Mapped { ptr: ptr as *const u8, len } })
    }

    #[cfg(not(all(unix, target_pointer_width = "64")))]
    fn map_file(file: &std::fs::File, len: usize, path: &Path) -> Result<Mmap> {
        use std::io::Read;
        let mut buf = Vec::with_capacity(len);
        let mut f = file;
        f.read_to_end(&mut buf)
            .with_context(|| format!("reading {}", path.display()))?;
        Ok(Mmap { inner: Inner::Owned(buf.into_boxed_slice()) })
    }

    /// Whether this buffer is a live kernel mapping (false on the owned
    /// fallback path) — the zero-copy acceptance checks assert on this.
    pub fn is_mapped(&self) -> bool {
        match &self.inner {
            #[cfg(all(unix, target_pointer_width = "64"))]
            Inner::Mapped { .. } => true,
            Inner::Owned(_) => false,
        }
    }

    /// Mapped (or owned) length in bytes.
    pub fn len(&self) -> usize {
        match &self.inner {
            #[cfg(all(unix, target_pointer_width = "64"))]
            Inner::Mapped { len, .. } => *len,
            Inner::Owned(b) => b.len(),
        }
    }

    /// Whether the buffer is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The mapped bytes.
    pub fn as_slice(&self) -> &[u8] {
        match &self.inner {
            #[cfg(all(unix, target_pointer_width = "64"))]
            // SAFETY: ptr/len came from a successful mmap that lives until
            // drop; the mapping is never mutated.
            Inner::Mapped { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
            Inner::Owned(b) => b,
        }
    }
}

impl std::ops::Deref for Mmap {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        if let Inner::Mapped { ptr, len } = &self.inner {
            // SAFETY: exactly the region mmap returned; dropped once.
            unsafe {
                sys::munmap(*ptr as *mut std::ffi::c_void, *len);
            }
        }
    }
}

impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mmap")
            .field("len", &self.len())
            .field("mapped", &self.is_mapped())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn temp_file(contents: &[u8]) -> std::path::PathBuf {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let path = std::env::temp_dir().join(format!(
            "rfc-mmap-test-{}-{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&path, contents).unwrap();
        path
    }

    #[test]
    fn maps_file_contents_exactly() {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i * 7) as u8).collect();
        let path = temp_file(&data);
        let map = Mmap::map_path(&path).unwrap();
        assert_eq!(&map[..], &data[..]);
        assert_eq!(map.len(), data.len());
        assert!(!map.is_empty());
        #[cfg(all(unix, target_pointer_width = "64"))]
        assert!(map.is_mapped(), "64-bit unix must take the real mmap path");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn unlinked_file_stays_readable_while_mapped() {
        // the store deletes spill files as soon as they reload; the mapping
        // must keep serving the bytes
        let data = vec![0xabu8; 4096 * 3 + 17];
        let path = temp_file(&data);
        let map = Mmap::map_path(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        assert_eq!(&map[..], &data[..]);
    }

    #[test]
    fn empty_file_maps_to_empty_slice() {
        let path = temp_file(b"");
        let map = Mmap::map_path(&path).unwrap();
        assert!(map.is_empty());
        assert_eq!(&map[..], b"");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn missing_file_is_a_typed_error() {
        let path = std::env::temp_dir().join("rfc-mmap-test-definitely-missing");
        assert!(Mmap::map_path(&path).is_err());
    }
}
