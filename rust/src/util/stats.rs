//! Small statistics helpers used by the forest builder, the lossy-compression
//! theory (§7 of the paper), and the benchmark harness.

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    /// An empty accumulator.
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold one sample in (Welford update).
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of samples folded in.
    pub fn count(&self) -> u64 {
        self.n
    }
    /// Sample mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }
    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    /// Sample (Bessel-corrected) variance.
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
    /// Smallest sample seen.
    pub fn min(&self) -> f64 {
        self.min
    }
    /// Largest sample seen.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator (parallel reduction; Chan et al.).
    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Mean squared error between predictions and targets.
pub fn mse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter()
        .zip(truth)
        .map(|(p, t)| (p - t) * (p - t))
        .sum::<f64>()
        / pred.len() as f64
}

/// Misclassification rate between predicted and true labels.
pub fn misclassification(pred: &[u32], truth: &[u32]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter().zip(truth).filter(|(p, t)| p != t).count() as f64 / pred.len() as f64
}

/// Exact quantile by sorting a copy (fine for bench-sized vectors).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    assert!((0.0..=1.0).contains(&q));
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = pos - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Pretty-print a byte count the way the paper reports sizes (MB with two
/// decimals, or KB below 0.1 MB).
pub fn human_bytes(bytes: u64) -> String {
    let mb = bytes as f64 / (1024.0 * 1024.0);
    if mb >= 0.1 {
        format!("{mb:.2} MB")
    } else {
        format!("{:.1} KB", bytes as f64 / 1024.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!((s.mean() - mean).abs() < 1e-12);
        assert!((s.variance() - var).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
        assert_eq!(s.count(), 5);
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = OnlineStats::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-10);
        assert!((a.variance() - whole.variance()).abs() < 1e-10);
    }

    #[test]
    fn mse_and_misclass() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 4.0]), 2.0);
        assert_eq!(misclassification(&[1, 0, 1, 1], &[1, 1, 1, 0]), 0.5);
    }

    #[test]
    fn quantile_endpoints_and_median() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
    }

    #[test]
    fn human_bytes_formats() {
        assert_eq!(human_bytes(12 * 1024 * 1024), "12.00 MB");
        assert_eq!(human_bytes(11 * 1024), "11.0 KB");
    }
}
