//! The closed-form rate/distortion bounds of §7, used by the Figure-2/3
//! benches to overlay predicted curves on measured ones.

/// Subsampling distortion (eq. 7, exact form):
///
/// ```text
/// D(A, A₀, σ²) = σ²·|A₀|·(1/|A₀| + 1/|A|)² + σ²·(|A|−|A₀|)/|A|²
/// ```
pub fn subsample_distortion_exact(a: usize, a0: usize, sigma2: f64) -> f64 {
    let a = a as f64;
    let a0f = a0 as f64;
    sigma2 * a0f * (1.0 / a0f + 1.0 / a).powi(2) + sigma2 * (a - a0f) / (a * a)
}

/// Subsampling distortion, the `|A₀| ≪ |A|` approximation:
/// `σ²/|A₀| + σ²/|A|`.
pub fn subsample_distortion_approx(a: usize, a0: usize, sigma2: f64) -> f64 {
    sigma2 / a0 as f64 + sigma2 / a as f64
}

/// Accuracy-loss *beyond* the full forest: the `σ²/|A₀|` term the paper
/// identifies as the real cost of sampling (the `σ²/|A|` part is the ground
/// truth's own variance).
pub fn subsample_excess_variance(a0: usize, sigma2: f64) -> f64 {
    sigma2 / a0 as f64
}

/// Quantization distortion under the uniform-error model: a `b`-bit uniform
/// quantizer over a range of size `2^r` has cell `2^{r-b}` and per-value MSE
/// `Δ²/12 = 2^{2(r−b)}/12`.
pub fn quantization_mse(range: f64, bits: u32) -> f64 {
    if range <= 0.0 {
        return 0.0;
    }
    let delta = range / (1u64 << bits) as f64;
    delta * delta / 12.0
}

/// The paper's combined average accuracy-loss bound after subsampling
/// `a0 ≪ a` trees and quantizing fits with `b` bits over a `2^r`-sized
/// range:
///
/// ```text
/// σ²/|A₀| + (2^{−(b−r)})² / (12·|A₀|)
/// ```
pub fn combined_loss_bound(a0: usize, sigma2: f64, range: f64, bits: u32) -> f64 {
    subsample_excess_variance(a0, sigma2) + quantization_mse(range, bits) / a0 as f64
}

/// Average compression-gain factors (paper §7): fits shrink by `b/64`,
/// the whole ensemble additionally by `|A₀|/|A|`.
pub fn compression_gain(a: usize, a0: usize, bits: u32) -> (f64, f64) {
    (bits as f64 / 64.0, a0 as f64 / a as f64)
}

/// Per-value MSE bound for a round-to-nearest float-narrowing convert
/// stage ([`crate::coding::stage::StageSpec::ConvertF64F32`] /
/// [`ConvertF64Bf16`][crate::coding::stage::StageSpec::ConvertF64Bf16]):
///
/// ```text
/// (vmax · 2^{−(m+1)})² + (2^{min_subnormal_log2} / 2)²
/// ```
///
/// The first term is the half-ULP relative rounding error over the normal
/// range (`m` target mantissa bits, `vmax` the largest magnitude in the
/// section); the second is the absolute error floor from the target's
/// subnormal grid — values below it flush toward zero, so the bound holds
/// on subnormal-heavy inputs too.
pub fn convert_mse_bound(vmax: f64, mantissa_bits: u32, min_subnormal_log2: i32) -> f64 {
    let rel = vmax * 2f64.powi(-(mantissa_bits as i32 + 1));
    let sub = 2f64.powi(min_subnormal_log2) / 2.0;
    rel * rel + sub * sub
}

/// The [`convert_mse_bound`] parameters of a lossy stage (`None` for
/// lossless stages): f32 keeps 23 mantissa bits with subnormals down to
/// 2⁻¹⁴⁹; bfloat16 keeps 7 with subnormals down to 2⁻¹³³.
pub fn stage_mse_bound(spec: &crate::coding::stage::StageSpec, vmax: f64) -> Option<f64> {
    use crate::coding::stage::StageSpec;
    match spec {
        StageSpec::ConvertF64F32 => Some(convert_mse_bound(vmax, 23, -149)),
        StageSpec::ConvertF64Bf16 => Some(convert_mse_bound(vmax, 7, -133)),
        _ => None,
    }
}

/// MSE bound for a whole chain: the worst lossy stage's bound, or `None`
/// for a fully lossless chain (zero distortion).
pub fn chain_mse_bound(chain: &[crate::coding::stage::StageSpec], vmax: f64) -> Option<f64> {
    chain
        .iter()
        .filter_map(|s| stage_mse_bound(s, vmax))
        .max_by(|a, b| a.partial_cmp(b).unwrap())
}

/// Estimate the single-tree prediction-error variance σ² from a forest's
/// per-tree test predictions: the variance across trees of their mean error
/// against the full-forest prediction (the paper's `e_t` construction).
pub fn estimate_sigma2(per_tree_means: &[f64]) -> f64 {
    if per_tree_means.len() < 2 {
        return 0.0;
    }
    let n = per_tree_means.len() as f64;
    let mean = per_tree_means.iter().sum::<f64>() / n;
    per_tree_means.iter().map(|e| (e - mean) * (e - mean)).sum::<f64>() / (n - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_approaches_approx_when_a0_small() {
        let exact = subsample_distortion_exact(10_000, 10, 2.0);
        let approx = subsample_distortion_approx(10_000, 10, 2.0);
        assert!((exact / approx - 1.0).abs() < 0.01, "exact={exact} approx={approx}");
    }

    #[test]
    fn distortion_decreases_with_more_trees() {
        let mut prev = f64::INFINITY;
        for a0 in [10, 50, 100, 500, 1000] {
            let d = subsample_distortion_approx(1000, a0, 1.0);
            assert!(d < prev);
            prev = d;
        }
    }

    #[test]
    fn quantization_mse_halves_per_bit_squared() {
        let m8 = quantization_mse(1.0, 8);
        let m9 = quantization_mse(1.0, 9);
        assert!((m8 / m9 - 4.0).abs() < 1e-9, "one more bit ⇒ ¼ the MSE");
    }

    #[test]
    fn combined_bound_dominated_by_sigma_term_at_high_bits() {
        let loss = combined_loss_bound(250, 0.5, 10.0, 16);
        let sigma_term = subsample_excess_variance(250, 0.5);
        assert!((loss / sigma_term - 1.0).abs() < 1e-3);
    }

    #[test]
    fn gains_match_paper_formulas() {
        let (fit_gain, ens_gain) = compression_gain(1000, 250, 7);
        assert!((fit_gain - 7.0 / 64.0).abs() < 1e-12);
        assert!((ens_gain - 0.25).abs() < 1e-12);
    }

    #[test]
    fn convert_bound_holds_on_actual_conversions() {
        use crate::coding::stage::{BufferList, Stage, StageSpec};
        // a spread of magnitudes including subnormal-range values
        let vals: Vec<f64> = (0..4000)
            .map(|i| {
                let x = (i as f64 - 2000.0) / 37.0;
                x * (1.5f64).powf(x.rem_euclid(20.0)) * 1e-3
            })
            .collect();
        let vmax = vals.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        let mut bytes = Vec::new();
        for v in &vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for spec in [StageSpec::ConvertF64F32, StageSpec::ConvertF64Bf16] {
            let st = spec.build();
            let enc = st.encode(&BufferList::from_single(bytes.clone())).unwrap();
            let dec = st.decode(&enc).unwrap().into_single().unwrap();
            let mse: f64 = dec
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .zip(&vals)
                .map(|(d, v)| (d - v) * (d - v))
                .sum::<f64>()
                / vals.len() as f64;
            let bound = stage_mse_bound(&spec, vmax).unwrap();
            assert!(mse <= bound, "{spec:?}: measured MSE {mse} exceeds bound {bound}");
        }
    }

    #[test]
    fn chain_bound_picks_the_worst_stage() {
        use crate::coding::stage::StageSpec;
        let chain = [StageSpec::ConvertF64Bf16, StageSpec::Lzss];
        let b = chain_mse_bound(&chain, 10.0).unwrap();
        assert_eq!(b, convert_mse_bound(10.0, 7, -133));
        assert!(chain_mse_bound(&[StageSpec::Lzss], 10.0).is_none());
        // bf16 bound dominates f32 at equal vmax
        assert!(convert_mse_bound(1.0, 7, -133) > convert_mse_bound(1.0, 23, -149));
    }

    #[test]
    fn sigma2_estimator_matches_sample_variance() {
        let e = [1.0, 2.0, 3.0, 4.0];
        let s2 = estimate_sigma2(&e);
        // sample variance of 1..4 = 5/3
        assert!((s2 - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(estimate_sigma2(&[1.0]), 0.0);
    }
}
