//! Lossy compression (paper §7): tree subsampling and fit quantization,
//! each a *forest transform* followed by the ordinary lossless codec — which
//! is exactly the paper's construction and what gives it controllable,
//! theoretically bounded distortion (unlike the pruning/mimicking schemes of
//! §1.1).
//!
//! * [`subsample`] — draw `|A₀|` of the `|A|` trees; accuracy loss is
//!   bounded by `σ²/|A₀| + σ²/|A|` (eq. 7)
//! * [`quantize`]  — re-grid the numeric fits to `b` bits (uniform, dithered
//!   uniform, or Lloyd–Max); distortion `2^{-2(b-r)}/12` per fit under the
//!   uniform-error model
//! * [`theory`]    — the closed-form bounds of §7, used by the benches to
//!   overlay predicted vs measured rate–distortion curves

pub mod quantize;
pub mod subsample;
pub mod theory;

pub use quantize::{lloyd_max_quantizer, quantize_fits, QuantizeMethod, Quantizer};
pub use subsample::subsample_trees;
