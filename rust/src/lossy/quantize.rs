//! Fit quantization (paper §7, second adjustment; §3.3).
//!
//! Numeric fits are re-gridded to `2^b` representative values before the
//! (otherwise unchanged) lossless pipeline runs. Besides the direct 64→b bit
//! saving per *distinct* fit value in the table, collapsing fits onto a
//! small grid makes the fit symbol streams low-entropy, which the entropy
//! coder then exploits — the paper's Figure 2/3 size curves combine both
//! effects.
//!
//! Three methods:
//! * uniform        — `2^b` points evenly placed over the observed range
//!   (the paper's "naive b-bit quantization" with its clean distortion
//!   analysis)
//! * dithered       — uniform grid, but each value is offset by a shared
//!   subtractive dither before rounding (Schuchman 1964): the quantization
//!   error becomes uniform and signal-independent, matching the paper's
//!   distortion model assumptions exactly
//! * Lloyd–Max      — distribution-optimal scalar quantizer (Lloyd 1982),
//!   the paper's suggested "more adequate frequency based" refinement

use crate::forest::{Fit, Forest};
use crate::util::Pcg64;
use anyhow::{bail, Result};

/// Quantization method.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantizeMethod {
    /// Uniform mid-rise quantizer over the value range.
    Uniform,
    /// Subtractive dither with the given seed.
    Dithered {
        /// RNG seed of the dither sequence.
        seed: u64,
    },
    /// Lloyd-Max (MSE-optimal representative placement).
    LloydMax,
}

/// A fitted scalar quantizer: maps any f64 to the nearest representative.
#[derive(Debug, Clone)]
pub struct Quantizer {
    /// Sorted representative values (≤ 2^b).
    pub levels: Vec<f64>,
    /// Dither offset applied before snapping (0 for undithered).
    dither: f64,
}

impl Quantizer {
    /// Snap a value to its representative.
    pub fn quantize(&self, x: f64) -> f64 {
        let x = x + self.dither;
        let i = match self
            .levels
            .binary_search_by(|l| l.partial_cmp(&x).unwrap())
        {
            Ok(i) => i,
            Err(0) => 0,
            Err(i) if i == self.levels.len() => self.levels.len() - 1,
            Err(i) => {
                if (x - self.levels[i - 1]).abs() <= (self.levels[i] - x).abs() {
                    i - 1
                } else {
                    i
                }
            }
        };
        self.levels[i] - self.dither
    }

    /// Mean squared quantization error over a sample.
    pub fn mse(&self, xs: &[f64]) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        xs.iter()
            .map(|&x| {
                let q = self.quantize(x);
                (x - q) * (x - q)
            })
            .sum::<f64>()
            / xs.len() as f64
    }
}

/// Build a uniform `b`-bit quantizer over `[lo, hi]`.
pub fn uniform_quantizer(lo: f64, hi: f64, bits: u32) -> Result<Quantizer> {
    if bits == 0 || bits > 24 {
        bail!("quantizer bits must be in 1..=24");
    }
    if !(lo.is_finite() && hi.is_finite()) || lo > hi {
        bail!("invalid range [{lo}, {hi}]");
    }
    let n = 1usize << bits;
    let levels = if hi == lo {
        vec![lo]
    } else {
        // midpoints of 2^b equal cells
        let w = (hi - lo) / n as f64;
        (0..n).map(|i| lo + w * (i as f64 + 0.5)).collect()
    };
    Ok(Quantizer { levels, dither: 0.0 })
}

/// Build a Lloyd–Max quantizer from data (k-means in 1-D, initialized on
/// quantiles; converges to the MSE-optimal scalar quantizer for the sample).
pub fn lloyd_max_quantizer(xs: &[f64], bits: u32) -> Result<Quantizer> {
    if bits == 0 || bits > 24 {
        bail!("quantizer bits must be in 1..=24");
    }
    if xs.is_empty() {
        bail!("no data");
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = 1usize << bits;
    let k = n.min(sorted.len());
    // quantile init
    let mut levels: Vec<f64> = (0..k)
        .map(|i| sorted[(i * sorted.len() + sorted.len() / 2) / k.max(1)])
        .collect();
    levels.dedup();
    for _ in 0..60 {
        // assignment boundaries are midpoints; centroid update via prefix sums
        let mut sums = vec![0.0f64; levels.len()];
        let mut counts = vec![0usize; levels.len()];
        let mut li = 0usize;
        for &x in &sorted {
            while li + 1 < levels.len() && (levels[li] + levels[li + 1]) / 2.0 < x {
                li += 1;
            }
            sums[li] += x;
            counts[li] += 1;
        }
        let mut changed = false;
        for i in 0..levels.len() {
            if counts[i] > 0 {
                let c = sums[i] / counts[i] as f64;
                if (c - levels[i]).abs() > 1e-12 {
                    levels[i] = c;
                    changed = true;
                }
            }
        }
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        levels.dedup();
        if !changed {
            break;
        }
    }
    Ok(Quantizer { levels, dither: 0.0 })
}

/// Quantize every numeric fit in a forest; returns the transformed forest
/// and the quantizer used. Classification forests are returned unchanged
/// (their fits are already a finite alphabet, §3.3).
pub fn quantize_fits(
    forest: &Forest,
    bits: u32,
    method: QuantizeMethod,
) -> Result<(Forest, Option<Quantizer>)> {
    if forest.classification {
        return Ok((forest.clone(), None));
    }
    // collect fit range / values
    let mut vals = Vec::new();
    for t in &forest.trees {
        for n in &t.nodes {
            if let Fit::Regression(v) = n.fit {
                vals.push(v);
            }
        }
    }
    if vals.is_empty() {
        bail!("regression forest with no fits");
    }
    let lo = vals.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let q = match method {
        QuantizeMethod::Uniform => uniform_quantizer(lo, hi, bits)?,
        QuantizeMethod::Dithered { seed } => {
            let mut rng = Pcg64::with_stream(seed, 0xd17);
            let cell = if hi > lo { (hi - lo) / (1u64 << bits) as f64 } else { 0.0 };
            let mut quant = uniform_quantizer(lo, hi, bits)?;
            // subtractive dither uniform over one cell
            quant.dither = (rng.gen_f64() - 0.5) * cell;
            quant
        }
        QuantizeMethod::LloydMax => lloyd_max_quantizer(&vals, bits)?,
    };
    let mut out = forest.clone();
    for t in out.trees.iter_mut() {
        for n in t.nodes.iter_mut() {
            if let Fit::Regression(v) = n.fit {
                n.fit = Fit::Regression(q.quantize(v));
            }
        }
    }
    Ok((out, Some(q)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::forest::ForestParams;

    #[test]
    fn uniform_error_bounded_by_half_cell() {
        let q = uniform_quantizer(0.0, 1.0, 4).unwrap();
        assert_eq!(q.levels.len(), 16);
        let cell = 1.0 / 16.0;
        for i in 0..=100 {
            let x = i as f64 / 100.0;
            let e = (x - q.quantize(x)).abs();
            assert!(e <= cell / 2.0 + 1e-12, "x={x} err={e}");
        }
    }

    #[test]
    fn uniform_mse_matches_theory() {
        // uniform input over the range ⇒ MSE ≈ Δ²/12
        let q = uniform_quantizer(0.0, 1.0, 6).unwrap();
        let xs: Vec<f64> = (0..20_000).map(|i| i as f64 / 20_000.0).collect();
        let mse = q.mse(&xs);
        let delta = 1.0 / 64.0;
        let theory = delta * delta / 12.0;
        assert!((mse / theory - 1.0).abs() < 0.05, "mse={mse} theory={theory}");
    }

    #[test]
    fn more_bits_less_error() {
        let xs: Vec<f64> = (0..5000).map(|i| ((i * 37) % 1000) as f64 / 100.0).collect();
        let mut prev = f64::INFINITY;
        for bits in [2u32, 4, 6, 8, 10] {
            let q = uniform_quantizer(0.0, 10.0, bits).unwrap();
            let e = q.mse(&xs);
            assert!(e <= prev + 1e-15, "bits={bits}");
            prev = e;
        }
    }

    #[test]
    fn lloyd_max_beats_uniform_on_skewed_data() {
        // heavily clustered data: Lloyd-Max should allocate levels there
        let mut xs = vec![0.0; 900];
        for i in 0..900 {
            xs[i] = 0.1 + (i % 30) as f64 * 0.0001;
        }
        xs.extend((0..100).map(|i| 100.0 + i as f64 * 0.001));
        let u = uniform_quantizer(0.0, 100.1, 3).unwrap();
        let lm = lloyd_max_quantizer(&xs, 3).unwrap();
        assert!(
            lm.mse(&xs) < u.mse(&xs) * 0.5,
            "lloyd-max {} should beat uniform {}",
            lm.mse(&xs),
            u.mse(&xs)
        );
    }

    #[test]
    fn dithered_error_uniform_and_bounded() {
        let ds = synthetic::airfoil_regression(41);
        let f = Forest::train(&ds, &ForestParams::regression(3), 7);
        let (qf, q) = quantize_fits(&f, 8, QuantizeMethod::Dithered { seed: 3 }).unwrap();
        let q = q.unwrap();
        // collect original & quantized fits
        let mut errs = Vec::new();
        for (t0, t1) in f.trees.iter().zip(&qf.trees) {
            for (n0, n1) in t0.nodes.iter().zip(&t1.nodes) {
                if let (Fit::Regression(a), Fit::Regression(b)) = (n0.fit, n1.fit) {
                    errs.push(b - a);
                }
            }
        }
        let cell = (q.levels[1] - q.levels[0]).abs();
        assert!(errs.iter().all(|e| e.abs() <= cell), "dithered error exceeds one cell");
    }

    #[test]
    fn quantize_forest_reduces_distinct_fits() {
        let ds = synthetic::airfoil_regression(42);
        let f = Forest::train(&ds, &ForestParams::regression(4), 8);
        let distinct = |f: &Forest| {
            let mut set = std::collections::HashSet::new();
            for t in &f.trees {
                for n in &t.nodes {
                    if let Fit::Regression(v) = n.fit {
                        set.insert(v.to_bits());
                    }
                }
            }
            set.len()
        };
        let before = distinct(&f);
        let (qf, _) = quantize_fits(&f, 7, QuantizeMethod::Uniform).unwrap();
        let after = distinct(&qf);
        assert!(after <= 128, "7-bit grid allows at most 128 distinct values, got {after}");
        assert!(after < before);
        // structure untouched
        for (a, b) in f.trees.iter().zip(&qf.trees) {
            assert_eq!(a.nodes.len(), b.nodes.len());
            for (na, nb) in a.nodes.iter().zip(&b.nodes) {
                assert_eq!(na.split, nb.split);
            }
        }
    }

    #[test]
    fn classification_forests_pass_through() {
        let ds = synthetic::iris(43);
        let f = Forest::train(&ds, &ForestParams::classification(3), 9);
        let (qf, q) = quantize_fits(&f, 4, QuantizeMethod::Uniform).unwrap();
        assert!(q.is_none());
        assert!(qf.identical(&f));
    }

    #[test]
    fn degenerate_constant_fits() {
        let q = uniform_quantizer(5.0, 5.0, 8).unwrap();
        assert_eq!(q.levels, vec![5.0]);
        assert_eq!(q.quantize(5.0), 5.0);
    }

    #[test]
    fn invalid_bits_rejected() {
        assert!(uniform_quantizer(0.0, 1.0, 0).is_err());
        assert!(uniform_quantizer(0.0, 1.0, 60).is_err());
        assert!(lloyd_max_quantizer(&[1.0], 0).is_err());
    }
}
