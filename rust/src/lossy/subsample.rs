//! Tree subsampling (paper §7, first adjustment).
//!
//! Because the forest's trees are i.i.d. given the data, a uniformly random
//! subset `A₀ ⊂ A` is itself a valid (smaller) random forest whose extra
//! prediction variance is `σ²/|A₀|` beyond the full ensemble's `σ²/|A|`.
//! Compression gain is linear in `|A₀|/|A|` (every tree compresses to
//! roughly the same size).

use crate::forest::Forest;
use crate::util::Pcg64;

/// Randomly sample `keep` trees (without replacement) into a new forest.
/// `keep` is clamped to `[1, |A|]`. Deterministic in `seed`.
pub fn subsample_trees(forest: &Forest, keep: usize, seed: u64) -> Forest {
    let n = forest.trees.len();
    let keep = keep.clamp(1, n);
    let mut rng = Pcg64::with_stream(seed, 0x5b5);
    let mut idx = rng.sample_indices(n, keep);
    // keep original order: preserves any tree-order-dependent diagnostics
    idx.sort();
    Forest {
        trees: idx.into_iter().map(|i| forest.trees[i].clone()).collect(),
        classification: forest.classification,
        classes: forest.classes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::forest::ForestParams;

    #[test]
    fn subsample_sizes_and_determinism() {
        let ds = synthetic::iris(31);
        let f = Forest::train(&ds, &ForestParams::classification(20), 1);
        let s = subsample_trees(&f, 7, 42);
        assert_eq!(s.num_trees(), 7);
        assert_eq!(s.classification, f.classification);
        let s2 = subsample_trees(&f, 7, 42);
        assert!(s.identical(&s2));
        let s3 = subsample_trees(&f, 7, 43);
        assert!(!s.identical(&s3));
    }

    #[test]
    fn subsample_clamps() {
        let ds = synthetic::iris(32);
        let f = Forest::train(&ds, &ForestParams::classification(5), 2);
        assert_eq!(subsample_trees(&f, 0, 1).num_trees(), 1);
        assert_eq!(subsample_trees(&f, 99, 1).num_trees(), 5);
    }

    #[test]
    fn subsampled_trees_come_from_original() {
        let ds = synthetic::iris(33);
        let f = Forest::train(&ds, &ForestParams::classification(10), 3);
        let s = subsample_trees(&f, 4, 7);
        for t in &s.trees {
            assert!(f.trees.iter().any(|o| o == t));
        }
        // no duplicates (sampling without replacement)
        for i in 0..s.trees.len() {
            for j in i + 1..s.trees.len() {
                assert!(
                    !(s.trees[i] == s.trees[j])
                        || f.trees.iter().filter(|o| **o == s.trees[i]).count() > 1
                );
            }
        }
    }

    #[test]
    fn error_grows_slowly_as_trees_drop() {
        // eq. (7): MSE increase ≈ σ²/|A₀|; with enough trees the degradation
        // from 40 → 20 trees should be modest
        let ds = synthetic::airfoil_regression(34);
        let mut rng = Pcg64::new(4);
        let tt = ds.train_test_split(0.8, &mut rng);
        let f = Forest::train(&tt.train, &ForestParams::regression(40), 5);
        let full_err = f.test_error(&tt.test);
        let half = subsample_trees(&f, 20, 6);
        let half_err = half.test_error(&tt.test);
        assert!(
            half_err < full_err * 1.5 + 1e-9,
            "half forest err {half_err} vs full {full_err}"
        );
    }
}
