//! The XLA-backed [`LloydEngine`]: executes the AOT-compiled
//! `lloyd_step_{M}x{B}x{K}` artifacts on the PJRT CPU client.
//!
//! Padding contract (mirrors `python/compile/model.py`):
//! * rows beyond the real M: `p = 0, w = 0` — contribute nothing;
//! * columns beyond the real B: zero in both `p` and `q`;
//! * clusters beyond the real K: all-zero `q` rows, which the kernel's
//!   log-clamp turns maximally unattractive, so real rows never pick them.
//!
//! The engine computes in f32 (the MXU-native width). Clustering decisions
//! at f32 precision can differ from the f64 native engine on near-ties —
//! harmless for correctness (any clustering is lossless; only the rate
//! moves marginally) and bounded by the integration tests.

use crate::cluster::kmeans::{LloydEngine, LloydStep, NativeEngine};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// One compiled shape bucket.
struct Bucket {
    m: usize,
    b: usize,
    k: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// All compiled artifacts + the PJRT client that owns them.
pub struct XlaRuntime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    buckets: Vec<Bucket>,
}

impl XlaRuntime {
    /// Load every artifact listed in `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {}", manifest.display()))?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let mut buckets = Vec::new();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 4 {
                bail!("bad manifest line {line:?}");
            }
            let (m, b, k) = (
                parts[0].parse::<usize>().context("manifest M")?,
                parts[1].parse::<usize>().context("manifest B")?,
                parts[2].parse::<usize>().context("manifest K")?,
            );
            let path = dir.join(parts[3]);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            buckets.push(Bucket { m, b, k, exe });
        }
        if buckets.is_empty() {
            bail!("manifest lists no artifacts");
        }
        // smallest-capacity-first so bucket search picks the cheapest fit
        buckets.sort_by_key(|b| b.m * b.b * b.k);
        Ok(XlaRuntime { client, buckets })
    }

    /// Load from the default directory ([`super::artifacts_dir`]).
    pub fn load_default() -> Result<Self> {
        Self::load(&super::artifacts_dir())
    }

    /// Number of compiled buckets.
    pub fn bucket_count(&self) -> usize {
        self.buckets.len()
    }

    /// Whether some bucket can hold an (m, b, k) problem *efficiently*.
    ///
    /// Efficiency gate: padding a tiny problem into a huge bucket makes the
    /// artifact arithmetic-bound on zeros (e.g. B=55 padded to 2048 wastes
    /// 37× the FLOPs); such problems run faster on the native engine. A
    /// bucket is eligible when its padded element count is within
    /// `PAD_WASTE_LIMIT`× of the real problem's.
    pub fn fits(&self, m: usize, b: usize, k: usize) -> bool {
        self.find_bucket(m, b, k).is_some()
    }

    fn find_bucket(&self, m: usize, b: usize, k: usize) -> Option<&Bucket> {
        // Buckets up to this size are cheap in absolute terms (≈10 ms on
        // this CPU) and may be used regardless of padding waste; bigger
        // buckets (interpret-mode Pallas loops get expensive) require the
        // real problem to fill a reasonable fraction of them.
        const CHEAP_ELEMS: usize = 512 * 1024;
        const PAD_WASTE_LIMIT: usize = 6;
        let real = (m * b).max(1);
        self.buckets.iter().find(|bu| {
            bu.m >= m
                && bu.b >= b
                && bu.k >= k
                && (bu.m * bu.b <= CHEAP_ELEMS || bu.m * bu.b <= real * PAD_WASTE_LIMIT)
        })
    }

    /// One Lloyd step on the artifact. Inputs are f64 row-major as in
    /// [`LloydEngine::step`]; returns `None` when no bucket fits.
    pub fn try_step(
        &self,
        p: &[f64],
        w: &[f64],
        q: &[f64],
        m: usize,
        b: usize,
        k: usize,
    ) -> Result<Option<LloydStep>> {
        let Some(bucket) = self.find_bucket(m, b, k) else {
            return Ok(None);
        };
        let (bm, bb, bk) = (bucket.m, bucket.b, bucket.k);
        // pad into f32 bucket buffers
        let mut pf = vec![0f32; bm * bb];
        for i in 0..m {
            for j in 0..b {
                pf[i * bb + j] = p[i * b + j] as f32;
            }
        }
        let mut wf = vec![0f32; bm];
        for i in 0..m {
            wf[i] = w[i] as f32;
        }
        let mut qf = vec![0f32; bk * bb];
        for i in 0..k {
            for j in 0..b {
                qf[i * bb + j] = q[i * b + j] as f32;
            }
        }
        let p_lit = xla::Literal::vec1(&pf).reshape(&[bm as i64, bb as i64])?;
        let w_lit = xla::Literal::vec1(&wf);
        let q_lit = xla::Literal::vec1(&qf).reshape(&[bk as i64, bb as i64])?;
        let result = bucket.exe.execute::<xla::Literal>(&[p_lit, w_lit, q_lit])?[0][0]
            .to_literal_sync()?;
        let (assign_l, new_q_l, obj_l) = result.to_tuple3()?;
        let assign_full = assign_l.to_vec::<i32>()?;
        let new_q_full = new_q_l.to_vec::<f32>()?;
        let obj = obj_l.to_vec::<f32>()?;
        // unpad
        let assign: Vec<u32> = assign_full[..m]
            .iter()
            .map(|&a| (a as u32).min(k as u32 - 1))
            .collect();
        let mut new_q = vec![0f64; k * b];
        for i in 0..k {
            for j in 0..b {
                new_q[i * b + j] = new_q_full[i * bb + j] as f64;
            }
        }
        Ok(Some(LloydStep {
            assign,
            new_q,
            objective: obj.first().copied().unwrap_or(0.0) as f64,
        }))
    }
}

/// [`LloydEngine`] that prefers the XLA artifacts and falls back to the
/// native implementation when no bucket fits (or no runtime was loaded).
pub struct HybridEngine {
    runtime: Option<XlaRuntime>,
    native: NativeEngine,
    /// Clustering steps answered by the XLA artifact (bench counter).
    pub xla_steps: u64,
    /// Clustering steps answered by the native fallback (bench counter).
    pub native_steps: u64,
}

impl HybridEngine {
    /// Try to load artifacts; degrade silently to native-only.
    pub fn new() -> Self {
        let runtime = XlaRuntime::load_default().ok();
        HybridEngine { runtime, native: NativeEngine, xla_steps: 0, native_steps: 0 }
    }

    /// Wrap an explicit, already-loaded runtime.
    pub fn with_runtime(runtime: XlaRuntime) -> Self {
        HybridEngine { runtime: Some(runtime), native: NativeEngine, xla_steps: 0, native_steps: 0 }
    }

    /// An engine that never touches the XLA runtime.
    pub fn native_only() -> Self {
        HybridEngine { runtime: None, native: NativeEngine, xla_steps: 0, native_steps: 0 }
    }

    /// Whether an XLA runtime is loaded.
    pub fn has_runtime(&self) -> bool {
        self.runtime.is_some()
    }
}

impl Default for HybridEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl LloydEngine for HybridEngine {
    fn step(
        &mut self,
        p: &[f64],
        w: &[f64],
        q: &[f64],
        m: usize,
        b: usize,
        k: usize,
    ) -> Result<LloydStep> {
        if let Some(rt) = &self.runtime {
            if let Some(step) = rt.try_step(p, w, q, m, b, k)? {
                self.xla_steps += 1;
                return Ok(step);
            }
        }
        self.native_steps += 1;
        self.native.step(p, w, q, m, b, k)
    }

    fn name(&self) -> &'static str {
        if self.runtime.is_some() {
            "hybrid(xla+native)"
        } else {
            "native"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hybrid_without_artifacts_is_native() {
        let mut eng = HybridEngine::native_only();
        assert!(!eng.has_runtime());
        let p = vec![0.9, 0.1, 0.1, 0.9];
        let w = vec![5.0, 5.0];
        let q = vec![0.5, 0.5];
        let s = eng.step(&p, &w, &q, 2, 2, 1).unwrap();
        assert_eq!(s.assign, vec![0, 0]);
        assert_eq!(eng.native_steps, 1);
    }

    // XLA-backed tests live in rust/tests/xla_runtime.rs (they need the
    // artifacts built by `make artifacts`).
}
