//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts (HLO **text**,
//! see `DESIGN.md §8`) and expose them to the coordinator.
//!
//! Python never runs at request time: `make artifacts` lowered the L2 Lloyd
//! step once per shape bucket; this module compiles those artifacts on the
//! `xla` crate's PJRT CPU client and implements the clustering
//! [`LloydEngine`] on top ([`xla_engine`]), padding real problems into the
//! smallest bucket that fits and falling back to the native engine when
//! none does (huge fit alphabets) or when no artifacts are present.

pub mod xla_engine;

pub use xla_engine::{HybridEngine, XlaRuntime};

/// Default artifact directory, overridable with `RF_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("RF_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
