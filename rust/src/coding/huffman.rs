//! Canonical Huffman coding with serializable dictionaries.
//!
//! These are the per-cluster codebooks of Algorithm 1: a cluster centroid
//! `Q_k` defines symbol weights, the Huffman code built from them encodes
//! every sequence assigned to the cluster, and the *dictionary* (the code
//! length table) is what the `α‖Q‖₀` term of eq. (6) pays for.
//!
//! Properties relied on elsewhere:
//! * prefix-free ⇒ symbols are decodable mid-stream (prediction from the
//!   compressed format, paper §5);
//! * lossless for any symbol with a codeword, even when the code was built
//!   from a *different* distribution than the data's (paper §5, citing
//!   Cover & Thomas) — this is why cluster-merged codebooks stay lossless;
//! * canonical form ⇒ the dictionary serializes as code *lengths* only.

use super::bitio::{BitReader, BitWriter};
use anyhow::{bail, Context, Result};

/// Maximum codeword length we allow. Canonical codes over the alphabets we
/// meet stay far below this; the cap bounds the decoder table.
pub const MAX_CODE_LEN: u8 = 58;

/// A canonical Huffman code over symbols `0..n`.
#[derive(Debug, Clone, PartialEq)]
pub struct HuffmanCode {
    /// Code length per symbol; 0 = symbol absent from the codebook.
    lengths: Vec<u8>,
    /// Canonical codeword per symbol (valid where `lengths > 0`).
    codes: Vec<u64>,
}

impl HuffmanCode {
    /// Build from non-negative weights (counts or probabilities). Symbols
    /// with zero weight get **no codeword**; encoding them is an error, which
    /// the pipeline avoids by giving every observed symbol a pseudo-count.
    ///
    /// Edge cases: an alphabet with a single weighted symbol gets a 1-bit
    /// code (Huffman's degenerate case).
    pub fn from_weights(weights: &[f64]) -> Result<Self> {
        let n = weights.len();
        if n == 0 {
            bail!("empty alphabet");
        }
        let active: Vec<usize> = (0..n).filter(|&i| weights[i] > 0.0).collect();
        if active.is_empty() {
            bail!("all weights are zero");
        }
        let mut lengths = vec![0u8; n];
        if active.len() == 1 {
            lengths[active[0]] = 1;
            return Self::from_lengths(lengths);
        }

        // Standard two-queue-free heap construction over (weight, node id).
        #[derive(PartialEq)]
        struct Item(f64, usize);
        impl Eq for Item {}
        impl PartialOrd for Item {
            fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
                Some(self.cmp(o))
            }
        }
        impl Ord for Item {
            fn cmp(&self, o: &Self) -> std::cmp::Ordering {
                // reversed: BinaryHeap is a max-heap and we need min
                o.0.partial_cmp(&self.0)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(o.1.cmp(&self.1))
            }
        }

        let mut heap = std::collections::BinaryHeap::new();
        // internal tree: parent pointers
        let mut parent: Vec<usize> = vec![usize::MAX; active.len()];
        for (node, &sym) in active.iter().enumerate() {
            heap.push(Item(weights[sym], node));
        }
        while heap.len() > 1 {
            let a = heap.pop().unwrap();
            let b = heap.pop().unwrap();
            let id = parent.len();
            parent.push(usize::MAX);
            parent[a.1] = id;
            parent[b.1] = id;
            heap.push(Item(a.0 + b.0, id));
        }
        // Depth of each leaf = code length.
        for (node, &sym) in active.iter().enumerate() {
            let mut d = 0u8;
            let mut cur = node;
            while parent[cur] != usize::MAX {
                cur = parent[cur];
                d += 1;
            }
            lengths[sym] = d.max(1); // single-leaf safety (handled above anyway)
        }
        if lengths.iter().any(|&l| l > MAX_CODE_LEN) {
            bail!("codeword length exceeds MAX_CODE_LEN");
        }
        Self::from_lengths(lengths)
    }

    /// Build the canonical code from a length table (the serialized
    /// dictionary form). Validates the Kraft equality/inequality.
    pub fn from_lengths(lengths: Vec<u8>) -> Result<Self> {
        let active = lengths.iter().filter(|&&l| l > 0).count();
        if active == 0 {
            bail!("no symbols in dictionary");
        }
        // Kraft sum over active symbols must be <= 1 (== 1 for a complete
        // code; a single-symbol code with length 1 gives 1/2, still valid).
        let kraft: f64 = lengths
            .iter()
            .filter(|&&l| l > 0)
            .map(|&l| 2f64.powi(-(l as i32)))
            .sum();
        if kraft > 1.0 + 1e-9 {
            bail!("invalid code lengths: Kraft sum {kraft} > 1");
        }
        // Canonical assignment: sort by (length, symbol).
        let mut order: Vec<usize> = (0..lengths.len()).filter(|&i| lengths[i] > 0).collect();
        order.sort_by_key(|&i| (lengths[i], i));
        let mut codes = vec![0u64; lengths.len()];
        let mut code = 0u64;
        let mut prev_len = 0u8;
        for &sym in &order {
            let l = lengths[sym];
            code <<= l - prev_len;
            codes[sym] = code;
            code += 1;
            prev_len = l;
        }
        Ok(HuffmanCode { lengths, codes })
    }

    /// Alphabet size (including zero-length symbols).
    pub fn alphabet_size(&self) -> usize {
        self.lengths.len()
    }

    /// Code length of a symbol (0 if absent).
    pub fn length(&self, sym: u32) -> u8 {
        self.lengths[sym as usize]
    }

    /// The length table — the dictionary content whose cost eq. (6) charges.
    pub fn lengths(&self) -> &[u8] {
        &self.lengths
    }

    /// Encode one symbol.
    pub fn encode(&self, sym: u32, out: &mut BitWriter) -> Result<()> {
        let l = *self
            .lengths
            .get(sym as usize)
            .context("symbol out of alphabet")?;
        if l == 0 {
            bail!("symbol {sym} has no codeword");
        }
        out.write_bits(self.codes[sym as usize], l);
        Ok(())
    }

    /// Encode a sequence.
    pub fn encode_all(&self, syms: &[u32], out: &mut BitWriter) -> Result<()> {
        for &s in syms {
            self.encode(s, out)?;
        }
        Ok(())
    }

    /// Expected code length under a distribution `p` (bits/symbol); the
    /// quantity the clustering objective trades against dictionary cost.
    pub fn expected_length(&self, p: &[f64]) -> f64 {
        p.iter()
            .zip(&self.lengths)
            .map(|(&pi, &l)| pi * l as f64)
            .sum()
    }

    /// Serialize the dictionary (length table) to a bit stream.
    ///
    /// Format: varint alphabet size, then run-length coded lengths (6 bits
    /// each, runs of equal lengths gamma-coded) — zero lengths are common
    /// (cluster codebooks cover only observed symbols), so this stays small.
    pub fn write_dict(&self, out: &mut BitWriter) {
        out.write_varint(self.lengths.len() as u64);
        let mut i = 0usize;
        while i < self.lengths.len() {
            let l = self.lengths[i];
            let mut run = 1u64;
            while i + (run as usize) < self.lengths.len() && self.lengths[i + run as usize] == l {
                run += 1;
            }
            out.write_bits(l as u64, 6);
            out.write_gamma(run);
            i += run as usize;
        }
    }

    /// Deserialize a dictionary written by [`write_dict`].
    pub fn read_dict(r: &mut BitReader) -> Result<Self> {
        let n = r.read_varint().context("dict: alphabet size")? as usize;
        if n == 0 || n > 100_000_000 {
            bail!("dict: implausible alphabet size {n}");
        }
        let mut lengths = Vec::with_capacity(n);
        while lengths.len() < n {
            let l = r.read_bits(6).context("dict: length")? as u8;
            let run = r.read_gamma().context("dict: run")? as usize;
            if lengths.len() + run > n {
                bail!("dict: run overflows alphabet");
            }
            lengths.extend(std::iter::repeat(l).take(run));
        }
        Self::from_lengths(lengths)
    }

    /// Size in bits of the serialized dictionary.
    pub fn dict_bits(&self) -> u64 {
        let mut w = BitWriter::new();
        self.write_dict(&mut w);
        w.bit_len()
    }

    /// Build the matching decoder.
    pub fn decoder(&self) -> HuffmanDecoder {
        HuffmanDecoder::new(self)
    }
}

/// Table-driven canonical Huffman decoder.
///
/// Uses the canonical first-code/first-symbol arrays: decode walks length by
/// length comparing the accumulated prefix against the canonical interval —
/// O(code length) per symbol with no per-node allocation. A one-shot
/// `fast_table` for short codes (≤ [`FAST_BITS`]) accelerates the common
/// case on the prediction hot path.
#[derive(Debug, Clone)]
pub struct HuffmanDecoder {
    /// first canonical code value at each length (index 1..=MAX)
    first_code: Vec<u64>,
    /// number of codewords at each length
    count: Vec<u64>,
    /// index into `sorted_syms` of the first symbol at each length
    first_sym_idx: Vec<u32>,
    /// symbols sorted by (length, symbol)
    sorted_syms: Vec<u32>,
    max_len: u8,
    /// fast path: prefix of FAST_BITS bits -> (symbol, length) when the code
    /// fits, else (u32::MAX, 0) sentinel.
    fast: Vec<(u32, u8)>,
}

/// Width of the fast decode table (2^FAST_BITS entries).
pub const FAST_BITS: u8 = 10;

impl HuffmanDecoder {
    /// Build the fast-table decoder for a canonical code.
    pub fn new(code: &HuffmanCode) -> Self {
        let max_len = code.lengths.iter().copied().max().unwrap_or(0);
        let mut order: Vec<u32> = (0..code.lengths.len() as u32)
            .filter(|&s| code.lengths[s as usize] > 0)
            .collect();
        order.sort_by_key(|&s| (code.lengths[s as usize], s));

        let mut first_code = vec![0u64; max_len as usize + 2];
        let mut first_sym_idx = vec![0u32; max_len as usize + 2];
        // count of codes per length
        let mut count = vec![0u64; max_len as usize + 1];
        for &l in code.lengths.iter().filter(|&&l| l > 0) {
            count[l as usize] += 1;
        }
        let mut c = 0u64;
        let mut idx = 0u32;
        for l in 1..=max_len as usize {
            first_code[l] = c;
            first_sym_idx[l] = idx;
            c = (c + count[l]) << 1;
            idx += count[l] as u32;
        }

        // fast table
        let fast_len = 1usize << FAST_BITS;
        let mut fast = vec![(u32::MAX, 0u8); fast_len];
        for &sym in &order {
            let l = code.lengths[sym as usize];
            if l <= FAST_BITS {
                let cw = code.codes[sym as usize];
                let shift = FAST_BITS - l;
                let base = (cw << shift) as usize;
                for pad in 0..(1usize << shift) {
                    fast[base | pad] = (sym, l);
                }
            }
        }

        HuffmanDecoder {
            first_code,
            count,
            first_sym_idx,
            sorted_syms: order,
            max_len,
            fast,
        }
    }

    /// Decode one symbol from the reader.
    pub fn decode(&self, r: &mut BitReader) -> Result<u32> {
        // Fast path: peek FAST_BITS bits if available.
        let pos = r.bit_pos();
        if pos + FAST_BITS as u64 <= r.bit_len() {
            let peek = r.read_bits(FAST_BITS).unwrap();
            let (sym, l) = self.fast[peek as usize];
            if sym != u32::MAX {
                r.seek_bits(pos + l as u64);
                return Ok(sym);
            }
            r.seek_bits(pos);
        }
        // Slow path: extend bit by bit; at length l the valid canonical
        // codewords are [first_code[l], first_code[l] + count[l]).
        let mut code = 0u64;
        for l in 1..=self.max_len {
            code = (code << 1) | r.read_bit().context("huffman: eof")? as u64;
            let li = l as usize;
            if self.count[li] > 0
                && code >= self.first_code[li]
                && code < self.first_code[li] + self.count[li]
            {
                let offset = code - self.first_code[li];
                let idx = self.first_sym_idx[li] as u64 + offset;
                return Ok(self.sorted_syms[idx as usize]);
            }
        }
        bail!("huffman: invalid codeword")
    }

    /// Decode exactly `n` symbols.
    pub fn decode_all(&self, r: &mut BitReader, n: usize) -> Result<Vec<u32>> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.decode(r)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(weights: &[f64], seq: &[u32]) {
        let code = HuffmanCode::from_weights(weights).unwrap();
        let mut w = BitWriter::new();
        code.encode_all(seq, &mut w).unwrap();
        let bytes = w.into_bytes();
        let dec = code.decoder();
        let mut r = BitReader::new(&bytes);
        let out = dec.decode_all(&mut r, seq.len()).unwrap();
        assert_eq!(out, seq);
    }

    #[test]
    fn basic_roundtrip() {
        roundtrip(&[5.0, 2.0, 1.0, 1.0], &[0, 1, 2, 3, 0, 0, 1, 2, 3, 3, 0]);
    }

    #[test]
    fn single_symbol_alphabet() {
        roundtrip(&[3.0], &[0, 0, 0, 0]);
    }

    #[test]
    fn two_symbols() {
        roundtrip(&[0.9, 0.1], &[0, 0, 0, 1, 0]);
    }

    #[test]
    fn sparse_alphabet_zero_weights() {
        // symbols 1 and 3 unused
        let code = HuffmanCode::from_weights(&[1.0, 0.0, 2.0, 0.0, 3.0]).unwrap();
        assert_eq!(code.length(1), 0);
        assert_eq!(code.length(3), 0);
        let mut w = BitWriter::new();
        assert!(code.encode(1, &mut w).is_err());
        roundtrip(&[1.0, 0.0, 2.0, 0.0, 3.0], &[0, 2, 4, 4, 0, 2]);
    }

    #[test]
    fn optimality_within_one_bit_of_entropy() {
        // H(X) <= R < H(X)+1 (paper §2.2)
        let p = [0.5, 0.25, 0.125, 0.125];
        let code = HuffmanCode::from_weights(&p).unwrap();
        let r = code.expected_length(&p);
        let h: f64 = p.iter().map(|&x| -x * x.log2()).sum();
        assert!(r >= h - 1e-9, "r={r} h={h}");
        assert!(r < h + 1.0, "r={r} h={h}");
        // dyadic ⇒ exactly optimal
        assert!((r - h).abs() < 1e-9);
    }

    #[test]
    fn kraft_equality_for_complete_code() {
        let code = HuffmanCode::from_weights(&[4.0, 3.0, 2.0, 1.0, 1.0]).unwrap();
        let kraft: f64 = code
            .lengths()
            .iter()
            .filter(|&&l| l > 0)
            .map(|&l| 2f64.powi(-(l as i32)))
            .sum();
        assert!((kraft - 1.0).abs() < 1e-12);
    }

    #[test]
    fn dict_roundtrip() {
        let code = HuffmanCode::from_weights(&[10.0, 0.0, 5.0, 1.0, 1.0, 0.0, 0.5]).unwrap();
        let mut w = BitWriter::new();
        code.write_dict(&mut w);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        let restored = HuffmanCode::read_dict(&mut r).unwrap();
        assert_eq!(code, restored);
    }

    #[test]
    fn decode_with_mismatched_distribution_still_lossless() {
        // Encode data drawn from P with a code built from Q != P: still
        // decodes exactly (paper §5).
        let q = [0.7, 0.1, 0.1, 0.1];
        let code = HuffmanCode::from_weights(&q).unwrap();
        let seq = [3u32, 3, 3, 2, 2, 1, 0, 3, 2, 1, 3]; // skewed toward 3
        let mut w = BitWriter::new();
        code.encode_all(&seq, &mut w).unwrap();
        let bytes = w.into_bytes();
        let out = code
            .decoder()
            .decode_all(&mut BitReader::new(&bytes), seq.len())
            .unwrap();
        assert_eq!(out, seq);
    }

    #[test]
    fn large_skewed_alphabet() {
        let n = 300usize;
        let weights: Vec<f64> = (0..n).map(|i| 1.0 / (i + 1) as f64).collect();
        let seq: Vec<u32> = (0..1000u32).map(|i| (i * 7919) % n as u32).collect();
        roundtrip(&weights, &seq);
    }

    #[test]
    fn prefix_decode_mid_stream() {
        // Decode the k-th symbol after seeking to its known bit offset —
        // the property prediction-from-compressed relies on.
        let weights = [3.0, 2.0, 1.0];
        let code = HuffmanCode::from_weights(&weights).unwrap();
        let seq = [0u32, 2, 1, 1, 0, 2];
        let mut w = BitWriter::new();
        let mut offsets = Vec::new();
        for &s in &seq {
            offsets.push(w.bit_len());
            code.encode(s, &mut w).unwrap();
        }
        let bytes = w.into_bytes();
        let dec = code.decoder();
        for (i, &s) in seq.iter().enumerate() {
            let mut r = BitReader::new(&bytes);
            r.seek_bits(offsets[i]);
            assert_eq!(dec.decode(&mut r).unwrap(), s);
        }
    }

    #[test]
    fn invalid_kraft_rejected() {
        assert!(HuffmanCode::from_lengths(vec![1, 1, 1]).is_err());
    }

    #[test]
    fn empty_and_zero_weight_rejected() {
        assert!(HuffmanCode::from_weights(&[]).is_err());
        assert!(HuffmanCode::from_weights(&[0.0, 0.0]).is_err());
    }

    #[test]
    fn truncated_stream_errors_cleanly() {
        let code = HuffmanCode::from_weights(&[1.0, 1.0, 1.0, 1.0]).unwrap();
        let mut w = BitWriter::new();
        code.encode_all(&[0, 1, 2, 3], &mut w).unwrap();
        let bytes = w.into_bytes();
        // cut off mid-stream: decoding more symbols than encoded must error,
        // not panic (trailing zero padding may decode as a phantom symbol,
        // which the container guards against by storing counts).
        let dec = code.decoder();
        let mut r = BitReader::new(&bytes[..1]);
        let res = dec.decode_all(&mut r, 10);
        assert!(res.is_err());
    }
}
