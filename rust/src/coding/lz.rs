//! LZSS over a bit/byte stream — the paper's structure coder (§3.1).
//!
//! The concatenated Zaks sequences of all trees in a forest are highly
//! repetitive (trees resemble each other near the root), so instead of
//! treating each whole sequence as one symbol from an enormous alphabet, the
//! paper — "inspired by [18]" (Chen & Reif) — runs an LZ coder over the
//! concatenation. We implement LZSS with a hash-chain match finder:
//!
//! * literal  : flag 0 + 8-bit byte
//! * match    : flag 1 + gamma(length-MIN_MATCH+1) + gamma(distance)
//!
//! Gamma codes make short distances/lengths cheap, which matches the Zaks
//! statistics (most matches are recent — trees repeat their neighbours).
//! The Zaks bitstring is packed 8-bits-per-byte before matching, so matches
//! work over byte granularity while literals stay cheap.

use super::bitio::{BitReader, BitWriter};
use anyhow::{bail, Context, Result};

/// Minimum match length (bytes) worth emitting as a reference.
pub const MIN_MATCH: usize = 4;
/// Maximum match length.
pub const MAX_MATCH: usize = 1 << 16;
/// Search window (bytes).
pub const WINDOW: usize = 1 << 20;
/// Hash-chain depth cap: longest chain walked per position.
const MAX_CHAIN: usize = 64;

const HASH_BITS: u32 = 16;
const HASH_SIZE: usize = 1 << HASH_BITS;

#[inline]
fn hash4(data: &[u8], i: usize) -> usize {
    let v = u32::from_le_bytes([data[i], data[i + 1], data[i + 2], data[i + 3]]);
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

/// Compress `data` into the bit stream. Returns compressed bit count.
pub fn compress(data: &[u8], out: &mut BitWriter) -> u64 {
    let start = out.bit_len();
    out.write_varint(data.len() as u64);
    let n = data.len();
    let mut head = vec![usize::MAX; HASH_SIZE];
    let mut prev = vec![usize::MAX; n.max(1)];
    let mut i = 0usize;
    while i < n {
        let mut best_len = 0usize;
        let mut best_dist = 0usize;
        if i + MIN_MATCH <= n {
            let h = hash4(data, i);
            let mut cand = head[h];
            let mut chain = 0usize;
            while cand != usize::MAX && i - cand <= WINDOW && chain < MAX_CHAIN {
                // extend match
                let max_l = (n - i).min(MAX_MATCH);
                let mut l = 0usize;
                while l < max_l && data[cand + l] == data[i + l] {
                    l += 1;
                }
                if l > best_len {
                    best_len = l;
                    best_dist = i - cand;
                    if l >= max_l {
                        break;
                    }
                }
                cand = prev[cand];
                chain += 1;
            }
        }
        if best_len >= MIN_MATCH {
            out.write_bit(true);
            out.write_gamma((best_len - MIN_MATCH + 1) as u64);
            out.write_gamma(best_dist as u64);
            // insert hash entries for every covered position
            let end = i + best_len;
            while i < end {
                if i + MIN_MATCH <= n {
                    let h = hash4(data, i);
                    prev[i] = head[h];
                    head[h] = i;
                }
                i += 1;
            }
        } else {
            out.write_bit(false);
            out.write_bits(data[i] as u64, 8);
            if i + MIN_MATCH <= n {
                let h = hash4(data, i);
                prev[i] = head[h];
                head[h] = i;
            }
            i += 1;
        }
    }
    out.bit_len() - start
}

/// Decompress a stream written by [`compress`].
pub fn decompress(r: &mut BitReader) -> Result<Vec<u8>> {
    let n = r.read_varint().context("lz: length")? as usize;
    if n > (1 << 34) {
        bail!("lz: implausible decompressed length {n}");
    }
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let is_match = r.read_bit().context("lz: flag")?;
        if is_match {
            let len = r.read_gamma().context("lz: match length")? as usize + MIN_MATCH - 1;
            let dist = r.read_gamma().context("lz: distance")? as usize;
            if dist == 0 || dist > out.len() {
                bail!("lz: invalid distance {dist} at {}", out.len());
            }
            if out.len() + len > n {
                bail!("lz: match overruns output");
            }
            let start = out.len() - dist;
            // overlapping copy must be byte-by-byte
            for k in 0..len {
                let b = out[start + k];
                out.push(b);
            }
        } else {
            let b = r.read_bits(8).context("lz: literal")? as u8;
            out.push(b);
        }
    }
    Ok(out)
}

/// One-shot helpers returning owned byte vectors.
pub fn compress_to_bytes(data: &[u8]) -> Vec<u8> {
    let mut w = BitWriter::new();
    compress(data, &mut w);
    w.into_bytes()
}

/// Decompress a buffer produced by [`compress_to_bytes`].
pub fn decompress_from_bytes(bytes: &[u8]) -> Result<Vec<u8>> {
    let mut r = BitReader::new(bytes);
    decompress(&mut r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn roundtrip(data: &[u8]) -> usize {
        let bytes = compress_to_bytes(data);
        let out = decompress_from_bytes(&bytes).unwrap();
        assert_eq!(out, data);
        bytes.len()
    }

    #[test]
    fn empty_and_tiny() {
        roundtrip(b"");
        roundtrip(b"a");
        roundtrip(b"abc");
    }

    #[test]
    fn repetitive_compresses_well() {
        let data: Vec<u8> = b"11110010010010"
            .iter()
            .cycle()
            .take(10_000)
            .copied()
            .collect();
        let c = roundtrip(&data);
        assert!(c < data.len() / 10, "compressed {c} of {}", data.len());
    }

    #[test]
    fn random_data_does_not_explode() {
        let mut rng = Pcg64::new(3);
        let data: Vec<u8> = (0..5000).map(|_| rng.next_u64() as u8).collect();
        let c = roundtrip(&data);
        // literals cost 9 bits/byte + header; bound the expansion
        assert!(c < data.len() * 9 / 8 + 16, "compressed {c} of {}", data.len());
    }

    #[test]
    fn overlapping_match() {
        // classic run: "aaaaa..." forces dist=1 overlapping copies
        let data = vec![b'a'; 1000];
        let c = roundtrip(&data);
        assert!(c < 40);
    }

    #[test]
    fn zaks_like_bitpacked_input() {
        // emulate concatenated Zaks sequences from similar trees
        let mut rng = Pcg64::new(9);
        let mut bits = Vec::new();
        let base: Vec<u8> = (0..200).map(|_| (rng.gen_bool(0.5)) as u8).collect();
        for _ in 0..50 {
            // each "tree" is the base with a few flips
            let mut t = base.clone();
            for _ in 0..5 {
                let i = rng.gen_index(t.len());
                t[i] ^= 1;
            }
            bits.extend_from_slice(&t);
        }
        // pack to bytes
        let mut w = BitWriter::new();
        for &b in &bits {
            w.write_bit(b == 1);
        }
        let packed = w.into_bytes();
        let c = roundtrip(&packed);
        // bit flips land at arbitrary positions, breaking byte-aligned
        // matches; still expect a clear win over the raw packing
        assert!(c < packed.len() * 3 / 5, "compressed {c} of {}", packed.len());
    }

    #[test]
    fn truncated_stream_errors() {
        let data = b"hello world hello world hello world";
        let bytes = compress_to_bytes(data);
        let res = decompress_from_bytes(&bytes[..bytes.len() / 2]);
        assert!(res.is_err());
    }

    #[test]
    fn corrupt_distance_rejected() {
        // craft: length prefix says 10 bytes, then a match with dist > produced
        let mut w = BitWriter::new();
        w.write_varint(10);
        w.write_bit(true); // match
        w.write_gamma(1); // len = MIN_MATCH
        w.write_gamma(5); // dist 5 with empty output -> invalid
        let bytes = w.into_bytes();
        assert!(decompress_from_bytes(&bytes).is_err());
    }
}
