//! Composable transform-stage codec pipeline.
//!
//! The paper's §4 encoder is one fixed four-stage function; its lossy
//! scheme (§5) is explicitly a *family* of rate–distortion trade-offs.
//! This module expresses both as declared chains of composable stages:
//! a [`Stage`] maps a [`BufferList`] (one or more byte buffers) to a
//! [`BufferList`], invertibly for lossless stages and within a
//! [`crate::lossy::theory`]-accounted distortion bound for lossy ones.
//!
//! Stage kinds (wire tag in parentheses):
//!
//! | stage              | tag | kind      | effect                                     |
//! |--------------------|-----|-----------|--------------------------------------------|
//! | `Lzss`             | 0   | entropy   | LZSS over each buffer                      |
//! | `Huffman`          | 1   | entropy   | order-0 byte Huffman, self-framed dict     |
//! | `Arith`            | 2   | entropy   | order-0 byte arithmetic coding             |
//! | `DeltaU64`         | 3   | transform | wrapping delta over LE 64-bit words        |
//! | `XorU64`           | 4   | transform | XOR-diff over LE 64-bit words              |
//! | `ColumnSplit(w)`   | 5   | transform | byte-plane transpose of `w`-byte records   |
//! | `ConvertF64F32`    | 6   | **lossy** | f64 → f32 round-to-nearest                 |
//! | `ConvertF64Bf16`   | 7   | **lossy** | f64 → bfloat16 round-to-nearest-even       |
//!
//! Transform stages are bit-pattern transforms: `DeltaU64`/`XorU64`
//! operate on the raw 64-bit words (any trailing `len % 8` bytes pass
//! through unchanged), so they are exactly invertible on **every** input —
//! NaNs, negative zero, and subnormals included. The lossy converts widen
//! back to f64 on decode, so a decoded chain always yields the section's
//! native f64 byte layout.
//!
//! A chain is serialized into the container header (see
//! [`crate::compress::container`]); [`encode_chain`] / [`decode_chain`]
//! run it forwards/backwards over a section payload.

use crate::coding::arith::{self, FreqModel};
use crate::coding::bitio::{BitReader, BitWriter};
use crate::coding::huffman::HuffmanCode;
use crate::coding::lz;
use anyhow::{bail, Context, Result};

/// Hard cap on the number of stages in one chain (header plausibility
/// bound; real chains are 1–4 stages).
pub const MAX_CHAIN_LEN: usize = 8;

/// An ordered list of byte buffers flowing through a stage chain.
///
/// Most sections enter as a single buffer; [`StageSpec::ColumnSplit`]
/// fans one buffer out into per-byte planes (and merges them back on
/// decode).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BufferList {
    bufs: Vec<Vec<u8>>,
}

impl BufferList {
    /// A list holding one buffer.
    pub fn from_single(buf: Vec<u8>) -> Self {
        BufferList { bufs: vec![buf] }
    }

    /// A list holding the given buffers in order.
    pub fn from_bufs(bufs: Vec<Vec<u8>>) -> Self {
        BufferList { bufs }
    }

    /// Number of buffers.
    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    /// Whether the list holds no buffers.
    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }

    /// Total bytes across all buffers.
    pub fn total_bytes(&self) -> usize {
        self.bufs.iter().map(|b| b.len()).sum()
    }

    /// Iterate over the buffers in order.
    pub fn iter(&self) -> impl Iterator<Item = &Vec<u8>> {
        self.bufs.iter()
    }

    /// Unwrap a single-buffer list (the shape every fully-decoded section
    /// chain must end in).
    pub fn into_single(mut self) -> Result<Vec<u8>> {
        if self.bufs.len() != 1 {
            bail!("expected a single buffer, found {}", self.bufs.len());
        }
        Ok(self.bufs.pop().unwrap())
    }
}

/// One stage of a codec chain: a declared, serializable transform over a
/// [`BufferList`]. `decode` inverts `encode` exactly for lossless stages;
/// lossy stages decode to the nearest representable value (distortion
/// accounted by [`crate::lossy::theory::convert_mse_bound`]).
pub trait Stage {
    /// The serializable description of this stage.
    fn spec(&self) -> StageSpec;
    /// Forward transform.
    fn encode(&self, input: &BufferList) -> Result<BufferList>;
    /// Inverse transform (exact for lossless stages).
    fn decode(&self, input: &BufferList) -> Result<BufferList>;
}

/// Serializable description of one stage (the form stored in the `RFCZ`
/// header). [`StageSpec::build`] instantiates the matching [`Stage`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageSpec {
    /// LZSS over each buffer (tag 0).
    Lzss,
    /// Order-0 byte-level Huffman with a self-framed dictionary (tag 1).
    Huffman,
    /// Order-0 byte-level arithmetic coding (tag 2).
    Arith,
    /// Wrapping delta over little-endian 64-bit words (tag 3).
    DeltaU64,
    /// XOR-diff over little-endian 64-bit words (tag 4).
    XorU64,
    /// Byte-plane transpose of `w`-byte records (tag 5): splits the
    /// mantissa/exponent bytes of numeric arrays into separate planes so a
    /// following entropy stage sees homogeneous distributions.
    ColumnSplit(u8),
    /// Lossy f64 → f32 conversion, round-to-nearest (tag 6).
    ConvertF64F32,
    /// Lossy f64 → bfloat16 conversion, round-to-nearest-even (tag 7).
    ConvertF64Bf16,
}

impl StageSpec {
    /// Whether this stage discards information (§5 lossy compression).
    pub fn is_lossy(&self) -> bool {
        matches!(self, StageSpec::ConvertF64F32 | StageSpec::ConvertF64Bf16)
    }

    /// Short human-readable name (bench reports, CLI chain syntax).
    pub fn name(&self) -> String {
        match self {
            StageSpec::Lzss => "lzss".into(),
            StageSpec::Huffman => "huff".into(),
            StageSpec::Arith => "arith".into(),
            StageSpec::DeltaU64 => "delta".into(),
            StageSpec::XorU64 => "xor".into(),
            StageSpec::ColumnSplit(w) => format!("split{w}"),
            StageSpec::ConvertF64F32 => "f32".into(),
            StageSpec::ConvertF64Bf16 => "bf16".into(),
        }
    }

    /// Instantiate the stage implementation this spec describes.
    pub fn build(&self) -> Box<dyn Stage> {
        match *self {
            StageSpec::Lzss => Box::new(LzssStage),
            StageSpec::Huffman => Box::new(HuffmanStage),
            StageSpec::Arith => Box::new(ArithStage),
            StageSpec::DeltaU64 => Box::new(DeltaStage),
            StageSpec::XorU64 => Box::new(XorStage),
            StageSpec::ColumnSplit(w) => Box::new(ColumnSplitStage { width: w }),
            StageSpec::ConvertF64F32 => Box::new(ConvertF32Stage),
            StageSpec::ConvertF64Bf16 => Box::new(ConvertBf16Stage),
        }
    }

    /// Serialize one spec (tag byte + parameters).
    pub fn write(&self, w: &mut BitWriter) {
        let tag: u64 = match self {
            StageSpec::Lzss => 0,
            StageSpec::Huffman => 1,
            StageSpec::Arith => 2,
            StageSpec::DeltaU64 => 3,
            StageSpec::XorU64 => 4,
            StageSpec::ColumnSplit(_) => 5,
            StageSpec::ConvertF64F32 => 6,
            StageSpec::ConvertF64Bf16 => 7,
        };
        w.write_bits(tag, 8);
        if let StageSpec::ColumnSplit(width) = self {
            w.write_bits(*width as u64, 8);
        }
    }

    /// Deserialize one spec.
    pub fn read(r: &mut BitReader) -> Result<Self> {
        Ok(match r.read_bits(8).context("stage tag")? {
            0 => StageSpec::Lzss,
            1 => StageSpec::Huffman,
            2 => StageSpec::Arith,
            3 => StageSpec::DeltaU64,
            4 => StageSpec::XorU64,
            5 => {
                let w = r.read_bits(8).context("column-split width")? as u8;
                StageSpec::ColumnSplit(w)
            }
            6 => StageSpec::ConvertF64F32,
            7 => StageSpec::ConvertF64Bf16,
            v => bail!("unknown stage tag {v}"),
        })
    }
}

/// Serialize a chain: varint stage count, then each spec.
pub fn write_chain(chain: &[StageSpec], w: &mut BitWriter) {
    w.write_varint(chain.len() as u64);
    for s in chain {
        s.write(w);
    }
}

/// Deserialize a chain (bounded by [`MAX_CHAIN_LEN`]).
pub fn read_chain(r: &mut BitReader) -> Result<Vec<StageSpec>> {
    let n = r.read_varint().context("chain length")?;
    if n > MAX_CHAIN_LEN as u64 {
        bail!("implausible chain length {n}");
    }
    (0..n).map(|_| StageSpec::read(r)).collect()
}

/// `"delta+lzss"`-style label for bench reports; the default (empty)
/// chain prints as `"-"`.
pub fn chain_label(chain: &[StageSpec]) -> String {
    if chain.is_empty() {
        return "-".into();
    }
    chain.iter().map(|s| s.name()).collect::<Vec<_>>().join("+")
}

/// Parse a `"delta+lzss"` / `"delta,lzss"` chain label (the CLI syntax;
/// see [`chain_label`] for the stage names).
pub fn parse_chain(s: &str) -> Result<Vec<StageSpec>> {
    let s = s.trim();
    if s.is_empty() || s == "-" {
        return Ok(Vec::new());
    }
    s.split(['+', ','])
        .map(|part| {
            Ok(match part.trim() {
                "lzss" => StageSpec::Lzss,
                "huff" => StageSpec::Huffman,
                "arith" => StageSpec::Arith,
                "delta" => StageSpec::DeltaU64,
                "xor" => StageSpec::XorU64,
                "split2" => StageSpec::ColumnSplit(2),
                "split4" => StageSpec::ColumnSplit(4),
                "split8" => StageSpec::ColumnSplit(8),
                "f32" => StageSpec::ConvertF64F32,
                "bf16" => StageSpec::ConvertF64Bf16,
                other => bail!("unknown stage name {other:?}"),
            })
        })
        .collect()
}

/// Structural validation shared by every chain: length cap, sane
/// column-split widths, and the lossy placement rule — converts are only
/// legal as the **first** stage (they reinterpret raw f64 sections), at
/// most one per chain, and only when the caller permits lossy coding at
/// all (`allow_lossy`; regression fit tables only).
pub fn validate_chain(chain: &[StageSpec], allow_lossy: bool) -> Result<()> {
    if chain.len() > MAX_CHAIN_LEN {
        bail!("chain of {} stages exceeds the cap of {MAX_CHAIN_LEN}", chain.len());
    }
    for (i, s) in chain.iter().enumerate() {
        if let StageSpec::ColumnSplit(w) = s {
            if !(2..=16).contains(w) {
                bail!("column-split width {w} outside 2..=16");
            }
        }
        if s.is_lossy() {
            if !allow_lossy {
                bail!("lossy stage {} not permitted in this chain", s.name());
            }
            if i != 0 {
                bail!("lossy stage {} must be the first stage of its chain", s.name());
            }
        }
    }
    if chain.iter().filter(|s| s.is_lossy()).count() > 1 {
        bail!("at most one lossy stage per chain");
    }
    Ok(())
}

/// Whether any stage of the chain is lossy.
pub fn chain_is_lossy(chain: &[StageSpec]) -> bool {
    chain.iter().any(|s| s.is_lossy())
}

/// The per-section stage chains of one container: empty chains mean the
/// fixed legacy pipeline (a version-1 `RFCZ` container, byte-for-byte).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SectionChains {
    /// Chain for the STRUCT section (packed Zaks bits).
    pub structure: Vec<StageSpec>,
    /// Chain for the numeric split-value tables (TABLES section).
    pub split_tables: Vec<StageSpec>,
    /// Chain for the regression fit-value table; the only chain that may
    /// open with a lossy convert (§5 distortion-rate trade).
    pub fit_table: Vec<StageSpec>,
}

impl SectionChains {
    /// Whether every chain is empty (the fixed legacy pipeline).
    pub fn is_default(&self) -> bool {
        self.structure.is_empty() && self.split_tables.is_empty() && self.fit_table.is_empty()
    }

    /// Whether any chain contains a lossy stage.
    pub fn is_lossy(&self) -> bool {
        chain_is_lossy(&self.fit_table)
            || chain_is_lossy(&self.structure)
            || chain_is_lossy(&self.split_tables)
    }

    /// Validate all three chains. Lossy stages are only legal in the fit
    /// chain and only for regression forests (classification fits are
    /// class ids — "rounding" them is meaningless, not a §5 trade).
    pub fn validate(&self, classification: bool) -> Result<()> {
        validate_chain(&self.structure, false).context("structure chain")?;
        validate_chain(&self.split_tables, false).context("split-tables chain")?;
        validate_chain(&self.fit_table, !classification).context("fit-table chain")?;
        Ok(())
    }

    /// Serialize the three chains (the version-2 header extension).
    pub fn write(&self, w: &mut BitWriter) {
        write_chain(&self.structure, w);
        write_chain(&self.split_tables, w);
        write_chain(&self.fit_table, w);
    }

    /// Deserialize the three chains.
    pub fn read(r: &mut BitReader) -> Result<Self> {
        Ok(SectionChains {
            structure: read_chain(r).context("structure chain")?,
            split_tables: read_chain(r).context("split-tables chain")?,
            fit_table: read_chain(r).context("fit-table chain")?,
        })
    }
}

// ------------------------------------------------------------ chain running

/// Run a chain forwards over `input` and serialize the resulting buffer
/// list (varint buffer count, varint lengths, byte-aligned payloads).
pub fn encode_chain(chain: &[StageSpec], input: BufferList) -> Result<Vec<u8>> {
    let mut bufs = input;
    for s in chain {
        bufs = s
            .build()
            .encode(&bufs)
            .with_context(|| format!("stage {} encode", s.name()))?;
    }
    let mut w = BitWriter::new();
    w.write_varint(bufs.len() as u64);
    for b in bufs.iter() {
        w.write_varint(b.len() as u64);
    }
    w.align_byte();
    for b in bufs.iter() {
        w.write_bytes(b);
    }
    Ok(w.into_bytes())
}

/// Parse a serialized buffer list and run the chain backwards over it.
pub fn decode_chain(chain: &[StageSpec], bytes: &[u8]) -> Result<BufferList> {
    let mut r = BitReader::new(bytes);
    let n_raw = r.read_varint().context("buffer count")?;
    if n_raw > (1 << 20) {
        bail!("implausible buffer count {n_raw}");
    }
    let n = n_raw as usize;
    let mut lens = Vec::with_capacity(n);
    let mut total = 0u64;
    for _ in 0..n {
        let l = r.read_varint().context("buffer length")?;
        total = total.checked_add(l).context("buffer length overflow")?;
        if total > (1 << 33) {
            bail!("implausible buffer bytes {total}");
        }
        lens.push(usize::try_from(l).context("buffer length")?);
    }
    r.align_byte();
    let mut bufs = Vec::with_capacity(n);
    for l in lens {
        // capacity capped: a corrupt length claim must error on read, not
        // force a huge allocation first
        let mut b = Vec::with_capacity(l.min(1 << 20));
        for _ in 0..l {
            b.push(r.read_byte().context("buffer payload")?);
        }
        bufs.push(b);
    }
    let mut bufs = BufferList::from_bufs(bufs);
    for s in chain.iter().rev() {
        bufs = s
            .build()
            .decode(&bufs)
            .with_context(|| format!("stage {} decode", s.name()))?;
    }
    Ok(bufs)
}

/// Encode an f64 array (little-endian bytes) through a chain.
pub fn encode_f64_chain(chain: &[StageSpec], vals: &[f64]) -> Result<Vec<u8>> {
    let mut bytes = Vec::with_capacity(vals.len() * 8);
    for v in vals {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    encode_chain(chain, BufferList::from_single(bytes))
}

/// Decode a chain back to an f64 array. Lossy converts widen on decode,
/// so every fit/split chain ends in the native f64 layout.
pub fn decode_f64_chain(chain: &[StageSpec], bytes: &[u8]) -> Result<Vec<f64>> {
    let buf = decode_chain(chain, bytes)?.into_single()?;
    if buf.len() % 8 != 0 {
        bail!("decoded f64 section holds {} bytes (not a multiple of 8)", buf.len());
    }
    Ok(buf
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

// ------------------------------------------------------------------- stages

/// Apply `f` buffer-by-buffer (the shape most stages take).
fn per_buffer(
    input: &BufferList,
    mut f: impl FnMut(&[u8]) -> Result<Vec<u8>>,
) -> Result<BufferList> {
    let mut out = Vec::with_capacity(input.len());
    for b in input.iter() {
        out.push(f(b)?);
    }
    Ok(BufferList::from_bufs(out))
}

/// LZSS over each buffer ([`StageSpec::Lzss`]).
pub struct LzssStage;

impl Stage for LzssStage {
    fn spec(&self) -> StageSpec {
        StageSpec::Lzss
    }

    fn encode(&self, input: &BufferList) -> Result<BufferList> {
        per_buffer(input, |b| Ok(lz::compress_to_bytes(b)))
    }

    fn decode(&self, input: &BufferList) -> Result<BufferList> {
        per_buffer(input, |b| lz::decompress_from_bytes(b))
    }
}

/// Order-0 byte-level Huffman ([`StageSpec::Huffman`]): each buffer is
/// self-framed as `varint len ++ dict ++ codes` (no frame at all for an
/// empty buffer).
pub struct HuffmanStage;

impl Stage for HuffmanStage {
    fn spec(&self) -> StageSpec {
        StageSpec::Huffman
    }

    fn encode(&self, input: &BufferList) -> Result<BufferList> {
        per_buffer(input, |b| {
            let mut w = BitWriter::new();
            w.write_varint(b.len() as u64);
            if !b.is_empty() {
                let mut weights = [0f64; 256];
                for &byte in b {
                    weights[byte as usize] += 1.0;
                }
                let code = HuffmanCode::from_weights(&weights)?;
                code.write_dict(&mut w);
                for &byte in b {
                    code.encode(byte as u32, &mut w)?;
                }
            }
            Ok(w.into_bytes())
        })
    }

    fn decode(&self, input: &BufferList) -> Result<BufferList> {
        per_buffer(input, |b| {
            let mut r = BitReader::new(b);
            let n = r.read_varint().context("huffman stage len")?;
            if n > (1 << 28) {
                bail!("implausible huffman stage length {n}");
            }
            if n == 0 {
                return Ok(Vec::new());
            }
            let code = HuffmanCode::read_dict(&mut r)?;
            let dec = code.decoder();
            let mut out = Vec::with_capacity((n as usize).min(1 << 20));
            for _ in 0..n {
                let sym = dec.decode(&mut r)?;
                if sym > 255 {
                    bail!("huffman stage symbol {sym} out of byte range");
                }
                out.push(sym as u8);
            }
            Ok(out)
        })
    }
}

/// Order-0 byte-level arithmetic coding ([`StageSpec::Arith`]): each
/// buffer is self-framed as `varint len ++ freq model ++ code bits`.
pub struct ArithStage;

impl Stage for ArithStage {
    fn spec(&self) -> StageSpec {
        StageSpec::Arith
    }

    fn encode(&self, input: &BufferList) -> Result<BufferList> {
        per_buffer(input, |b| {
            let mut w = BitWriter::new();
            w.write_varint(b.len() as u64);
            if !b.is_empty() {
                let mut freqs = [0u64; 256];
                for &byte in b {
                    freqs[byte as usize] += 1;
                }
                let model = FreqModel::from_freqs(&freqs)?;
                model.write(&mut w);
                let syms: Vec<u32> = b.iter().map(|&x| x as u32).collect();
                arith::encode_sequence(&model, &syms, &mut w)?;
            }
            Ok(w.into_bytes())
        })
    }

    fn decode(&self, input: &BufferList) -> Result<BufferList> {
        per_buffer(input, |b| {
            let mut r = BitReader::new(b);
            let n = r.read_varint().context("arith stage len")?;
            if n > (1 << 28) {
                bail!("implausible arith stage length {n}");
            }
            if n == 0 {
                return Ok(Vec::new());
            }
            let model = FreqModel::read(&mut r)?;
            let syms = arith::decode_sequence(&model, &mut r, n as usize)?;
            syms.into_iter()
                .map(|s| {
                    if s > 255 {
                        bail!("arith stage symbol {s} out of byte range");
                    }
                    Ok(s as u8)
                })
                .collect()
        })
    }
}

/// Split a buffer into its full little-endian u64 words plus a raw tail
/// (< 8 bytes) that transform stages pass through untouched.
fn le_words(b: &[u8]) -> (Vec<u64>, &[u8]) {
    let n = b.len() / 8;
    let words = b[..n * 8]
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    (words, &b[n * 8..])
}

fn words_to_bytes(words: &[u64], tail: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(words.len() * 8 + tail.len());
    for w in words {
        out.extend_from_slice(&w.to_le_bytes());
    }
    out.extend_from_slice(tail);
    out
}

/// Wrapping delta over LE 64-bit words ([`StageSpec::DeltaU64`]): split
/// tables and fit tables are sorted f64 arrays, so consecutive bit
/// patterns share high bytes and the deltas compress far better. Exactly
/// invertible on every bit pattern (wrapping arithmetic, no float
/// interpretation).
pub struct DeltaStage;

impl Stage for DeltaStage {
    fn spec(&self) -> StageSpec {
        StageSpec::DeltaU64
    }

    fn encode(&self, input: &BufferList) -> Result<BufferList> {
        per_buffer(input, |b| {
            let (words, tail) = le_words(b);
            let mut prev = 0u64;
            let deltas: Vec<u64> = words
                .iter()
                .map(|&w| {
                    let d = w.wrapping_sub(prev);
                    prev = w;
                    d
                })
                .collect();
            Ok(words_to_bytes(&deltas, tail))
        })
    }

    fn decode(&self, input: &BufferList) -> Result<BufferList> {
        per_buffer(input, |b| {
            let (deltas, tail) = le_words(b);
            let mut prev = 0u64;
            let words: Vec<u64> = deltas
                .iter()
                .map(|&d| {
                    prev = prev.wrapping_add(d);
                    prev
                })
                .collect();
            Ok(words_to_bytes(&words, tail))
        })
    }
}

/// XOR-diff over LE 64-bit words ([`StageSpec::XorU64`]): like
/// [`DeltaStage`] but XOR instead of subtraction — zeroes exactly the
/// bits that repeat between neighbours (the FPC/Gorilla trick for
/// slowly-varying floats). Self-inverse per word pair, exactly invertible.
pub struct XorStage;

impl Stage for XorStage {
    fn spec(&self) -> StageSpec {
        StageSpec::XorU64
    }

    fn encode(&self, input: &BufferList) -> Result<BufferList> {
        per_buffer(input, |b| {
            let (words, tail) = le_words(b);
            let mut prev = 0u64;
            let diffs: Vec<u64> = words
                .iter()
                .map(|&w| {
                    let d = w ^ prev;
                    prev = w;
                    d
                })
                .collect();
            Ok(words_to_bytes(&diffs, tail))
        })
    }

    fn decode(&self, input: &BufferList) -> Result<BufferList> {
        per_buffer(input, |b| {
            let (diffs, tail) = le_words(b);
            let mut prev = 0u64;
            let words: Vec<u64> = diffs
                .iter()
                .map(|&d| {
                    prev ^= d;
                    prev
                })
                .collect();
            Ok(words_to_bytes(&words, tail))
        })
    }
}

/// Byte-plane transpose ([`StageSpec::ColumnSplit`]): each input buffer
/// of `w`-byte records becomes `w` plane buffers (plane `j` holds byte
/// `j` of every record). A `len % w` tail is appended to the **last**
/// plane, so any buffer length round-trips. Mantissa bytes land in their
/// own planes — near-uniform high bytes separate from low-entropy
/// sign/exponent bytes, which is what makes a following entropy stage
/// effective (the "mantissa-split" of the module title).
pub struct ColumnSplitStage {
    /// Record width in bytes (2..=16; 8 for f64 sections).
    pub width: u8,
}

impl Stage for ColumnSplitStage {
    fn spec(&self) -> StageSpec {
        StageSpec::ColumnSplit(self.width)
    }

    fn encode(&self, input: &BufferList) -> Result<BufferList> {
        let w = self.width as usize;
        if w == 0 {
            bail!("column-split width 0");
        }
        let mut out = Vec::with_capacity(input.len() * w);
        for b in input.iter() {
            let n = b.len() / w;
            let tail = &b[n * w..];
            for j in 0..w {
                let mut plane = Vec::with_capacity(n + if j == w - 1 { tail.len() } else { 0 });
                for i in 0..n {
                    plane.push(b[i * w + j]);
                }
                if j == w - 1 {
                    plane.extend_from_slice(tail);
                }
                out.push(plane);
            }
        }
        Ok(BufferList::from_bufs(out))
    }

    fn decode(&self, input: &BufferList) -> Result<BufferList> {
        let w = self.width as usize;
        if w == 0 {
            bail!("column-split width 0");
        }
        if input.len() % w != 0 {
            bail!("column-split decode: {} planes not a multiple of width {w}", input.len());
        }
        let planes: Vec<&Vec<u8>> = input.iter().collect();
        let mut out = Vec::with_capacity(input.len() / w);
        for group in planes.chunks_exact(w) {
            let n = group[0].len();
            for (j, p) in group.iter().enumerate().take(w - 1) {
                if p.len() != n {
                    bail!("column-split decode: plane {j} holds {} bytes, expected {n}", p.len());
                }
            }
            let last = group[w - 1];
            if last.len() < n {
                bail!("column-split decode: last plane short ({} < {n})", last.len());
            }
            let tail = &last[n..];
            if tail.len() >= w {
                bail!("column-split decode: tail of {} bytes exceeds width {w}", tail.len());
            }
            let mut buf = Vec::with_capacity(n * w + tail.len());
            for i in 0..n {
                for p in group.iter() {
                    buf.push(p[i]);
                }
            }
            buf.extend_from_slice(tail);
            out.push(buf);
        }
        Ok(BufferList::from_bufs(out))
    }
}

/// Lossy f64 → f32 ([`StageSpec::ConvertF64F32`]): halves the section at
/// ≤ 2⁻²⁴ relative error per value. Encoding errors out (rather than
/// silently saturating) when a finite input overflows the f32 range;
/// values below the f32 subnormal grid flush toward zero, which the
/// distortion bound's absolute term accounts for.
pub struct ConvertF32Stage;

impl Stage for ConvertF32Stage {
    fn spec(&self) -> StageSpec {
        StageSpec::ConvertF64F32
    }

    fn encode(&self, input: &BufferList) -> Result<BufferList> {
        per_buffer(input, |b| {
            if b.len() % 8 != 0 {
                bail!("f64→f32 convert on {} bytes (not a multiple of 8)", b.len());
            }
            let mut out = Vec::with_capacity(b.len() / 2);
            for c in b.chunks_exact(8) {
                let v = f64::from_le_bytes(c.try_into().unwrap());
                let v32 = v as f32;
                if v.is_finite() && v32.is_infinite() {
                    bail!("value {v} overflows the f32 range");
                }
                out.extend_from_slice(&v32.to_le_bytes());
            }
            Ok(out)
        })
    }

    fn decode(&self, input: &BufferList) -> Result<BufferList> {
        per_buffer(input, |b| {
            if b.len() % 4 != 0 {
                bail!("f32 section holds {} bytes (not a multiple of 4)", b.len());
            }
            let mut out = Vec::with_capacity(b.len() * 2);
            for c in b.chunks_exact(4) {
                let v = f32::from_le_bytes(c.try_into().unwrap()) as f64;
                out.extend_from_slice(&v.to_le_bytes());
            }
            Ok(out)
        })
    }
}

/// Round an f32 bit pattern to bfloat16 (round-to-nearest-even; NaN
/// payloads are quieted so they stay NaN after truncation).
fn f32_bits_to_bf16(b: u32) -> u16 {
    if f32::from_bits(b).is_nan() {
        return ((b >> 16) as u16) | 0x0040;
    }
    let round = ((b >> 16) & 1) + 0x7FFF;
    ((b.wrapping_add(round)) >> 16) as u16
}

/// Lossy f64 → bfloat16 ([`StageSpec::ConvertF64Bf16`]): quarters the
/// section at ≤ 2⁻⁸ relative error per value — the aggressive end of the
/// §5 distortion-rate curve. Same overflow/underflow policy as
/// [`ConvertF32Stage`].
pub struct ConvertBf16Stage;

impl Stage for ConvertBf16Stage {
    fn spec(&self) -> StageSpec {
        StageSpec::ConvertF64Bf16
    }

    fn encode(&self, input: &BufferList) -> Result<BufferList> {
        per_buffer(input, |b| {
            if b.len() % 8 != 0 {
                bail!("f64→bf16 convert on {} bytes (not a multiple of 8)", b.len());
            }
            let mut out = Vec::with_capacity(b.len() / 4);
            for c in b.chunks_exact(8) {
                let v = f64::from_le_bytes(c.try_into().unwrap());
                let h = f32_bits_to_bf16((v as f32).to_bits());
                if v.is_finite() && (h & 0x7FFF) >= 0x7F80 {
                    bail!("value {v} overflows the bfloat16 range");
                }
                out.extend_from_slice(&h.to_le_bytes());
            }
            Ok(out)
        })
    }

    fn decode(&self, input: &BufferList) -> Result<BufferList> {
        per_buffer(input, |b| {
            if b.len() % 2 != 0 {
                bail!("bf16 section holds {} bytes (not a multiple of 2)", b.len());
            }
            let mut out = Vec::with_capacity(b.len() * 4);
            for c in b.chunks_exact(2) {
                let h = u16::from_le_bytes(c.try_into().unwrap());
                let v = f32::from_bits((h as u32) << 16) as f64;
                out.extend_from_slice(&v.to_le_bytes());
            }
            Ok(out)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specials() -> Vec<f64> {
        vec![
            0.0,
            -0.0,
            1.0,
            -1.5,
            f64::NAN,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::MIN_POSITIVE,
            5e-324, // smallest positive subnormal
            -5e-324,
            1e300,
            -1e300,
            std::f64::consts::PI,
        ]
    }

    fn bytes_of(vals: &[f64]) -> Vec<u8> {
        vals.iter().flat_map(|v| v.to_le_bytes()).collect()
    }

    #[test]
    fn transform_stages_roundtrip_special_floats_bit_exactly() {
        let data = bytes_of(&specials());
        for spec in [
            StageSpec::DeltaU64,
            StageSpec::XorU64,
            StageSpec::ColumnSplit(8),
            StageSpec::ColumnSplit(4),
            StageSpec::Lzss,
            StageSpec::Huffman,
            StageSpec::Arith,
        ] {
            let st = spec.build();
            let enc = st.encode(&BufferList::from_single(data.clone())).unwrap();
            let dec = st.decode(&enc).unwrap();
            assert_eq!(
                dec.clone().into_single().unwrap(),
                data,
                "stage {} must be bit-exact",
                spec.name()
            );
        }
    }

    #[test]
    fn transform_stages_tolerate_unaligned_tails() {
        // 13 bytes: one u64 word + 5 tail bytes for delta/xor; 1 record +
        // 5 tail for split8
        let data: Vec<u8> = (0u8..13).collect();
        for spec in [StageSpec::DeltaU64, StageSpec::XorU64, StageSpec::ColumnSplit(8)] {
            let st = spec.build();
            let enc = st.encode(&BufferList::from_single(data.clone())).unwrap();
            let dec = st.decode(&enc).unwrap().into_single().unwrap();
            assert_eq!(dec, data, "stage {} tail handling", spec.name());
        }
    }

    #[test]
    fn entropy_stages_roundtrip_empty_and_uniform_buffers() {
        for spec in [StageSpec::Lzss, StageSpec::Huffman, StageSpec::Arith] {
            let st = spec.build();
            for data in [vec![], vec![7u8; 100], (0u8..=255).collect::<Vec<u8>>()] {
                let enc = st.encode(&BufferList::from_single(data.clone())).unwrap();
                let dec = st.decode(&enc).unwrap().into_single().unwrap();
                assert_eq!(dec, data, "stage {}", spec.name());
            }
        }
    }

    #[test]
    fn column_split_fans_out_and_merges_multiple_buffers() {
        let a: Vec<u8> = (0..32).collect();
        let b: Vec<u8> = (100..117).collect(); // 17 bytes: 2 records + 1 tail
        let st = ColumnSplitStage { width: 8 };
        let input = BufferList::from_bufs(vec![a.clone(), b.clone()]);
        let enc = st.encode(&input).unwrap();
        assert_eq!(enc.len(), 16, "two buffers × width 8 planes");
        let dec = st.decode(&enc).unwrap();
        assert_eq!(dec, input);
    }

    #[test]
    fn convert_f32_widens_back_and_preserves_signed_zero_and_nan() {
        let vals = vec![0.0, -0.0, 1.0, -2.5, f64::NAN, f64::INFINITY, 1e-310];
        let st = ConvertF32Stage;
        let enc = st.encode(&BufferList::from_single(bytes_of(&vals))).unwrap();
        let out = st.decode(&enc).unwrap().into_single().unwrap();
        let decoded: Vec<f64> = out
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(decoded[0].to_bits(), 0.0f64.to_bits());
        assert_eq!(decoded[1].to_bits(), (-0.0f64).to_bits(), "signed zero survives");
        assert_eq!(decoded[2], 1.0);
        assert_eq!(decoded[3], -2.5);
        assert!(decoded[4].is_nan(), "NaN stays NaN");
        assert_eq!(decoded[5], f64::INFINITY);
        // deep subnormal flushes to (signed) zero — within the bound's
        // absolute term
        assert_eq!(decoded[6], 0.0);
    }

    #[test]
    fn convert_overflow_is_a_typed_error_not_saturation() {
        // finite in f64 and f32, but rounds past bf16 max (~3.39e38)
        let barely = vec![3.4e38];
        assert!(ConvertBf16Stage.encode(&BufferList::from_single(bytes_of(&barely))).is_err());
        // finite in f64, above f32 max (~3.40e38)
        let big = vec![3.5e38];
        assert!(ConvertF32Stage.encode(&BufferList::from_single(bytes_of(&big))).is_err());
        assert!(ConvertBf16Stage.encode(&BufferList::from_single(bytes_of(&big))).is_err());
        // infinities pass through both
        let inf = vec![f64::INFINITY, f64::NEG_INFINITY];
        assert!(ConvertF32Stage.encode(&BufferList::from_single(bytes_of(&inf))).is_ok());
        assert!(ConvertBf16Stage.encode(&BufferList::from_single(bytes_of(&inf))).is_ok());
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1 + 2⁻⁸ sits exactly between 1 and 1 + 2⁻⁷: ties to even (1.0)
        assert_eq!(f32_bits_to_bf16(0x3F80_8000), 0x3F80);
        // 1 + 3·2⁻⁸ sits between 1 + 2⁻⁷ and 1 + 2⁻⁶: ties to even (2⁻⁶ side)
        assert_eq!(f32_bits_to_bf16(0x3F81_8000), 0x3F82);
        // below the tie: round down
        assert_eq!(f32_bits_to_bf16(0x3F80_7FFF), 0x3F80);
        // above the tie: round up
        assert_eq!(f32_bits_to_bf16(0x3F80_8001), 0x3F81);
        // NaN is quieted, stays NaN
        let h = f32_bits_to_bf16(f32::NAN.to_bits());
        assert!(f32::from_bits((h as u32) << 16).is_nan());
    }

    #[test]
    fn chain_encode_decode_roundtrips_multi_stage() {
        let vals: Vec<f64> = (0..321).map(|i| (i as f64).sqrt() * 3.25).collect();
        for chain in [
            vec![],
            vec![StageSpec::Lzss],
            vec![StageSpec::DeltaU64, StageSpec::Lzss],
            vec![StageSpec::XorU64, StageSpec::ColumnSplit(8), StageSpec::Huffman],
            vec![StageSpec::ColumnSplit(8), StageSpec::Arith],
        ] {
            let enc = encode_f64_chain(&chain, &vals).unwrap();
            let dec = decode_f64_chain(&chain, &enc).unwrap();
            assert_eq!(
                dec.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "chain {} must round-trip bit-exactly",
                chain_label(&chain)
            );
        }
    }

    #[test]
    fn lossy_chain_decodes_to_converted_values() {
        let vals = vec![1.1, -2.7, 0.0, 1e30];
        let chain = vec![StageSpec::ConvertF64F32, StageSpec::Lzss];
        let enc = encode_f64_chain(&chain, &vals).unwrap();
        let dec = decode_f64_chain(&chain, &enc).unwrap();
        for (d, v) in dec.iter().zip(&vals) {
            assert_eq!(*d, *v as f32 as f64, "decode = widened f32 rounding");
        }
    }

    #[test]
    fn chain_wire_format_roundtrips() {
        let chains = SectionChains {
            structure: vec![StageSpec::Huffman],
            split_tables: vec![StageSpec::DeltaU64, StageSpec::Lzss],
            fit_table: vec![StageSpec::ConvertF64Bf16, StageSpec::ColumnSplit(2)],
        };
        let mut w = BitWriter::new();
        chains.write(&mut w);
        let bytes = w.into_bytes();
        let got = SectionChains::read(&mut BitReader::new(&bytes)).unwrap();
        assert_eq!(got, chains);
    }

    #[test]
    fn validation_enforces_lossy_placement() {
        // lossy only at position 0
        assert!(validate_chain(&[StageSpec::Lzss, StageSpec::ConvertF64F32], true).is_err());
        assert!(validate_chain(&[StageSpec::ConvertF64F32, StageSpec::Lzss], true).is_ok());
        // lossy refused where not permitted
        assert!(validate_chain(&[StageSpec::ConvertF64F32], false).is_err());
        // bad split width
        assert!(validate_chain(&[StageSpec::ColumnSplit(0)], false).is_err());
        assert!(validate_chain(&[StageSpec::ColumnSplit(17)], false).is_err());
        // classification forbids lossy fit chains
        let lossy_fit = SectionChains {
            fit_table: vec![StageSpec::ConvertF64F32],
            ..Default::default()
        };
        assert!(lossy_fit.validate(true).is_err());
        assert!(lossy_fit.validate(false).is_ok());
    }

    #[test]
    fn chain_parse_and_label_are_inverse() {
        let chain = parse_chain("delta+split8+lzss").unwrap();
        assert_eq!(
            chain,
            vec![StageSpec::DeltaU64, StageSpec::ColumnSplit(8), StageSpec::Lzss]
        );
        assert_eq!(chain_label(&chain), "delta+split8+lzss");
        assert_eq!(parse_chain("-").unwrap(), vec![]);
        let mixed = parse_chain("f32, lzss").unwrap();
        assert_eq!(mixed, vec![StageSpec::ConvertF64F32, StageSpec::Lzss]);
        assert!(parse_chain("bogus").is_err());
    }

    #[test]
    fn corrupt_chain_payload_errors_cleanly() {
        let vals: Vec<f64> = (0..64).map(|i| i as f64 * 0.5).collect();
        let chain = vec![StageSpec::DeltaU64, StageSpec::Lzss];
        let enc = encode_f64_chain(&chain, &vals).unwrap();
        // truncations and bit flips must surface typed errors or wrong
        // data, never panics
        for cut in [0, 1, enc.len() / 2, enc.len().saturating_sub(1)] {
            let _ = decode_f64_chain(&chain, &enc[..cut]);
        }
        let mut flipped = enc.clone();
        if let Some(b) = flipped.last_mut() {
            *b ^= 0xFF;
        }
        let _ = decode_f64_chain(&chain, &flipped);
        // decoding with the wrong chain is an error or garbage, not a panic
        let _ = decode_f64_chain(&[StageSpec::Lzss], &enc);
    }

    #[test]
    fn delta_improves_sorted_table_compressibility() {
        // a sorted split table: deltas expose the shared high bytes
        let vals: Vec<f64> = (0..512).map(|i| 1000.0 + i as f64 * 0.25).collect();
        let plain = encode_f64_chain(&[StageSpec::Lzss], &vals).unwrap();
        let delta = encode_f64_chain(&[StageSpec::DeltaU64, StageSpec::Lzss], &vals).unwrap();
        assert!(
            delta.len() < plain.len(),
            "delta+lzss ({}) must beat lzss ({}) on a sorted table",
            delta.len(),
            plain.len()
        );
    }
}
