//! Compact f64 coding: Huffman on the high 12 bits (sign + exponent),
//! mantissa raw.
//!
//! Fitted values and split thresholds from one dataset concentrate in a
//! narrow dynamic range, so their sign/exponent field takes a handful of
//! values (≈1–3 bits under Huffman) while the 52 mantissa bits are
//! incompressible noise. This recovers the same ~15 % the paper's gzip
//! baseline finds in raw IEEE streams, keeps bit-exactness, and decodes a
//! value in O(code length) — no byte-level modeling needed.

use super::bitio::{BitReader, BitWriter};
use super::huffman::{HuffmanCode, HuffmanDecoder};
use anyhow::{Context, Result};

/// Number of coded high bits (sign + 11 exponent bits).
const HIGH_BITS: u8 = 12;
const MANTISSA_BITS: u8 = 64 - HIGH_BITS as u8;

#[inline]
fn high(v: f64) -> u32 {
    (v.to_bits() >> MANTISSA_BITS) as u32
}

/// Codec for a stream of f64s sharing one sign/exponent distribution.
#[derive(Debug, Clone)]
pub struct F64Codec {
    code: HuffmanCode,
    decoder: HuffmanDecoder,
}

impl F64Codec {
    /// Build from sample values (must cover every value later encoded —
    /// in this codebase the sample *is* the full stream).
    pub fn from_values<'a>(values: impl Iterator<Item = &'a f64>) -> Result<Self> {
        let mut counts = vec![0u64; 1 << HIGH_BITS];
        let mut any = false;
        for v in values {
            counts[high(*v) as usize] += 1;
            any = true;
        }
        if !any {
            counts[0] = 1; // degenerate but valid codec
        }
        let weights: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
        let code = HuffmanCode::from_weights(&weights)?;
        let decoder = code.decoder();
        Ok(F64Codec { code, decoder })
    }

    /// Expected bits per value under the build distribution (for the
    /// encoder's raw-vs-indexed cost comparison).
    pub fn expected_bits(&self, values: &[f64]) -> f64 {
        if values.is_empty() {
            return 0.0;
        }
        let total: f64 = values
            .iter()
            .map(|v| self.code.length(high(*v)) as f64 + MANTISSA_BITS as f64)
            .sum();
        total / values.len() as f64
    }

    /// Encode one value (Huffman-coded high bits + raw mantissa).
    pub fn encode(&self, v: f64, w: &mut BitWriter) -> Result<()> {
        self.code.encode(high(v), w)?;
        w.write_bits(v.to_bits() & ((1u64 << MANTISSA_BITS) - 1), MANTISSA_BITS);
        Ok(())
    }

    /// Decode one value written by [`Self::encode`].
    pub fn decode(&self, r: &mut BitReader) -> Result<f64> {
        let h = self.decoder.decode(r)? as u64;
        let m = r.read_bits(MANTISSA_BITS).context("f64 mantissa")?;
        Ok(f64::from_bits((h << MANTISSA_BITS) | m))
    }

    /// Serialize the codec (the Huffman length table over the 4096-symbol
    /// high-bits alphabet; run-length coded, so ~tens of bytes in practice).
    pub fn write_dict(&self, w: &mut BitWriter) {
        self.code.write_dict(w);
    }

    /// Deserialize a codec written by [`Self::write_dict`].
    pub fn read_dict(r: &mut BitReader) -> Result<Self> {
        let code = HuffmanCode::read_dict(r)?;
        let decoder = code.decoder();
        Ok(F64Codec { code, decoder })
    }

    /// Serialized dictionary size in bits.
    pub fn dict_bits(&self) -> u64 {
        self.code.dict_bits()
    }
}

/// One-shot block: codec dict + count + values (used for the container's
/// value tables).
pub fn write_block(values: &[f64], w: &mut BitWriter) -> Result<()> {
    let codec = F64Codec::from_values(values.iter())?;
    codec.write_dict(w);
    w.write_varint(values.len() as u64);
    for v in values {
        codec.encode(*v, w)?;
    }
    Ok(())
}

/// Read a block written by [`write_block`].
pub fn read_block(r: &mut BitReader) -> Result<Vec<f64>> {
    let codec = F64Codec::read_dict(r)?;
    let n = r.read_varint().context("f64 block count")? as usize;
    if n > 500_000_000 {
        anyhow::bail!("implausible f64 block size {n}");
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(codec.decode(r)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn roundtrip_bit_exact() {
        let mut rng = Pcg64::new(1);
        let values: Vec<f64> = (0..2000)
            .map(|_| (rng.gen_f64() - 0.3) * 120.0)
            .chain([0.0, -0.0, 1.0, f64::MIN_POSITIVE, 1e300, -1e-300])
            .collect();
        let mut w = BitWriter::new();
        write_block(&values, &mut w).unwrap();
        let bytes = w.into_bytes();
        let out = read_block(&mut BitReader::new(&bytes)).unwrap();
        assert_eq!(out.len(), values.len());
        for (a, b) in values.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn narrow_range_beats_raw64() {
        // values in [1, 2): a single exponent ⇒ ~53 bits/value
        let values: Vec<f64> = (0..4096).map(|i| 1.0 + i as f64 / 4096.0).collect();
        let mut w = BitWriter::new();
        write_block(&values, &mut w).unwrap();
        let bits_per = w.bit_len() as f64 / values.len() as f64;
        assert!(bits_per < 55.0, "bits/value = {bits_per}");
    }

    #[test]
    fn expected_bits_matches_actual() {
        let mut rng = Pcg64::new(2);
        let values: Vec<f64> = (0..1000).map(|_| rng.gen_normal() * 50.0).collect();
        let codec = F64Codec::from_values(values.iter()).unwrap();
        let mut w = BitWriter::new();
        for v in &values {
            codec.encode(*v, &mut w).unwrap();
        }
        let actual = w.bit_len() as f64 / values.len() as f64;
        let expected = codec.expected_bits(&values);
        assert!((actual - expected).abs() < 1e-9);
    }

    #[test]
    fn special_values_roundtrip_bit_identically() {
        // regression coverage: NaN payloads, negative zero, subnormals and
        // infinities must all reconstruct bit-for-bit through every path
        let specials = [
            f64::NAN,
            f64::from_bits(0x7FF8_DEAD_BEEF_1234), // quiet NaN with payload
            f64::from_bits(0xFFF0_0000_0000_0001), // negative NaN, low payload bit
            0.0,
            -0.0,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::from_bits(1),                     // smallest positive subnormal
            f64::from_bits(0x000F_FFFF_FFFF_FFFF), // largest subnormal
            -f64::MIN_POSITIVE / 4.0,              // negative subnormal
            f64::MIN_POSITIVE,
            f64::MAX,
            f64::MIN,
        ];
        // block path (the container's value tables)
        let mut w = BitWriter::new();
        write_block(&specials, &mut w).unwrap();
        let bytes = w.into_bytes();
        let out = read_block(&mut BitReader::new(&bytes)).unwrap();
        assert_eq!(out.len(), specials.len());
        for (a, b) in specials.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits(), "block: {a:?}");
        }
        // streaming codec path (raw fit streams)
        let codec = F64Codec::from_values(specials.iter()).unwrap();
        let mut w = BitWriter::new();
        for v in &specials {
            codec.encode(*v, &mut w).unwrap();
        }
        let stream = w.into_bytes();
        let mut r = BitReader::new(&stream);
        for v in &specials {
            assert_eq!(codec.decode(&mut r).unwrap().to_bits(), v.to_bits(), "codec: {v:?}");
        }
        // dictionary round-trip: a decoder rebuilt from serialized bytes
        // must agree (what a standalone container reader does)
        let mut dw = BitWriter::new();
        codec.write_dict(&mut dw);
        let dict_bytes = dw.into_bytes();
        let codec2 = F64Codec::read_dict(&mut BitReader::new(&dict_bytes)).unwrap();
        let mut r = BitReader::new(&stream);
        for v in &specials {
            assert_eq!(codec2.decode(&mut r).unwrap().to_bits(), v.to_bits(), "dict: {v:?}");
        }
    }

    #[test]
    fn single_exponent_bucket_roundtrip() {
        // every value shares one sign/exponent symbol: the degenerate
        // 1-symbol Huffman code still decodes losslessly
        let values: Vec<f64> = (0..100).map(|i| 1.0 + i as f64 * 1e-6).collect();
        let mut w = BitWriter::new();
        write_block(&values, &mut w).unwrap();
        let bytes = w.into_bytes();
        let out = read_block(&mut BitReader::new(&bytes)).unwrap();
        for (a, b) in values.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn empty_block() {
        let mut w = BitWriter::new();
        write_block(&[], &mut w).unwrap();
        let bytes = w.into_bytes();
        assert!(read_block(&mut BitReader::new(&bytes)).unwrap().is_empty());
    }

    #[test]
    fn truncated_block_errors() {
        let values = vec![1.5; 100];
        let mut w = BitWriter::new();
        write_block(&values, &mut w).unwrap();
        let bytes = w.into_bytes();
        assert!(read_block(&mut BitReader::new(&bytes[..bytes.len() / 4])).is_err());
    }
}
