//! Entropy-coding substrate (paper §2.2).
//!
//! * [`bitio`]   — MSB-first bit writer/reader with random access
//! * [`huffman`] — canonical Huffman codes with serializable dictionaries
//!   (the per-cluster codebooks of Algorithm 1)
//! * [`arith`]   — arithmetic coding, static and adaptive (used for binary
//!   fits in two-class problems, §4)
//! * [`lz`]      — LZSS, applied to the concatenated Zaks sequences (§3.1)
//! * [`entropy`] — empirical entropy, KL divergence, and the dictionary-cost
//!   constants `α` of eq. (6)
//! * [`f64pack`] — bit-exact f64 coding (Huffman'd sign/exponent + raw
//!   mantissa) for value tables and raw fit streams
//! * [`stage`]   — composable transform-stage chains (delta/XOR,
//!   mantissa-split, lossy float converts) layered over the coders above

pub mod arith;
pub mod bitio;
pub mod entropy;
pub mod f64pack;
pub mod huffman;
pub mod lz;
pub mod stage;

pub use bitio::{BitReader, BitWriter};
pub use huffman::{HuffmanCode, HuffmanDecoder};
