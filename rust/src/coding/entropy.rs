//! Information-theoretic utilities: empirical entropy, KL divergence, and
//! the dictionary-cost constants `α` of the clustering objective (eq. 3–6).

/// Empirical entropy (bits/symbol) of a count vector.
pub fn entropy_counts(counts: &[u64]) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / t;
            -p * p.log2()
        })
        .sum()
}

/// Empirical entropy (bits/symbol) of a probability vector.
pub fn entropy_probs(p: &[f64]) -> f64 {
    p.iter()
        .filter(|&&pi| pi > 0.0)
        .map(|&pi| -pi * pi.log2())
        .sum()
}

/// Kullback–Leibler divergence `D_KL(P ‖ Q)` in bits.
///
/// Returns `f64::INFINITY` when `P` has mass where `Q` has none — the
/// clustering code never lets that happen (centroids are mixtures of their
/// members, so member support ⊆ centroid support), but callers comparing
/// against arbitrary reference distributions (§2.2) may see it.
pub fn kl_divergence(p: &[f64], q: &[f64]) -> f64 {
    debug_assert_eq!(p.len(), q.len());
    let mut d = 0.0;
    for (&pi, &qi) in p.iter().zip(q) {
        if pi > 0.0 {
            if qi <= 0.0 {
                return f64::INFINITY;
            }
            d += pi * (pi / qi).log2();
        }
    }
    // numerical noise can push an identical pair slightly negative
    d.max(0.0)
}

/// Cross entropy `H(P, Q) = −Σ p log q` in bits (∞ on support mismatch).
pub fn cross_entropy(p: &[f64], q: &[f64]) -> f64 {
    debug_assert_eq!(p.len(), q.len());
    let mut h = 0.0;
    for (&pi, &qi) in p.iter().zip(q) {
        if pi > 0.0 {
            if qi <= 0.0 {
                return f64::INFINITY;
            }
            h -= pi * qi.log2();
        }
    }
    h
}

/// Normalize counts into a probability vector (empty/zero-total → uniform).
pub fn normalize(counts: &[u64]) -> Vec<f64> {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        let n = counts.len().max(1);
        return vec![1.0 / n as f64; counts.len()];
    }
    counts.iter().map(|&c| c as f64 / total as f64).collect()
}

/// Dictionary-line costs `α` from §3.2.2 of the paper, in bits.
///
/// * variable names over `d` variables: `α = log₂(d) + d`
/// * categorical split values over `C` values: `α = log₂(C) + C`
/// * numerical split values (index into `n` observations): `α = log₂(n) + C`
/// * fits represented with `bits` bits: `α = bits + C` (the symbol costs
///   `bits` to describe; `C` bounds the worst-case codeword length)
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DictCost {
    /// cost in bits of describing one dictionary line
    pub alpha: f64,
}

impl DictCost {
    /// `α = log₂(d) + d` — variable-name dictionaries.
    pub fn variable_names(d: usize) -> Self {
        let d = d.max(1) as f64;
        DictCost {
            alpha: d.log2().max(0.0) + d,
        }
    }

    /// `α = log₂(C) + C` — categorical split-value dictionaries.
    pub fn categorical_splits(c: usize) -> Self {
        let c = c.max(1) as f64;
        DictCost {
            alpha: c.log2().max(0.0) + c,
        }
    }

    /// `α = log₂(n) + C` — numerical split values stored as observation
    /// (rank) indices; `n` observations, `C` distinct split values.
    pub fn numerical_splits(n: usize, c: usize) -> Self {
        let n = n.max(1) as f64;
        DictCost {
            alpha: n.log2().max(0.0) + c.max(1) as f64,
        }
    }

    /// Fits represented with `bits` bits per value, `C` distinct values.
    /// The paper's §6 observation: at 64-bit fit representation the α is
    /// large ⇒ few clusters; at 32-bit it shrinks ⇒ ≈7 clusters.
    pub fn fits(bits: u32, c: usize) -> Self {
        DictCost {
            alpha: bits as f64 + c.max(1) as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entropy_uniform_is_log() {
        let h = entropy_counts(&[10, 10, 10, 10]);
        assert!((h - 2.0).abs() < 1e-12);
        assert!((entropy_probs(&[0.25; 4]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn entropy_degenerate_zero() {
        assert_eq!(entropy_counts(&[42, 0, 0]), 0.0);
        assert_eq!(entropy_counts(&[]), 0.0);
    }

    #[test]
    fn kl_self_zero_and_nonnegative() {
        let p = [0.2, 0.3, 0.5];
        assert_eq!(kl_divergence(&p, &p), 0.0);
        let q = [0.4, 0.3, 0.3];
        assert!(kl_divergence(&p, &q) > 0.0);
        assert!(kl_divergence(&q, &p) > 0.0);
    }

    #[test]
    fn kl_asymmetric() {
        let p = [0.9, 0.1];
        let q = [0.5, 0.5];
        assert!((kl_divergence(&p, &q) - kl_divergence(&q, &p)).abs() > 1e-6);
    }

    #[test]
    fn kl_support_mismatch_infinite() {
        assert_eq!(kl_divergence(&[0.5, 0.5], &[1.0, 0.0]), f64::INFINITY);
        // other direction is fine: q has extra support
        assert!(kl_divergence(&[1.0, 0.0], &[0.5, 0.5]).is_finite());
    }

    #[test]
    fn cross_entropy_decomposition() {
        // H(P,Q) = H(P) + D(P||Q)
        let p = [0.3, 0.7];
        let q = [0.6, 0.4];
        let lhs = cross_entropy(&p, &q);
        let rhs = entropy_probs(&p) + kl_divergence(&p, &q);
        assert!((lhs - rhs).abs() < 1e-12);
    }

    #[test]
    fn normalize_handles_zero() {
        assert_eq!(normalize(&[0, 0]), vec![0.5, 0.5]);
        assert_eq!(normalize(&[1, 3]), vec![0.25, 0.75]);
    }

    #[test]
    fn dict_costs_match_paper_formulas() {
        let d = 32usize;
        assert!((DictCost::variable_names(d).alpha - (5.0 + 32.0)).abs() < 1e-12);
        let c = 16usize;
        assert!((DictCost::categorical_splits(c).alpha - (4.0 + 16.0)).abs() < 1e-12);
        let n = 1024usize;
        assert!((DictCost::numerical_splits(n, c).alpha - (10.0 + 16.0)).abs() < 1e-12);
        assert!((DictCost::fits(64, 8).alpha - 72.0).abs() < 1e-12);
    }
}
