//! MSB-first bit-level I/O.
//!
//! Everything the codec writes goes through [`BitWriter`]; decoding (including
//! the *random access* that prediction-from-compressed needs, §5 of the
//! paper) goes through [`BitReader::seek_bits`].

/// Accumulates bits MSB-first into a byte vector.
#[derive(Debug, Default, Clone)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bits used in the final partial byte (0..=7); 0 means byte-aligned.
    partial: u8,
}

impl BitWriter {
    /// An empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> u64 {
        self.buf.len() as u64 * 8 - if self.partial == 0 { 0 } else { (8 - self.partial) as u64 }
    }

    /// Write a single bit.
    #[inline]
    pub fn write_bit(&mut self, bit: bool) {
        if self.partial == 0 {
            self.buf.push(0);
        }
        if bit {
            let last = self.buf.last_mut().unwrap();
            *last |= 1 << (7 - self.partial);
        }
        self.partial = (self.partial + 1) & 7;
    }

    /// Write the low `n` bits of `value`, MSB first. `n <= 64`.
    /// Byte-chunked (§Perf: the per-bit loop dominated encode profiles).
    #[inline]
    pub fn write_bits(&mut self, value: u64, n: u8) {
        debug_assert!(n <= 64);
        let mut remaining = n as u32;
        while remaining > 0 {
            if self.partial == 0 {
                self.buf.push(0);
            }
            let free = 8 - self.partial as u32;
            let take = free.min(remaining);
            let shift = remaining - take;
            let chunk = ((value >> shift) as u8) & (((1u16 << take) - 1) as u8);
            let last = self.buf.last_mut().unwrap();
            *last |= chunk << (free - take);
            self.partial = ((self.partial as u32 + take) & 7) as u8;
            remaining -= take;
        }
    }

    /// Write a whole byte (still honoring the current bit offset).
    pub fn write_byte(&mut self, b: u8) {
        self.write_bits(b as u64, 8);
    }

    /// Write a byte slice. On a byte-aligned stream this is a single
    /// `extend_from_slice` — the bulk path archive payloads ride (§Perf:
    /// a per-byte `write_byte` loop costs millions of calls per pack);
    /// unaligned streams fall back to the bit-honoring path.
    pub fn write_bytes(&mut self, bytes: &[u8]) {
        if self.partial == 0 {
            self.buf.extend_from_slice(bytes);
        } else {
            for &b in bytes {
                self.write_bits(b as u64, 8);
            }
        }
    }

    /// Write a length-prefixed LEB128-style varint (7 bits per byte).
    pub fn write_varint(&mut self, mut v: u64) {
        loop {
            let chunk = (v & 0x7f) as u64;
            v >>= 7;
            if v == 0 {
                self.write_bits(chunk, 8);
                break;
            }
            self.write_bits(chunk | 0x80, 8);
        }
    }

    /// Write an Elias-gamma code for `v >= 1` (used for small unbounded
    /// integers inside bit-packed sections, e.g. LZ match lengths).
    pub fn write_gamma(&mut self, v: u64) {
        debug_assert!(v >= 1);
        let nbits = 64 - v.leading_zeros() as u8; // position of MSB, 1-based
        for _ in 0..nbits - 1 {
            self.write_bit(false);
        }
        self.write_bits(v, nbits);
    }

    /// Pad with zero bits to the next byte boundary.
    pub fn align_byte(&mut self) {
        while self.partial != 0 {
            self.write_bit(false);
        }
    }

    /// Append the full bit content of another writer (bit-exact, not
    /// byte-aligned). Used when assembling per-cluster payloads.
    pub fn append(&mut self, other: &BitWriter) {
        let bits = other.bit_len();
        let full_bytes = (bits / 8) as usize;
        for &b in &other.buf[..full_bytes] {
            self.write_bits(b as u64, 8);
        }
        let tail = (bits % 8) as u8;
        if tail > 0 {
            let last = other.buf[full_bytes];
            self.write_bits((last >> (8 - tail)) as u64, tail);
        }
    }

    /// Finish and return the backing bytes (final byte zero-padded).
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Borrow the bytes written so far.
    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }
}

/// Reads bits MSB-first from a byte slice, with absolute seeking.
#[derive(Debug, Clone)]
pub struct BitReader<'a> {
    data: &'a [u8],
    pos: u64, // absolute bit position
}

impl<'a> BitReader<'a> {
    /// A reader positioned at the first bit of `data`.
    pub fn new(data: &'a [u8]) -> Self {
        BitReader { data, pos: 0 }
    }

    /// Current absolute bit position.
    pub fn bit_pos(&self) -> u64 {
        self.pos
    }

    /// Total readable bits.
    pub fn bit_len(&self) -> u64 {
        self.data.len() as u64 * 8
    }

    /// Jump to an absolute bit offset — the random-access primitive behind
    /// prediction from the compressed format.
    pub fn seek_bits(&mut self, pos: u64) {
        self.pos = pos;
    }

    /// Read one bit; `None` at end of data.
    #[inline]
    pub fn read_bit(&mut self) -> Option<bool> {
        let byte = (self.pos / 8) as usize;
        if byte >= self.data.len() {
            return None;
        }
        let bit = (self.data[byte] >> (7 - (self.pos & 7))) & 1;
        self.pos += 1;
        Some(bit == 1)
    }

    /// Read `n` bits MSB-first into the low bits of a `u64`.
    /// Byte-chunked (§Perf).
    #[inline]
    pub fn read_bits(&mut self, n: u8) -> Option<u64> {
        debug_assert!(n <= 64);
        if self.pos + n as u64 > self.data.len() as u64 * 8 {
            return None;
        }
        let mut v = 0u64;
        let mut remaining = n as u32;
        while remaining > 0 {
            let byte = (self.pos >> 3) as usize;
            let bit_off = (self.pos & 7) as u32;
            let avail = 8 - bit_off;
            let take = avail.min(remaining);
            let chunk = (self.data[byte] >> (avail - take)) & (((1u16 << take) - 1) as u8);
            v = (v << take) | chunk as u64;
            self.pos += take as u64;
            remaining -= take;
        }
        Some(v)
    }

    /// Read 8 bits as a byte (`None` past the end).
    pub fn read_byte(&mut self) -> Option<u8> {
        self.read_bits(8).map(|v| v as u8)
    }

    /// Read a varint written by [`BitWriter::write_varint`].
    pub fn read_varint(&mut self) -> Option<u64> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let b = self.read_byte()?;
            v |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                return Some(v);
            }
            shift += 7;
            if shift >= 64 {
                return None; // malformed
            }
        }
    }

    /// Read an Elias-gamma code written by [`BitWriter::write_gamma`].
    pub fn read_gamma(&mut self) -> Option<u64> {
        let mut zeros = 0u8;
        loop {
            if self.read_bit()? {
                break;
            }
            zeros += 1;
            if zeros >= 64 {
                return None; // malformed
            }
        }
        let rest = self.read_bits(zeros)?;
        Some((1u64 << zeros) | rest)
    }

    /// Skip to the next byte boundary.
    pub fn align_byte(&mut self) {
        self.pos = (self.pos + 7) & !7;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_roundtrip() {
        let mut w = BitWriter::new();
        let pattern = [true, false, true, true, false, false, true, false, true];
        for &b in &pattern {
            w.write_bit(b);
        }
        assert_eq!(w.bit_len(), 9);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &b in &pattern {
            assert_eq!(r.read_bit(), Some(b));
        }
    }

    #[test]
    fn bits_roundtrip_various_widths() {
        let mut w = BitWriter::new();
        let cases: &[(u64, u8)] = &[(0, 1), (1, 1), (5, 3), (255, 8), (1023, 10), (u64::MAX, 64), (0xdead_beef, 37)];
        for &(v, n) in cases {
            w.write_bits(v, n);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &(v, n) in cases {
            assert_eq!(r.read_bits(n), Some(v & (u64::MAX >> (64 - n.min(64)))), "width {n}");
        }
    }

    #[test]
    fn varint_roundtrip() {
        let vals = [0u64, 1, 127, 128, 300, 16_384, u32::MAX as u64, u64::MAX];
        let mut w = BitWriter::new();
        for &v in &vals {
            w.write_varint(v);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &v in &vals {
            assert_eq!(r.read_varint(), Some(v));
        }
    }

    #[test]
    fn gamma_roundtrip() {
        let vals = [1u64, 2, 3, 4, 7, 8, 100, 1_000_000];
        let mut w = BitWriter::new();
        for &v in &vals {
            w.write_gamma(v);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &v in &vals {
            assert_eq!(r.read_gamma(), Some(v));
        }
    }

    #[test]
    fn seek_gives_random_access() {
        let mut w = BitWriter::new();
        w.write_bits(0b1010_1010_1100_1100, 16);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        r.seek_bits(8);
        assert_eq!(r.read_bits(4), Some(0b1100));
        r.seek_bits(0);
        assert_eq!(r.read_bits(4), Some(0b1010));
    }

    #[test]
    fn append_is_bit_exact() {
        let mut a = BitWriter::new();
        a.write_bits(0b101, 3);
        let mut b = BitWriter::new();
        b.write_bits(0b0110, 4);
        a.append(&b);
        assert_eq!(a.bit_len(), 7);
        let bytes = a.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(7), Some(0b1010110));
    }

    #[test]
    fn read_past_end_is_none() {
        let mut r = BitReader::new(&[0xff]);
        assert_eq!(r.read_bits(8), Some(0xff));
        assert_eq!(r.read_bit(), None);
        assert_eq!(r.read_bits(3), None);
    }

    #[test]
    fn align_byte_pads_zero() {
        let mut w = BitWriter::new();
        w.write_bit(true);
        w.align_byte();
        assert_eq!(w.bit_len(), 8);
        assert_eq!(w.as_bytes(), &[0b1000_0000]);
    }

    #[test]
    fn write_bytes_matches_per_byte_writes() {
        let payload = [0xde, 0xad, 0xbe, 0xef, 0x01];
        // aligned: the bulk path must produce the same stream as write_byte
        let mut bulk = BitWriter::new();
        bulk.write_varint(7);
        bulk.align_byte();
        bulk.write_bytes(&payload);
        let mut slow = BitWriter::new();
        slow.write_varint(7);
        slow.align_byte();
        for &b in &payload {
            slow.write_byte(b);
        }
        assert_eq!(bulk.into_bytes(), slow.into_bytes());
        // unaligned: falls back to the bit-honoring path, still identical
        let mut bulk = BitWriter::new();
        bulk.write_bits(0b101, 3);
        bulk.write_bytes(&payload);
        let mut slow = BitWriter::new();
        slow.write_bits(0b101, 3);
        for &b in &payload {
            slow.write_byte(b);
        }
        assert_eq!(bulk.bit_len(), slow.bit_len());
        assert_eq!(bulk.into_bytes(), slow.into_bytes());
        // empty slice is a no-op either way
        let mut w = BitWriter::new();
        w.write_bytes(&[]);
        assert_eq!(w.bit_len(), 0);
    }
}
