//! Integer arithmetic coding (static-model range coder).
//!
//! The paper (§4, Algorithm 1 line 40) prefers an arithmetic encoder over
//! Huffman for the fits of two-class classification problems: a binary
//! alphabet with a skewed distribution costs ≥ 1 bit/symbol under Huffman but
//! approaches the entropy under arithmetic coding (§2.2: within 2 bits of the
//! empirical entropy *for the whole sequence*).
//!
//! Implementation: classic 32-bit-precision carry-free coder (CACM'87 style,
//! cf. Sayood ch. 4) over a static cumulative-frequency model. The model is
//! the cluster centroid `Q_k`, quantized to integer frequencies, so the
//! decoder rebuilds it from the serialized dictionary exactly.

use super::bitio::{BitReader, BitWriter};
use anyhow::{bail, Context, Result};

const PRECISION: u32 = 32;
const TOP: u64 = 1 << PRECISION;
const HALF: u64 = TOP >> 1;
const QUARTER: u64 = TOP >> 2;
const THREE_QUARTER: u64 = HALF + QUARTER;
/// Maximum model total so that `range / total` never underflows.
pub const MAX_TOTAL: u64 = 1 << 16;

/// A static frequency model over symbols `0..n`, stored cumulatively.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FreqModel {
    /// cum[i] = sum of freqs of symbols < i; cum[n] = total
    cum: Vec<u64>,
}

impl FreqModel {
    /// Quantize a probability vector to integer frequencies summing to at
    /// most [`MAX_TOTAL`], giving every *positive*-probability symbol a
    /// nonzero frequency (losslessness guard).
    ///
    /// Typed-error contract: empty, oversized (≥ [`MAX_TOTAL`] symbols),
    /// negative, non-finite, or all-zero inputs return `Err` — never panic
    /// (an oversized alphabet used to underflow the budget subtraction).
    pub fn from_probs(p: &[f64]) -> Result<Self> {
        if p.is_empty() {
            bail!("empty alphabet");
        }
        if p.len() as u64 >= MAX_TOTAL {
            bail!(
                "alphabet of {} symbols exceeds the coder's frequency budget ({MAX_TOTAL})",
                p.len()
            );
        }
        if p.iter().any(|&x| x < 0.0 || !x.is_finite()) {
            bail!("probabilities must be finite and non-negative");
        }
        let total_p: f64 = p.iter().sum();
        if !total_p.is_finite() || total_p <= 0.0 {
            bail!("all probabilities zero");
        }
        let budget = MAX_TOTAL - p.len() as u64; // reserve 1 per symbol
        let mut freqs: Vec<u64> = p
            .iter()
            .map(|&pi| {
                if pi <= 0.0 {
                    0
                } else {
                    1 + ((pi / total_p) * budget as f64) as u64
                }
            })
            .collect();
        // ensure at least one active symbol
        if freqs.iter().all(|&f| f == 0) {
            freqs[0] = 1;
        }
        Self::from_freqs(&freqs)
    }

    /// Build from explicit integer frequencies (0 = absent symbol).
    pub fn from_freqs(freqs: &[u64]) -> Result<Self> {
        if freqs.is_empty() {
            bail!("empty alphabet");
        }
        let total: u64 = freqs
            .iter()
            .try_fold(0u64, |acc, &f| acc.checked_add(f))
            .context("total frequency overflows u64")?;
        if total == 0 {
            bail!("zero total frequency");
        }
        if total > MAX_TOTAL {
            bail!("total frequency {total} exceeds MAX_TOTAL");
        }
        let mut cum = Vec::with_capacity(freqs.len() + 1);
        let mut acc = 0u64;
        cum.push(0);
        for &f in freqs {
            acc += f;
            cum.push(acc);
        }
        Ok(FreqModel { cum })
    }

    /// Number of symbols in the model's alphabet.
    pub fn alphabet_size(&self) -> usize {
        self.cum.len() - 1
    }

    /// Sum of all symbol frequencies.
    pub fn total(&self) -> u64 {
        *self.cum.last().unwrap()
    }

    /// Frequency of one symbol.
    pub fn freq(&self, sym: u32) -> u64 {
        self.cum[sym as usize + 1] - self.cum[sym as usize]
    }

    fn interval(&self, sym: u32) -> (u64, u64) {
        (self.cum[sym as usize], self.cum[sym as usize + 1])
    }

    /// Find the symbol whose cumulative interval contains `target`.
    fn lookup(&self, target: u64) -> u32 {
        // binary search over cum
        let mut lo = 0usize;
        let mut hi = self.cum.len() - 1;
        while lo + 1 < hi {
            let mid = (lo + hi) / 2;
            if self.cum[mid] <= target {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo as u32
    }

    /// Serialize: varint n, then varint freq per symbol (run-length for 0s).
    pub fn write(&self, out: &mut BitWriter) {
        let n = self.alphabet_size();
        out.write_varint(n as u64);
        let mut i = 0usize;
        while i < n {
            let f = self.cum[i + 1] - self.cum[i];
            if f == 0 {
                // zero run
                let mut run = 1usize;
                while i + run < n && self.cum[i + run + 1] - self.cum[i + run] == 0 {
                    run += 1;
                }
                out.write_bit(false);
                out.write_varint(run as u64);
                i += run;
            } else {
                out.write_bit(true);
                out.write_varint(f);
                i += 1;
            }
        }
    }

    /// Deserialize a model written by `write`.
    pub fn read(r: &mut BitReader) -> Result<Self> {
        let n = r.read_varint().context("freq model: n")? as usize;
        if n == 0 || n > 100_000_000 {
            bail!("freq model: implausible alphabet size {n}");
        }
        let mut freqs = Vec::with_capacity(n);
        while freqs.len() < n {
            let nonzero = r.read_bit().context("freq model: tag")?;
            if nonzero {
                freqs.push(r.read_varint().context("freq model: freq")?);
            } else {
                let run = r.read_varint().context("freq model: run")? as usize;
                if freqs.len() + run > n {
                    bail!("freq model: zero-run overflow");
                }
                freqs.extend(std::iter::repeat(0).take(run));
            }
        }
        Self::from_freqs(&freqs)
    }
}

/// Arithmetic encoder writing to a [`BitWriter`].
pub struct ArithEncoder<'a> {
    low: u64,
    high: u64,
    pending: u64,
    out: &'a mut BitWriter,
}

impl<'a> ArithEncoder<'a> {
    /// An encoder emitting into `out`.
    pub fn new(out: &'a mut BitWriter) -> Self {
        ArithEncoder {
            low: 0,
            high: TOP - 1,
            pending: 0,
            out,
        }
    }

    fn emit(&mut self, bit: bool) {
        self.out.write_bit(bit);
        while self.pending > 0 {
            self.out.write_bit(!bit);
            self.pending -= 1;
        }
    }

    /// Encode one symbol under a static model.
    pub fn encode(&mut self, model: &FreqModel, sym: u32) -> Result<()> {
        let (c_lo, c_hi) = model.interval(sym);
        if c_lo == c_hi {
            bail!("symbol {sym} has zero frequency");
        }
        let total = model.total();
        let range = self.high - self.low + 1;
        self.high = self.low + range * c_hi / total - 1;
        self.low += range * c_lo / total;
        loop {
            if self.high < HALF {
                self.emit(false);
            } else if self.low >= HALF {
                self.emit(true);
                self.low -= HALF;
                self.high -= HALF;
            } else if self.low >= QUARTER && self.high < THREE_QUARTER {
                self.pending += 1;
                self.low -= QUARTER;
                self.high -= QUARTER;
            } else {
                break;
            }
            self.low <<= 1;
            self.high = (self.high << 1) | 1;
        }
        Ok(())
    }

    /// Flush the final interval (call exactly once).
    pub fn finish(mut self) {
        self.pending += 1;
        if self.low < QUARTER {
            self.emit(false);
        } else {
            self.emit(true);
        }
    }
}

/// Arithmetic decoder owning a [`BitReader`] (reads zeros past the end of
/// its slice, matching the encoder's implicit zero padding — which is why
/// per-tree arith streams are stored byte-aligned in their own slices).
pub struct ArithDecoder<'b> {
    low: u64,
    high: u64,
    value: u64,
    r: BitReader<'b>,
}

impl<'b> ArithDecoder<'b> {
    /// Initialize by pre-loading PRECISION bits (missing bits read as 0,
    /// matching the encoder's zero padding).
    pub fn new(mut r: BitReader<'b>) -> Self {
        let mut value = 0u64;
        for _ in 0..PRECISION {
            value = (value << 1) | r.read_bit().unwrap_or(false) as u64;
        }
        ArithDecoder {
            low: 0,
            high: TOP - 1,
            value,
            r,
        }
    }

    /// Decode one symbol under a static model.
    pub fn decode(&mut self, model: &FreqModel) -> Result<u32> {
        let total = model.total();
        let range = self.high - self.low + 1;
        let target = ((self.value - self.low + 1) * total - 1) / range;
        if target >= total {
            bail!("arith: corrupt stream (target out of range)");
        }
        let sym = model.lookup(target);
        let (c_lo, c_hi) = model.interval(sym);
        self.high = self.low + range * c_hi / total - 1;
        self.low += range * c_lo / total;
        loop {
            if self.high < HALF {
                // nothing
            } else if self.low >= HALF {
                self.low -= HALF;
                self.high -= HALF;
                self.value -= HALF;
            } else if self.low >= QUARTER && self.high < THREE_QUARTER {
                self.low -= QUARTER;
                self.high -= QUARTER;
                self.value -= QUARTER;
            } else {
                break;
            }
            self.low <<= 1;
            self.high = (self.high << 1) | 1;
            self.value = (self.value << 1) | self.r.read_bit().unwrap_or(false) as u64;
        }
        Ok(sym)
    }
}

/// Convenience: encode a whole sequence under one model; returns bits used.
pub fn encode_sequence(model: &FreqModel, syms: &[u32], out: &mut BitWriter) -> Result<u64> {
    let start = out.bit_len();
    let mut enc = ArithEncoder::new(out);
    for &s in syms {
        enc.encode(model, s)?;
    }
    enc.finish();
    Ok(out.bit_len() - start)
}

/// Convenience: decode `n` symbols under one model.
pub fn decode_sequence(model: &FreqModel, r: &mut BitReader, n: usize) -> Result<Vec<u32>> {
    let mut dec = ArithDecoder::new(r.clone());
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(dec.decode(model)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn roundtrip(freqs: &[u64], seq: &[u32]) -> u64 {
        let model = FreqModel::from_freqs(freqs).unwrap();
        let mut w = BitWriter::new();
        let bits = encode_sequence(&model, seq, &mut w).unwrap();
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        let out = decode_sequence(&model, &mut r, seq.len()).unwrap();
        assert_eq!(out, seq);
        bits
    }

    #[test]
    fn basic_roundtrip() {
        roundtrip(&[5, 3, 2], &[0, 1, 2, 0, 0, 1, 2, 2, 1, 0, 0]);
    }

    #[test]
    fn binary_skewed_beats_one_bit_per_symbol() {
        // P(0)=0.95: entropy ≈ 0.286 bits; Huffman is stuck at 1 bit.
        let mut rng = Pcg64::new(42);
        let n = 4000usize;
        let seq: Vec<u32> = (0..n).map(|_| rng.gen_bool(0.05) as u32).collect();
        let ones = seq.iter().filter(|&&s| s == 1).count() as u64;
        let bits = roundtrip(&[(n as u64 - ones).max(1), ones.max(1)], &seq);
        let rate = bits as f64 / n as f64;
        assert!(rate < 0.5, "rate={rate} should be far below 1 bit/sym");
    }

    #[test]
    fn rate_close_to_entropy() {
        let mut rng = Pcg64::new(1);
        let p = [0.6, 0.2, 0.1, 0.1];
        let n = 8000usize;
        let seq: Vec<u32> = (0..n)
            .map(|_| {
                let u = rng.gen_f64();
                let mut acc = 0.0;
                for (i, &pi) in p.iter().enumerate() {
                    acc += pi;
                    if u < acc {
                        return i as u32;
                    }
                }
                p.len() as u32 - 1
            })
            .collect();
        let mut counts = [0u64; 4];
        for &s in &seq {
            counts[s as usize] += 1;
        }
        let bits = roundtrip(&counts, &seq);
        let emp_h: f64 = counts
            .iter()
            .map(|&c| {
                let pi = c as f64 / n as f64;
                if pi > 0.0 {
                    -pi * pi.log2()
                } else {
                    0.0
                }
            })
            .sum();
        let rate = bits as f64 / n as f64;
        // §2.2: within 2 bits over the whole sequence + quantization slack
        assert!(rate <= emp_h + 0.05, "rate={rate} H={emp_h}");
        assert!(rate >= emp_h - 1e-3, "cannot beat entropy: rate={rate} H={emp_h}");
    }

    #[test]
    fn single_symbol_sequences() {
        roundtrip(&[1], &[0, 0, 0, 0, 0]);
        roundtrip(&[10, 1], &vec![0u32; 64]);
    }

    #[test]
    fn empty_sequence() {
        roundtrip(&[1, 1], &[]);
    }

    #[test]
    fn sparse_alphabet() {
        roundtrip(&[5, 0, 3, 0, 0, 2], &[0, 2, 5, 5, 2, 0, 0]);
    }

    #[test]
    fn model_serialization_roundtrip() {
        let m = FreqModel::from_freqs(&[100, 0, 0, 7, 1, 0, 42]).unwrap();
        let mut w = BitWriter::new();
        m.write(&mut w);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(FreqModel::read(&mut r).unwrap(), m);
    }

    #[test]
    fn from_probs_keeps_all_positive_symbols() {
        let m = FreqModel::from_probs(&[0.999, 1e-9, 0.0009]).unwrap();
        assert!(m.freq(0) > 0);
        assert!(m.freq(1) > 0, "tiny but positive prob must stay encodable");
        assert!(m.freq(2) > 0);
    }

    #[test]
    fn from_probs_oversized_alphabet_is_typed_error() {
        // regression: this used to underflow `MAX_TOTAL - len` and panic
        let p = vec![1.0; MAX_TOTAL as usize + 10];
        assert!(FreqModel::from_probs(&p).is_err());
        let p = vec![1.0; MAX_TOTAL as usize];
        assert!(FreqModel::from_probs(&p).is_err());
    }

    #[test]
    fn from_probs_rejects_degenerate_inputs() {
        assert!(FreqModel::from_probs(&[]).is_err());
        assert!(FreqModel::from_probs(&[0.0, 0.0]).is_err());
        assert!(FreqModel::from_probs(&[f64::NAN, 1.0]).is_err());
        assert!(FreqModel::from_probs(&[f64::INFINITY]).is_err());
        assert!(FreqModel::from_probs(&[-1.0, 2.0]).is_err());
    }

    #[test]
    fn from_freqs_overflow_is_typed_error() {
        assert!(FreqModel::from_freqs(&[u64::MAX, u64::MAX]).is_err());
        assert!(FreqModel::from_freqs(&[MAX_TOTAL + 1]).is_err());
        assert!(FreqModel::from_freqs(&[]).is_err());
        assert!(FreqModel::from_freqs(&[0, 0, 0]).is_err());
    }

    #[test]
    fn empty_input_stream_roundtrips_without_bytes() {
        let model = FreqModel::from_freqs(&[3, 1]).unwrap();
        let mut w = BitWriter::new();
        encode_sequence(&model, &[], &mut w).unwrap();
        let bytes = w.into_bytes();
        let out = decode_sequence(&model, &mut BitReader::new(&bytes), 0).unwrap();
        assert!(out.is_empty());
        // decoding zero symbols from a completely empty buffer is also fine
        let out = decode_sequence(&model, &mut BitReader::new(&[]), 0).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn zero_freq_symbol_encode_fails() {
        let m = FreqModel::from_freqs(&[1, 0]).unwrap();
        let mut w = BitWriter::new();
        let mut enc = ArithEncoder::new(&mut w);
        assert!(enc.encode(&m, 1).is_err());
    }

    #[test]
    fn long_random_roundtrip() {
        let mut rng = Pcg64::new(7);
        let freqs: Vec<u64> = (0..50).map(|_| rng.gen_range(100) + 1).collect();
        let model = FreqModel::from_freqs(&freqs).unwrap();
        let seq: Vec<u32> = (0..20_000).map(|_| rng.gen_index(50) as u32).collect();
        let mut w = BitWriter::new();
        encode_sequence(&model, &seq, &mut w).unwrap();
        let bytes = w.into_bytes();
        let out = decode_sequence(&model, &mut BitReader::new(&bytes), seq.len()).unwrap();
        assert_eq!(out, seq);
    }

    #[test]
    fn mismatched_model_still_lossless() {
        // encode with a model that is NOT the data's distribution
        let model = FreqModel::from_freqs(&[1, 1, 1, 13]).unwrap();
        let seq = vec![0u32, 0, 0, 1, 2, 0, 0, 1];
        let mut w = BitWriter::new();
        encode_sequence(&model, &seq, &mut w).unwrap();
        let bytes = w.into_bytes();
        let out = decode_sequence(&model, &mut BitReader::new(&bytes), seq.len()).unwrap();
        assert_eq!(out, seq);
    }
}
