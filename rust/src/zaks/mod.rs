//! Zaks sequences — the paper's tree-structure code (§3.1, after Zaks 1980).
//!
//! Label internal nodes `1` and leaves `0`, then read labels in preorder.
//! For a (full binary) tree with `n` internal nodes the sequence has length
//! `2n + 1` and is uniquely decodable. Feasibility (paper §3.1):
//!
//! 1. the string begins with `1` (degenerate case: a single-leaf tree is the
//!    string `0` — the paper's trees always split at least once, ours may
//!    not, so we admit it),
//! 2. #zeros = #ones + 1,
//! 3. no proper prefix satisfies (2).
//!
//! Because [`crate::forest::Tree`] stores nodes in preorder, the `i`-th bit
//! of the Zaks sequence corresponds to `tree.nodes[i]` directly.

use crate::forest::{Node, Tree};
use anyhow::{bail, Result};

/// Extract the Zaks sequence of a tree (one bit per stored node, `true` =
/// internal). Relies on preorder node storage.
pub fn zaks_of_tree(tree: &Tree) -> Vec<bool> {
    debug_assert!(tree.is_preorder());
    tree.nodes.iter().map(|n| !n.is_leaf()).collect()
}

/// Validate the three feasibility conditions.
pub fn is_valid_zaks(bits: &[bool]) -> bool {
    if bits.is_empty() {
        return false;
    }
    if bits.len() == 1 {
        return !bits[0]; // single leaf: "0"
    }
    if !bits[0] {
        return false; // condition (i)
    }
    // conditions (ii) + (iii) via a running balance:
    // balance = #zeros - #ones must first hit +1 exactly at the end
    let mut balance: i64 = 0;
    for (i, &b) in bits.iter().enumerate() {
        balance += if b { -1 } else { 1 };
        if balance == 1 && i + 1 != bits.len() {
            return false; // proper prefix satisfies (ii)
        }
    }
    balance == 1
}

/// The decoded structure of one tree: preorder child links.
/// `children[i] = Some((left, right))` for internal nodes, `None` for leaves.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TreeShape {
    /// `Some((left, right))` for internal nodes, `None` for leaves.
    pub children: Vec<Option<(u32, u32)>>,
}

impl TreeShape {
    /// Total number of nodes.
    pub fn node_count(&self) -> usize {
        self.children.len()
    }

    /// Number of internal (splitting) nodes.
    pub fn internal_count(&self) -> usize {
        self.children.iter().filter(|c| c.is_some()).count()
    }

    /// Depth of every node, in preorder — the conditioning variable of the
    /// paper's node models.
    pub fn depths(&self) -> Vec<u32> {
        let mut depths = vec![0u32; self.children.len()];
        for (i, c) in self.children.iter().enumerate() {
            if let Some((l, r)) = c {
                depths[*l as usize] = depths[i] + 1;
                depths[*r as usize] = depths[i] + 1;
            }
        }
        depths
    }
}

/// Decode a Zaks sequence into a [`TreeShape`]. Errors on infeasible input.
pub fn shape_from_zaks(bits: &[bool]) -> Result<TreeShape> {
    if !is_valid_zaks(bits) {
        bail!("infeasible Zaks sequence of length {}", bits.len());
    }
    let mut children: Vec<Option<(u32, u32)>> = vec![None; bits.len()];
    let mut pos = 0usize;
    build(bits, &mut pos, &mut children)?;
    if pos != bits.len() {
        bail!("Zaks sequence has trailing symbols");
    }
    Ok(TreeShape { children })
}

fn build(bits: &[bool], pos: &mut usize, children: &mut [Option<(u32, u32)>]) -> Result<u32> {
    let idx = *pos;
    if idx >= bits.len() {
        bail!("Zaks sequence truncated");
    }
    *pos += 1;
    if bits[idx] {
        let l = build(bits, pos, children)?;
        let r = build(bits, pos, children)?;
        children[idx] = Some((l, r));
    }
    Ok(idx as u32)
}

/// Verify a shape matches a tree's structure node-for-node.
pub fn shape_matches_tree(shape: &TreeShape, tree: &Tree) -> bool {
    if shape.node_count() != tree.nodes.len() {
        return false;
    }
    tree.nodes.iter().zip(&shape.children).all(|(n, c)| match (&n.split, c) {
        (Some((_, l1, r1)), Some((l2, r2))) => l1 == l2 && r1 == r2,
        (None, None) => true,
        _ => false,
    })
}

/// Concatenate the Zaks sequences of many trees into one bitstring, with the
/// per-tree bit lengths (decoding needs the boundaries only if random access
/// is wanted; sequential decode self-delimits via condition (iii)).
pub fn concat_forest_zaks(trees: &[Tree]) -> (Vec<bool>, Vec<u32>) {
    let mut bits = Vec::new();
    let mut lens = Vec::with_capacity(trees.len());
    for t in trees {
        let z = zaks_of_tree(t);
        lens.push(z.len() as u32);
        bits.extend_from_slice(&z);
    }
    (bits, lens)
}

/// Split a concatenated Zaks bitstring back into per-tree sequences using
/// the self-delimiting property (each sequence ends exactly when
/// #zeros = #ones + 1).
pub fn split_concatenated(bits: &[bool], n_trees: usize) -> Result<Vec<Vec<bool>>> {
    let mut out = Vec::with_capacity(n_trees);
    let mut start = 0usize;
    for t in 0..n_trees {
        let mut balance: i64 = 0;
        let mut end = None;
        for (i, &b) in bits[start..].iter().enumerate() {
            balance += if b { -1 } else { 1 };
            if balance == 1 {
                end = Some(start + i + 1);
                break;
            }
        }
        let Some(end) = end else {
            bail!("concatenated Zaks stream ends mid-tree (tree {t})");
        };
        out.push(bits[start..end].to_vec());
        start = end;
    }
    if start != bits.len() {
        bail!("trailing bits after {n_trees} trees");
    }
    Ok(out)
}

/// A dummy placeholder node used when materializing shapes (fits/splits are
/// filled by the container decoder).
pub fn shape_to_skeleton(shape: &TreeShape) -> Tree {
    use crate::forest::{Fit, Split, SplitValue};
    let nodes = shape
        .children
        .iter()
        .map(|c| Node {
            split: c.map(|(l, r)| {
                (Split { feature: 0, value: SplitValue::Numeric(0.0) }, l, r)
            }),
            fit: Fit::Regression(0.0),
        })
        .collect();
    Tree { nodes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::forest::{Forest, ForestParams};
    use crate::testing::prop::forall;

    /// The paper's Figure-1 example sequence. As printed it has 11 ones and
    /// 11 zeros — one trailing `0` short of feasibility (2n+1 = 23), an
    /// apparent typo; with the final `0` restored it decodes.
    #[test]
    fn paper_figure1_sequence_is_valid_with_trailing_zero() {
        let printed = "1111001001001111001000";
        let bits: Vec<bool> = printed.chars().map(|c| c == '1').collect();
        assert!(!is_valid_zaks(&bits), "paper's printed string is one 0 short");
        let mut fixed = bits.clone();
        fixed.push(false);
        assert!(is_valid_zaks(&fixed));
        let shape = shape_from_zaks(&fixed).unwrap();
        let ones = fixed.iter().filter(|&&b| b).count();
        assert_eq!(fixed.len(), 2 * ones + 1);
        assert_eq!(shape.internal_count(), ones);
    }

    #[test]
    fn simple_sequences() {
        // single leaf
        assert!(is_valid_zaks(&[false]));
        // root with two leaves: 100
        assert!(is_valid_zaks(&[true, false, false]));
        // invalid: starts with 0 but longer than 1
        assert!(!is_valid_zaks(&[false, true, false]));
        // invalid: prefix property broken (balance hits +1 early)
        assert!(!is_valid_zaks(&[true, false, false, false]));
        // invalid: never closes
        assert!(!is_valid_zaks(&[true, true, false, false]));
        assert!(!is_valid_zaks(&[]));
    }

    #[test]
    fn tree_roundtrip() {
        let ds = synthetic::iris(5);
        let f = Forest::train(&ds, &ForestParams::classification(5), 2);
        for t in &f.trees {
            let z = zaks_of_tree(t);
            assert!(is_valid_zaks(&z), "trained tree must give feasible Zaks");
            assert_eq!(z.len(), t.nodes.len());
            assert_eq!(z.len(), 2 * t.internal_count() + 1);
            let shape = shape_from_zaks(&z).unwrap();
            assert!(shape_matches_tree(&shape, t));
        }
    }

    #[test]
    fn depths_match_tree() {
        let ds = synthetic::iris(6);
        let f = Forest::train(&ds, &ForestParams::classification(2), 3);
        for t in &f.trees {
            let shape = shape_from_zaks(&zaks_of_tree(t)).unwrap();
            let depths = shape.depths();
            let mut expected = vec![0u32; t.nodes.len()];
            t.visit_preorder(|i, _, d, _| expected[i] = d);
            assert_eq!(depths, expected);
        }
    }

    #[test]
    fn concatenation_roundtrip() {
        let ds = synthetic::wages(7);
        let f = Forest::train(&ds, &ForestParams::classification(8), 4);
        let (bits, lens) = concat_forest_zaks(&f.trees);
        assert_eq!(lens.len(), 8);
        assert_eq!(bits.len() as u64, lens.iter().map(|&l| l as u64).sum());
        let seqs = split_concatenated(&bits, 8).unwrap();
        for (seq, tree) in seqs.iter().zip(&f.trees) {
            assert_eq!(seq, &zaks_of_tree(tree));
        }
    }

    #[test]
    fn split_concatenated_rejects_garbage() {
        assert!(split_concatenated(&[true, true, false], 1).is_err());
        assert!(split_concatenated(&[false, false], 1).is_err()); // trailing
    }

    #[test]
    fn prop_random_shapes_roundtrip() {
        // generate random full binary trees by random valid Zaks strings:
        // do a random walk that never closes early
        forall("zaks roundtrip", |g| {
            let internal = g.usize_in(0, 64);
            let mut bits = Vec::new();
            let mut open = 1i64; // pending subtrees
            let mut remaining = internal as i64;
            while open > 0 {
                let take_internal = remaining > 0 && g.bool(0.5);
                if take_internal {
                    bits.push(true);
                    remaining -= 1;
                    open += 1;
                } else {
                    bits.push(false);
                    open -= 1;
                }
            }
            if !is_valid_zaks(&bits) {
                return Err(format!("constructed invalid sequence len {}", bits.len()));
            }
            let shape = shape_from_zaks(&bits).map_err(|e| e.to_string())?;
            // re-extract from the skeleton and compare
            let skel = shape_to_skeleton(&shape);
            let z2 = zaks_of_tree(&skel);
            if z2 != bits {
                return Err("re-extracted Zaks differs".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_corrupt_sequences_rejected_or_valid() {
        forall("zaks corruption", |g| {
            // start from a valid sequence and flip one bit
            let mut bits = vec![true, false, false];
            for _ in 0..g.usize_in(0, 5) {
                // grow: replace a random leaf(0) with 100
                let leaf_positions: Vec<usize> =
                    (0..bits.len()).filter(|&i| !bits[i]).collect();
                let pos = leaf_positions[g.usize_in(0, leaf_positions.len() - 1)];
                bits.splice(pos..=pos, [true, false, false]);
            }
            let flip = g.usize_in(0, bits.len() - 1);
            bits[flip] = !bits[flip];
            // flipping a bit changes the 0/1 balance ⇒ never valid
            if is_valid_zaks(&bits) {
                return Err("single bit flip kept sequence valid".into());
            }
            // and decoding must not panic
            let _ = shape_from_zaks(&bits);
            Ok(())
        });
    }
}
