//! Per-request trace spans: where did this request's time go?
//!
//! A [`Span`] is created when a request line is parsed, rides the job
//! through the batcher and the store, and is finished right after the
//! reply is written (serial) or rendered (pipelined). Each [`Phase`] owns
//! one microsecond slot; the router annotates spans with attempt count
//! and the backend that answered. Finished spans feed the
//! [`crate::obs::Obs`] hub: phase totals into `phase_<name>_us` counters,
//! and the rendered line into the slow-request ring when the wall time
//! crosses the threshold.

use std::time::Instant;

/// One timed segment of a request's life, in pipeline order.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Phase {
    /// Splitting the wire line into verb, model, and values.
    Parse,
    /// Pipelined admission: tracker bookkeeping under the in-flight cap.
    Admit,
    /// Flat-plan build on a plan-cache miss (hits spend ~0 here).
    Plan,
    /// Reloading spilled container bytes from the disk tier.
    Reload,
    /// Materializing a pack member on first touch.
    PackLoad,
    /// Sitting in the batch window waiting for the batcher to drain.
    BatchWait,
    /// Tree traversal itself (plan-build time on a miss is carved out
    /// into [`Phase::Plan`]).
    Execute,
    /// Rendering and handing the reply off — the serial rendezvous send
    /// or the pipelined outbox enqueue. The socket write itself runs on
    /// the reader/writer thread after the span is observed and is not
    /// attributed.
    Write,
    /// Merging a pack generation chain into a fresh base (store-side
    /// compaction; runs outside any one request but is span-timed so the
    /// `phase_compact_us` counter attributes the maintenance cost).
    Compact,
}

impl Phase {
    /// Every phase, in pipeline order.
    pub const ALL: [Phase; 9] = [
        Phase::Parse,
        Phase::Admit,
        Phase::Plan,
        Phase::Reload,
        Phase::PackLoad,
        Phase::BatchWait,
        Phase::Execute,
        Phase::Write,
        Phase::Compact,
    ];

    /// Stable lower-case name: `phase_<name>_us` registry counters and
    /// `SLOW` line fields key off it.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Parse => "parse",
            Phase::Admit => "admit",
            Phase::Plan => "plan",
            Phase::Reload => "reload",
            Phase::PackLoad => "pack_load",
            Phase::BatchWait => "batch_wait",
            Phase::Execute => "execute",
            Phase::Write => "write",
            Phase::Compact => "compact",
        }
    }

    fn idx(self) -> usize {
        self as usize
    }
}

/// Phase timings one store call attributes back to the request(s) that
/// rode it. The server copies these into each member job's [`Span`];
/// plan-cache hit/miss counts come from a before/after delta of the
/// shared cache counters, so under concurrency a neighbor batch's
/// traffic can bleed in — attribution is approximate, totals are exact.
#[derive(Default, Clone, Copy, Debug)]
pub struct BatchTrace {
    /// µs spent reloading spilled bytes (zero unless the model was
    /// spilled when the call started).
    pub reload_us: u64,
    /// µs spent materializing a pack member (zero unless packed-unloaded
    /// at call start).
    pub pack_load_us: u64,
    /// µs in tree traversal, **including** any plan builds it triggered
    /// ([`Span::absorb`] carves those out into [`Phase::Plan`]).
    pub execute_us: u64,
    /// µs spent building flat plans on cache misses during the call
    /// (delta of the shared cache's build timer).
    pub plan_us: u64,
    /// Plan-cache hits observed across the call.
    pub plan_hits: u64,
    /// Plan-cache misses (each one paid a flat-plan build).
    pub plan_misses: u64,
}

/// Phase-timed record of one request.
pub struct Span {
    started: Instant,
    phase_us: [u64; 9],
    wall_us: u64,
    model: String,
    /// Attempt legs a router spent on this request (0 = not routed; ≥ 2
    /// means at least one failover/retry).
    pub attempts: u32,
    /// Backend that answered, when routed.
    pub backend: Option<String>,
    /// Plan-cache hits attributed to this request's store call.
    pub plan_hits: u64,
    /// Plan-cache misses attributed to this request's store call.
    pub plan_misses: u64,
}

impl Span {
    /// Start a span now, for a request against `model`.
    pub fn begin(model: &str) -> Span {
        Span::begin_at(Instant::now(), model)
    }

    /// Start a span whose clock began at `started` — for callers that did
    /// timed work (parsing the request line) before the model name was
    /// known, so the wall time still covers it.
    pub fn begin_at(started: Instant, model: &str) -> Span {
        Span {
            started,
            phase_us: [0; 9],
            wall_us: 0,
            model: model.to_string(),
            attempts: 0,
            backend: None,
            plan_hits: 0,
            plan_misses: 0,
        }
    }

    /// The instant the span started (the batcher subtracts it to charge
    /// [`Phase::BatchWait`]).
    pub fn started(&self) -> Instant {
        self.started
    }

    /// Add `us` to `phase` (accumulates; a retried request charges the
    /// same phase more than once).
    pub fn add(&mut self, phase: Phase, us: u64) {
        self.phase_us[phase.idx()] += us;
    }

    /// Time `f` and charge its duration to `phase`.
    pub fn time<R>(&mut self, phase: Phase, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed().as_micros() as u64);
        out
    }

    /// Fold a store-call [`BatchTrace`] into this span. Plan-build time
    /// is a sub-interval of the traced execute window, so it is carved
    /// out of [`Phase::Execute`] into [`Phase::Plan`] — phases stay
    /// non-overlapping and their sum stays within the wall time.
    pub fn absorb(&mut self, t: &BatchTrace) {
        self.add(Phase::Reload, t.reload_us);
        self.add(Phase::PackLoad, t.pack_load_us);
        self.add(Phase::Plan, t.plan_us.min(t.execute_us));
        self.add(Phase::Execute, t.execute_us.saturating_sub(t.plan_us));
        self.plan_hits += t.plan_hits;
        self.plan_misses += t.plan_misses;
    }

    /// Stamp the wall time (start → now). Call once, after the reply is
    /// out; phases recorded later would no longer be covered by the wall.
    pub fn finish(&mut self) {
        self.wall_us = self.started.elapsed().as_micros() as u64;
    }

    /// Wall time stamped by [`Span::finish`] (0 before it).
    pub fn wall_us(&self) -> u64 {
        self.wall_us
    }

    /// µs recorded for `phase`.
    pub fn phase_us(&self, phase: Phase) -> u64 {
        self.phase_us[phase.idx()]
    }

    /// Model the request targeted.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// One `key=value` line for the `SLOW` dump: wall time, model, every
    /// phase (`<name>_us=`), plan hit/miss counts, and — when routed —
    /// `attempts=` and `backend=`.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut line = format!("wall_us={} model={}", self.wall_us, self.model);
        for p in Phase::ALL {
            let _ = write!(line, " {}_us={}", p.name(), self.phase_us(p));
        }
        let _ = write!(line, " plan_hits={} plan_misses={}", self.plan_hits, self.plan_misses);
        if self.attempts > 0 {
            let _ = write!(line, " attempts={}", self.attempts);
        }
        if let Some(b) = &self.backend {
            let _ = write!(line, " backend={b}");
        }
        line
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Sanity: phases are sub-intervals of the request, so their sum
    /// never exceeds the wall time the span stamps at finish.
    #[test]
    fn span_phase_sum_stays_within_wall() {
        let mut s = Span::begin("m0");
        s.time(Phase::Parse, || std::thread::sleep(std::time::Duration::from_millis(2)));
        s.time(Phase::Execute, || std::thread::sleep(std::time::Duration::from_millis(3)));
        s.finish();
        let sum: u64 = Phase::ALL.iter().map(|&p| s.phase_us(p)).sum();
        assert!(sum > 0, "timed phases recorded nothing");
        assert!(
            sum <= s.wall_us(),
            "phase sum {} exceeds wall {}",
            sum,
            s.wall_us()
        );
    }

    #[test]
    fn render_names_every_phase_and_router_legs() {
        let mut s = Span::begin("tenant-7");
        s.add(Phase::Reload, 812);
        s.absorb(&BatchTrace { execute_us: 40, plan_misses: 1, ..Default::default() });
        s.attempts = 2;
        s.backend = Some("127.0.0.1:7001".into());
        s.finish();
        let line = s.render();
        for p in Phase::ALL {
            assert!(line.contains(&format!(" {}_us=", p.name())), "missing {}", p.name());
        }
        assert!(line.contains("model=tenant-7"));
        assert!(line.contains(" reload_us=812"));
        assert!(line.contains(" attempts=2"));
        assert!(line.contains(" backend=127.0.0.1:7001"));
        assert!(line.contains(" plan_misses=1"));
    }
}
