//! Bounded slow-request ring: the last N rendered spans that crossed the
//! slow threshold, oldest evicted first. `SLOW [n]` dumps it.

use std::collections::VecDeque;
use std::sync::Mutex;

/// Fixed-capacity ring of rendered span lines. Pushes are rare by
/// construction (only threshold-crossing requests), so one mutex is
/// plenty; capacity 0 disables retention entirely.
pub struct SlowRing {
    inner: Mutex<VecDeque<String>>,
    cap: usize,
}

impl SlowRing {
    /// Ring holding at most `cap` entries.
    pub fn new(cap: usize) -> SlowRing {
        SlowRing { inner: Mutex::new(VecDeque::with_capacity(cap.min(1024))), cap }
    }

    /// Append a rendered span, evicting the oldest entry when full.
    pub fn push(&self, line: String) {
        if self.cap == 0 {
            return;
        }
        let mut q = self.inner.lock().unwrap();
        if q.len() == self.cap {
            q.pop_front();
        }
        q.push_back(line);
    }

    /// Up to `n` retained entries, newest first.
    pub fn dump(&self, n: usize) -> Vec<String> {
        let q = self.inner.lock().unwrap();
        q.iter().rev().take(n).cloned().collect()
    }

    /// Entries currently retained.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// True when nothing has been retained.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Maximum entries retained.
    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_caps_at_capacity_and_evicts_oldest() {
        let r = SlowRing::new(3);
        for i in 0..5 {
            r.push(format!("req{i}"));
        }
        assert_eq!(r.len(), 3);
        // newest first; req0/req1 evicted
        assert_eq!(r.dump(10), vec!["req4", "req3", "req2"]);
        assert_eq!(r.dump(2), vec!["req4", "req3"]);
    }

    #[test]
    fn zero_capacity_ring_retains_nothing() {
        let r = SlowRing::new(0);
        r.push("req".into());
        assert!(r.is_empty());
        assert!(r.dump(10).is_empty());
    }
}
