//! In-process observability: metrics registry, per-request trace spans,
//! and the slow-request ring — zero external dependencies.
//!
//! One [`Obs`] hub lives on each process role: the model store owns one
//! (request histogram + store/server counters) and the router owns its
//! own (route histogram + routing counters). The hot path touches only pre-registered atomic handles;
//! the `METRICS` verb renders [`Obs::expose`] and `SLOW [n]` dumps the
//! ring. See `rust/PROTOCOL.md` for the wire grammar and
//! `rust/OPERATIONS.md` for how to read the output.

pub mod metrics;
pub mod ring;
pub mod trace;

pub use metrics::{Histogram, Metric, Registry};
pub use ring::SlowRing;
pub use trace::{BatchTrace, Phase, Span};

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Default `--slow-threshold-us`: requests slower than 100 ms retain
/// their phase breakdown.
pub const DEFAULT_SLOW_THRESHOLD_US: u64 = 100_000;
/// Default `--trace-ring` capacity.
pub const DEFAULT_TRACE_RING: usize = 128;

/// `StoreStats` keys mirrored into a store-role registry at exposition
/// time (monotonic counters).
const STORE_COUNTERS: [&str; 14] = [
    "requests",
    "batches",
    "evictions",
    "spills",
    "reloads",
    "plan_hits",
    "plan_misses",
    "pack_loads",
    "pack_releases",
    "rejected_busy",
    "timeouts",
    "prefetches",
    "admission_rejects",
    "compactions",
];
/// `StoreStats` keys that are levels, not counts.
const STORE_GAUGES: [&str; 4] = ["inflight", "spill_bytes", "pack_generations", "tombstones"];

/// `RouterStats` keys mirrored into a router-role registry (counters).
const ROUTER_COUNTERS: [&str; 6] =
    ["routed", "retries", "failovers", "ejections", "readmissions", "unavailable"];
/// Router level metrics.
const ROUTER_GAUGES: [&str; 1] = ["backends_up"];

/// Per-role observability hub: the registry, a request-latency histogram
/// handle, per-phase µs counters, the slow ring, and the on/off switch
/// (`set_enabled(false)` is how the overhead bench measures the traced
/// path against itself with recording elided).
pub struct Obs {
    registry: Registry,
    request_us: Arc<Histogram>,
    phase_us: [Arc<AtomicU64>; 9],
    ring: SlowRing,
    slow_threshold_us: AtomicU64,
    enabled: AtomicBool,
}

impl Obs {
    /// Build a hub with the given latency-histogram name and mirrored
    /// counter/gauge names pre-registered.
    fn new(
        hist_name: &str,
        counters: &[&str],
        gauges: &[&str],
        slow_threshold_us: u64,
        ring_cap: usize,
    ) -> Obs {
        let registry = Registry::new();
        for c in counters {
            registry.counter(c);
        }
        for g in gauges {
            registry.gauge(g);
        }
        let request_us = registry.histogram(hist_name);
        let phase_us =
            Phase::ALL.map(|p| registry.counter(&format!("phase_{}_us", p.name())));
        Obs {
            registry,
            request_us,
            phase_us,
            ring: SlowRing::new(ring_cap),
            slow_threshold_us: AtomicU64::new(slow_threshold_us),
            enabled: AtomicBool::new(true),
        }
    }

    /// Hub for a serving backend: `request_latency_us` histogram plus the
    /// `STATS`-mirrored store counters.
    pub fn for_store(slow_threshold_us: u64, ring_cap: usize) -> Obs {
        Obs::new("request_latency_us", &STORE_COUNTERS, &STORE_GAUGES, slow_threshold_us, ring_cap)
    }

    /// Hub for a router: `route_latency_us` histogram plus the routing
    /// counters.
    pub fn for_router(slow_threshold_us: u64, ring_cap: usize) -> Obs {
        Obs::new("route_latency_us", &ROUTER_COUNTERS, &ROUTER_GAUGES, slow_threshold_us, ring_cap)
    }

    /// The metric registry (exposition, drift guards, mirrors).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// The role's latency histogram (`request_latency_us` /
    /// `route_latency_us`).
    pub fn request_us(&self) -> &Histogram {
        &self.request_us
    }

    /// The slow-request ring.
    pub fn ring(&self) -> &SlowRing {
        &self.ring
    }

    /// Current slow threshold (µs). A finished span at or above it is
    /// retained; 0 retains everything.
    pub fn slow_threshold_us(&self) -> u64 {
        self.slow_threshold_us.load(Ordering::Relaxed)
    }

    /// Change the slow threshold (builder-time configuration; safe at
    /// runtime too).
    pub fn set_slow_threshold_us(&self, us: u64) {
        self.slow_threshold_us.store(us, Ordering::Relaxed);
    }

    /// Whether recording is on.
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Turn recording off (and back on). With recording off,
    /// [`Obs::observe`] and the latency histogram feeds become no-ops —
    /// the overhead bench's tracing-off leg.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Record `n` request completions at `us` into the latency histogram.
    pub fn record_latency(&self, us: u64, n: u64) {
        if self.enabled() {
            self.request_us.record_n(us, n);
        }
    }

    /// Fold a finished span into the hub: phase totals into the
    /// `phase_<name>_us` counters, and the rendered line into the ring
    /// when the wall time crosses the threshold.
    pub fn observe(&self, span: &Span) {
        if !self.enabled() {
            return;
        }
        for (i, p) in Phase::ALL.iter().enumerate() {
            let us = span.phase_us(*p);
            if us > 0 {
                self.phase_us[i].fetch_add(us, Ordering::Relaxed);
            }
        }
        if span.wall_us() >= self.slow_threshold_us() {
            self.ring.push(span.render());
        }
    }

    /// Render the Prometheus-style exposition (sorted by metric name).
    pub fn expose(&self) -> Vec<String> {
        self.registry.expose()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observe_feeds_phase_counters_and_slow_ring() {
        let obs = Obs::for_store(0, 8); // threshold 0: everything is slow
        let mut span = Span::begin("m1");
        span.add(Phase::Reload, 900);
        span.add(Phase::Execute, 50);
        span.finish();
        obs.observe(&span);
        obs.record_latency(950, 1);
        let text = obs.expose().join("\n");
        assert!(text.contains("phase_reload_us 900"), "missing reload total in:\n{text}");
        assert!(text.contains("phase_execute_us 50"));
        assert!(text.contains("request_latency_us_count 1"));
        assert_eq!(obs.ring().len(), 1);
        assert!(obs.ring().dump(1)[0].contains("reload_us=900"));
    }

    #[test]
    fn disabled_hub_records_nothing() {
        let obs = Obs::for_store(0, 8);
        obs.set_enabled(false);
        let mut span = Span::begin("m1");
        span.add(Phase::Execute, 10);
        span.finish();
        obs.observe(&span);
        obs.record_latency(10, 1);
        assert!(obs.ring().is_empty());
        assert_eq!(obs.request_us().count(), 0);
    }

    #[test]
    fn registries_name_every_mirrored_stat() {
        let store = Obs::for_store(1, 1);
        let names = store.registry().names();
        for k in STORE_COUNTERS.iter().chain(STORE_GAUGES.iter()) {
            assert!(names.iter().any(|n| n == k), "store registry missing {k}");
        }
        assert!(names.iter().any(|n| n == "request_latency_us"));
        let router = Obs::for_router(1, 1);
        let rnames = router.registry().names();
        for k in ROUTER_COUNTERS.iter().chain(ROUTER_GAUGES.iter()) {
            assert!(rnames.iter().any(|n| n == k), "router registry missing {k}");
        }
        assert!(rnames.iter().any(|n| n == "route_latency_us"));
    }
}
