//! Lock-free metrics primitives: an atomic log-bucketed latency
//! [`Histogram`] and a sharded name → metric [`Registry`].
//!
//! Registration happens once, at hub construction (cold path); callers
//! hold the returned `Arc` handles and increment plain atomics, so the
//! request hot path never touches the shard maps. The registry exists for
//! the cold paths: [`Registry::names`] feeds the protocol-doc drift guard
//! and [`Registry::expose`] renders the Prometheus-style text the
//! `METRICS` verb ships.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// 8 exact buckets + 8 per power-of-two region up to `2^63`.
const BUCKETS: usize = 8 * 62;

/// Log-bucketed latency histogram: exact below 8 µs, then eight
/// sub-buckets per power of two (≤ 12.5% relative bucket width) — compact
/// enough to share across threads, fine enough for honest p99s. Every
/// cell is an atomic, so recording takes `&self` and no lock; this is the
/// shared home of the histogram the loadgen client and the server-side
/// request/route timers all use.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    max: AtomicU64,
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    fn bucket_of(v: u64) -> usize {
        if v < 8 {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros() as usize;
        8 * (msb - 2) + ((v >> (msb - 3)) & 7) as usize
    }

    /// Upper edge of the bucket (conservative for tail quantiles).
    fn bucket_value(idx: usize) -> u64 {
        if idx < 8 {
            return idx as u64;
        }
        let msb = idx / 8 + 2;
        let sub = (idx % 8) as u64;
        ((8 + sub) << (msb - 3)) + (1 << (msb - 3)) - 1
    }

    /// Record one latency observation (µs).
    pub fn record(&self, us: u64) {
        self.record_n(us, 1);
    }

    /// Record `n` observations of the same value — a completed batch
    /// charges every member request the batch latency in one call.
    pub fn record_n(&self, us: u64, n: u64) {
        if n == 0 {
            return;
        }
        self.buckets[Self::bucket_of(us)].fetch_add(n, Ordering::Relaxed);
        self.count.fetch_add(n, Ordering::Relaxed);
        self.max.fetch_max(us, Ordering::Relaxed);
    }

    /// Number of recorded observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Largest recorded observation (exact, not bucketed).
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Approximate quantile (`q` in `[0, 1]`), reported at its bucket's
    /// upper edge and capped at the exact max. Returns 0 when empty.
    /// Concurrent recording can skew a readout by the in-flight samples;
    /// the readout is for monitoring, not accounting.
    pub fn quantile(&self, q: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let max = self.max();
        let target = ((q.clamp(0.0, 1.0) * count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_value(i).min(max);
            }
        }
        max
    }

    /// Occupied buckets as `(upper_edge_us, count)` pairs, ascending by
    /// edge — the exposition renders cumulative `_bucket{le=...}` rows
    /// from these.
    pub fn nonzero_buckets(&self) -> Vec<(u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let c = b.load(Ordering::Relaxed);
                (c > 0).then_some((Self::bucket_value(i), c))
            })
            .collect()
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// One registered metric: the variant fixes the exposition `# TYPE`.
#[derive(Clone)]
pub enum Metric {
    /// Monotonically increasing count.
    Counter(Arc<AtomicU64>),
    /// Point-in-time level (can go down).
    Gauge(Arc<AtomicU64>),
    /// Latency distribution.
    Histogram(Arc<Histogram>),
}

const SHARDS: usize = 8;

/// FNV-1a over the metric name — stable and dependency-free.
fn shard_of(name: &str) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    (h % SHARDS as u64) as usize
}

/// Sharded name → metric map. Handles are registered once and held by
/// their owners; by-name lookups (registration, exposition-time mirrors)
/// take one shard's lock and never contend with increments.
pub struct Registry {
    shards: Vec<RwLock<BTreeMap<String, Metric>>>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Registry {
        Registry {
            shards: (0..SHARDS).map(|_| RwLock::new(BTreeMap::new())).collect(),
        }
    }

    /// Register (or fetch) the counter named `name`.
    ///
    /// # Panics
    /// If `name` is already registered as a different metric type.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut shard = self.shards[shard_of(name)].write().unwrap();
        let m = shard
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(AtomicU64::new(0))));
        match m {
            Metric::Counter(c) => c.clone(),
            _ => panic!("metric {name:?} already registered with a different type"),
        }
    }

    /// Register (or fetch) the gauge named `name`.
    ///
    /// # Panics
    /// If `name` is already registered as a different metric type.
    pub fn gauge(&self, name: &str) -> Arc<AtomicU64> {
        let mut shard = self.shards[shard_of(name)].write().unwrap();
        let m = shard
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(AtomicU64::new(0))));
        match m {
            Metric::Gauge(g) => g.clone(),
            _ => panic!("metric {name:?} already registered with a different type"),
        }
    }

    /// Register (or fetch) the histogram named `name`.
    ///
    /// # Panics
    /// If `name` is already registered as a different metric type.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut shard = self.shards[shard_of(name)].write().unwrap();
        let m = shard
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::new())));
        match m {
            Metric::Histogram(h) => h.clone(),
            _ => panic!("metric {name:?} already registered with a different type"),
        }
    }

    /// Store `value` into the counter or gauge named `name`, if
    /// registered. Exposition-time mirror for stats an existing struct
    /// (e.g. `StoreStats`) still owns — histograms are not settable.
    pub fn set(&self, name: &str, value: u64) {
        let shard = self.shards[shard_of(name)].read().unwrap();
        match shard.get(name) {
            Some(Metric::Counter(c)) | Some(Metric::Gauge(c)) => {
                c.store(value, Ordering::Relaxed);
            }
            _ => {}
        }
    }

    /// Every registered metric name, sorted — the protocol-doc drift
    /// guard iterates this.
    pub fn names(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for shard in &self.shards {
            out.extend(shard.read().unwrap().keys().cloned());
        }
        out.sort();
        out
    }

    /// Render the Prometheus-style text exposition, one element per
    /// output line: a `# TYPE` comment per metric, `name value` samples
    /// for counters and gauges, and for histograms the cumulative
    /// `_bucket{le="..."}` rows (occupied buckets plus `+Inf`), `_count`,
    /// `_max`, and `{quantile="..."}` readouts for p50/p95/p99.
    pub fn expose(&self) -> Vec<String> {
        let mut metrics: Vec<(String, Metric)> = Vec::new();
        for shard in &self.shards {
            for (name, m) in shard.read().unwrap().iter() {
                metrics.push((name.clone(), m.clone()));
            }
        }
        metrics.sort_by(|a, b| a.0.cmp(&b.0));
        let mut out = Vec::new();
        for (name, m) in &metrics {
            match m {
                Metric::Counter(c) => {
                    out.push(format!("# TYPE {name} counter"));
                    out.push(format!("{name} {}", c.load(Ordering::Relaxed)));
                }
                Metric::Gauge(g) => {
                    out.push(format!("# TYPE {name} gauge"));
                    out.push(format!("{name} {}", g.load(Ordering::Relaxed)));
                }
                Metric::Histogram(h) => {
                    out.push(format!("# TYPE {name} histogram"));
                    let mut cum = 0u64;
                    for (edge, c) in h.nonzero_buckets() {
                        cum += c;
                        out.push(format!("{name}_bucket{{le=\"{edge}\"}} {cum}"));
                    }
                    out.push(format!("{name}_bucket{{le=\"+Inf\"}} {}", h.count()));
                    out.push(format!("{name}_count {}", h.count()));
                    out.push(format!("{name}_max {}", h.max()));
                    for (q, label) in [(0.50, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
                        out.push(format!(
                            "{name}{{quantile=\"{label}\"}} {}",
                            h.quantile(q)
                        ));
                    }
                }
            }
        }
        out
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Moved here with the histogram from `testing/loadgen.rs`: quantiles
    /// land within one bucket width of the exact rank and stay ordered.
    #[test]
    fn histogram_quantiles_are_close_and_ordered() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let (p50, p95, p99) = (h.quantile(0.50), h.quantile(0.95), h.quantile(0.99));
        assert!(p50 <= p95 && p95 <= p99, "quantiles out of order: {p50} {p95} {p99}");
        assert!((430..=575).contains(&p50), "p50 {p50} too far from 500");
        assert!((850..=1000).contains(&p95), "p95 {p95} too far from 950");
        assert!((930..=1000).contains(&p99), "p99 {p99} too far from 990");
        assert_eq!(h.max(), 1000);
        // bucket round-trip: the reported edge is ≥ the value and within
        // 12.5% of it
        for v in [0u64, 5, 7, 8, 100, 4096, 1 << 40] {
            let bv = Histogram::bucket_value(Histogram::bucket_of(v));
            assert!(bv >= v && bv <= v + v / 8 + 1, "bucket edge {bv} for {v}");
        }
    }

    /// Property: for random samples, every reported pN sits within its
    /// bucket's bounds — at or above the exact sorted quantile, and no
    /// more than one bucket width (12.5%) past it.
    #[test]
    fn histogram_quantile_is_bounded_by_its_bucket() {
        crate::testing::prop::forall("hist-quantile-bounds", |g| {
            let n = g.usize_in(1, 512);
            let h = Histogram::new();
            let mut vals: Vec<u64> = (0..n).map(|_| g.u64_in(0, 2_000_000)).collect();
            for &v in &vals {
                h.record(v);
            }
            vals.sort_unstable();
            for q in [0.5f64, 0.95, 0.99] {
                let target = ((q * n as f64).ceil() as usize).clamp(1, n);
                let exact = vals[target - 1];
                let got = h.quantile(q);
                if got < exact || got > (exact + exact / 8 + 1).min(h.max()) {
                    return Err(format!("q={q} exact={exact} got={got} n={n}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn record_n_matches_repeated_record() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record_n(777, 5);
        for _ in 0..5 {
            b.record(777);
        }
        assert_eq!(a.count(), b.count());
        assert_eq!(a.max(), b.max());
        assert_eq!(a.quantile(0.99), b.quantile(0.99));
        assert_eq!(a.nonzero_buckets(), b.nonzero_buckets());
    }

    #[test]
    fn registry_exposes_typed_counters_gauges_and_histograms() {
        let r = Registry::new();
        r.counter("requests").fetch_add(3, Ordering::Relaxed);
        r.gauge("inflight").store(2, Ordering::Relaxed);
        r.histogram("request_latency_us").record(100);
        r.set("requests", 9); // exposition-time mirror overwrites
        let text = r.expose().join("\n");
        assert!(text.contains("# TYPE requests counter"));
        assert!(text.contains("requests 9"));
        assert!(text.contains("# TYPE inflight gauge"));
        assert!(text.contains("inflight 2"));
        assert!(text.contains("# TYPE request_latency_us histogram"));
        assert!(text.contains("request_latency_us_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("request_latency_us_count 1"));
        assert!(text.contains("request_latency_us{quantile=\"0.99\"}"));
        let names = r.names();
        assert_eq!(names, vec!["inflight", "request_latency_us", "requests"]);
    }
}
