//! Algorithm 1 — the lossless forest codec.
//!
//! * [`container`] — the on-disk format: header, value tables, cluster maps,
//!   dictionaries, and the four payload sections (structure / variable
//!   names / split values / fits), each per-tree byte-addressable
//! * [`pipeline`]  — compress (extract → cluster → encode) and the full
//!   decompress (bit-exact forest reconstruction)
//! * [`predict`]   — prediction straight from the compressed bytes (§5):
//!   walk a tree's Zaks shape, Huffman-decoding only the preorder prefix a
//!   root-to-leaf path needs, without materializing the forest
//! * [`flat`]      — the batch execution engine: trees decoded once into
//!   struct-of-arrays [`flat::FlatTree`] plans, blocked row routing, and a
//!   bounded [`flat::PlanCache`] so repeated batches skip the decode
//!
//! Losslessness contract (asserted by integration tests): for any trained
//! [`crate::forest::Forest`], `decompress(compress(f)) == f` with bit-exact
//! split values and fits, and compressed-format predictions equal the
//! original forest's predictions on every row.

pub mod container;
pub mod flat;
pub mod pipeline;
pub mod predict;

pub use container::{FitCodec, SectionSizes, SharedBytes};
pub use flat::{FlatTree, PlanCache};
pub use pipeline::{CodecPlan, CompressOptions, CompressedForest};
pub use predict::CompressedPredictor;
