//! Algorithm 1 — the lossless forest codec.
//!
//! * [`container`] — the on-disk format: header, value tables, cluster maps,
//!   dictionaries, and the four payload sections (structure / variable
//!   names / split values / fits), each per-tree byte-addressable
//! * [`pipeline`]  — compress (extract → cluster → encode) and the full
//!   decompress (bit-exact forest reconstruction)
//! * [`predict`]   — prediction straight from the compressed bytes (§5):
//!   walk a tree's Zaks shape, Huffman-decoding only the preorder prefix a
//!   root-to-leaf path needs, without materializing the forest
//!
//! Losslessness contract (asserted by integration tests): for any trained
//! [`crate::forest::Forest`], `decompress(compress(f)) == f` with bit-exact
//! split values and fits, and compressed-format predictions equal the
//! original forest's predictions on every row.

pub mod container;
pub mod pipeline;
pub mod predict;

pub use container::{FitCodec, SectionSizes};
pub use pipeline::{CompressOptions, CompressedForest};
pub use predict::CompressedPredictor;
